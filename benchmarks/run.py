"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (values that are ratios or
counts are emitted as plain values; see each module). Modules may also
write machine-readable JSON artifacts next to the working directory —
``bench_engine`` writes ``BENCH_engine.json`` (rows/s per execution
backend, jax-vs-numpy speedup, share hit rate, compile/stage counts) so
the perf trajectory is tracked per PR.
"""
from __future__ import annotations

import os
import sys
import traceback

MODULES = [
    "bench_series",      # Fig 6
    "bench_nlp",         # Fig 7
    "bench_image",       # Fig 8
    "bench_storage",     # Fig 9
    "bench_selection",   # Fig 10
    "bench_placement",   # Figs 11-12
    "bench_batchsize",   # Table 3
    "bench_sharing",     # Fig 13
    "bench_engine",      # ours: end-to-end engine vs per-row inference
    "bench_serving",     # ours: MorphingServer vs per-request execution
    "bench_sharding",    # ours: mesh-parallel embed lanes vs 1 device
    #                    # (run standalone for real simulated devices:
    #                    # earlier benches fix the jax device topology)
    "bench_roofline",    # ours: §Roofline summary
]


def main() -> int:
    print("name,us_per_call,derived")
    failed = []
    only = sys.argv[1:] if len(sys.argv) > 1 else None
    for mod_name in MODULES:
        if only and mod_name not in only:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            mod.run()
        except Exception:
            failed.append(mod_name)
            traceback.print_exc()
    for artifact in ("BENCH_engine.json", "BENCH_serving.json",
                     "BENCH_sharding.json"):
        if os.path.exists(artifact):
            print(f"# artifact: {artifact}")
    if failed:
        print(f"# FAILED: {failed}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
