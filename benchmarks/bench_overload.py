"""Overload + chaos benchmark for the SLO-aware admission layer.

Three legs against the same mixed-tenant ``PREDICT`` workload
(interactive requests with deadlines, batch, and best-effort bulk):

1. **sustainable** — closed-loop: the server's sustainable request rate
   with the admission policy attached (this calibrates the overload leg,
   so the bench adapts to the machine instead of hardcoding a rate);
2. **overload** — open-loop submission at ``OVERLOAD_X`` (2x) the
   sustainable rate. Graceful degradation is the contract: interactive
   p95 must hold within its SLO bound while best-effort is the class
   that degrades (sheds via typed ``Rejected`` backpressure) — both
   asserted in-bench;
3. **chaos** — a ``FaultInjector`` kills >= ``CHAOS_ERROR_RATE`` (5%+)
   of trunk batches. Failed batches surface as ``RequestError`` on
   exactly their requests; every non-injected request must match the
   fault-free engine answer (parity), and the same server keeps serving
   afterwards — no restart.

The share cache is disabled for this bench: every request pays real
trunk work, so saturation (and therefore backpressure) is genuine
rather than an artifact of cache-hit traffic.

Run directly for machine-readable output::

    PYTHONPATH=src:. python benchmarks/bench_overload.py \
        --json BENCH_overload.json

``BENCH_overload.json`` is gated by ``scripts/check_bench.py``
(``docs/benchmarks.md`` documents the schema and baseline protocol:
median run for throughput floors, max-of-3 for the p95 ceiling).
``--smoke`` shrinks the workload for CI.
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np

from benchmarks.common import emit_value
from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import MorphingServer, MorphingSession
from repro.pipeline import AdmissionPolicy, Rejected, RequestError
from repro.training.fault import FaultInjector, InjectedFault

N_ROWS = 2000
TRUNK_WIDTH = 160                # heavy enough that trunk work is real
N_CALIBRATE = 48                 # closed-loop requests for leg 1
N_OVERLOAD = 96                  # open-loop requests for leg 2
N_CHAOS = 48                     # closed-loop requests for leg 3
CONCURRENCY = 8
OVERLOAD_X = 2.0                 # offered load vs sustainable
CHAOS_ERROR_RATE = 0.10          # >= 5% of batches killed
# interactive SLO: a multiple of the *unloaded* interactive p95 — the
# contract is "overload does not blow up the premium tail", not an
# absolute number that would flake across machines
SLO_FACTOR = 10.0
SLO_FLOOR_MS = 50.0
# below this the statistical asserts are recorded but not enforced
# (tiny smoke runs don't have enough samples for stable percentiles)
MIN_REQUESTS_FOR_ASSERT = 64


# -- workload ---------------------------------------------------------------

def _setup(n_rows: int):
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=160, dim=16, classes=3)
    zoo = [pretrain_model(src, width=TRUNK_WIDTH, seed=1, name="ovl-m0")]
    rng = np.random.default_rng(0)
    table = {"len": rng.integers(1, 200, n_rows),
             "emb": rng.standard_normal((n_rows, 16)).astype(np.float32)}
    sample = make_task(rng, "gauss", n=128, dim=16, classes=3)
    return zoo, table, sample


def _make_session(zoo, table, sample):
    # share cache off: every request pays trunk compute, so the
    # sustainable rate (and the overload above it) is real work
    sess = MorphingSession(zoo=zoo, model_store="decoupled",
                           backend="numpy", enable_share=False)
    sess.register_table("reviews",
                        {k: v.copy() for k, v in table.items()})
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0
    sess.resolve_task("sent", sample.X, sample.y)
    return sess


def _mixed_requests(n: int, slo_ms: float):
    """(sql, priority, deadline_ms) mix: 25% interactive over small row
    windows with the SLO deadline, 25% batch, 50% best-effort bulk."""
    reqs = []
    for i in range(n):
        r = i % 4
        if r == 0:
            reqs.append((f"PREDICT emb USING TASK sent FROM reviews "
                         f"WHERE len > {170 + (i % 8)}",
                         "interactive", slo_ms))
        elif r == 1:
            reqs.append((f"PREDICT emb USING TASK sent FROM reviews "
                         f"WHERE len > {100 + (i % 8)}", "batch", None))
        else:
            reqs.append((f"PREDICT emb USING TASK sent FROM reviews "
                         f"WHERE len > {20 + (i % 8)}",
                         "best_effort", None))
    return reqs


def _rows_of(sess, sql: str) -> int:
    thr = int(sql.rsplit(">", 1)[1])
    return int((sess.tables["reviews"]["len"] > thr).sum())


def _policy(rows_per_be_request: int) -> AdmissionPolicy:
    # best-effort may hold ~1.5 bulk requests of queued rows and batch
    # ~1.7, together below the total cap: interactive always has
    # admission headroom, so under overload best-effort is the class
    # that sheds (typed Rejected) while interactive keeps its SLO
    return AdmissionPolicy(
        max_queue_rows=rows_per_be_request * 4,
        per_priority_rows={
            "best_effort": int(rows_per_be_request * 1.5),
            "batch": int(rows_per_be_request * 1.7),
        },
        mode="reject", retry_limit=1, retry_backoff_s=0.005,
        breaker_threshold=50, min_batch_rows=64)


# -- legs -------------------------------------------------------------------

def leg_sustainable(server, reqs, concurrency):
    """Closed loop: measures what the server can actually sustain.
    Clients honor backpressure — a Rejected submit backs off and
    retries, as a well-behaved closed-loop client would."""
    def one(r):
        sql, prio, dl = r
        while True:
            try:
                return server.predict(sql, timeout=60.0, priority=prio,
                                      deadline_ms=dl)
            except Rejected:
                time.sleep(0.005)

    with ThreadPoolExecutor(concurrency) as pool:
        list(pool.map(one, reqs[:concurrency]))          # warm
        server.reset_telemetry()
        t0 = time.perf_counter()
        list(pool.map(one, reqs))
        wall = time.perf_counter() - t0
    st = server.stats()
    return wall, st


def leg_overload(server, reqs, offered_rps: float, concurrency):
    """Open loop at ``offered_rps``: a pacer thread submits on schedule
    regardless of completions (rejections don't slow the offered load);
    a collector pool blocks on results."""
    outcomes = {"ok": [], "rejected": [], "failed": []}
    lock = threading.Lock()
    rows_ok = 0
    interval = 1.0 / max(offered_rps, 1e-6)

    def collect(rid, r):
        nonlocal rows_ok
        sql, prio, _ = r
        try:
            out = server.result(rid, timeout=120.0)
            with lock:
                outcomes["ok"].append((prio, sql))
                rows_ok += out.rows
        except RequestError:
            with lock:
                outcomes["failed"].append((prio, sql))

    t0 = time.perf_counter()
    with ThreadPoolExecutor(concurrency) as pool:
        for i, r in enumerate(reqs):
            target = t0 + i * interval
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            sql, prio, dl = r
            try:
                rid = server.submit(sql, priority=prio, deadline_ms=dl)
            except Rejected:
                with lock:
                    outcomes["rejected"].append((prio, sql))
                continue
            pool.submit(collect, rid, r)
    wall = time.perf_counter() - t0
    st = server.stats()
    return wall, rows_ok, outcomes, st


def leg_chaos(server, sess, reqs, ref, error_rate: float, concurrency):
    """Closed loop with a FaultInjector killing batches. Returns
    (ok, failed, injector). Scripted kills on trunk calls 1 and 2
    guarantee at least one batch exhausts its retry (the lane serializes
    batches, so the call-1 batch retries *as* call 2) on top of the
    probabilistic error_rate."""
    fi = FaultInjector(error_rate=error_rate, scripted_errors={1, 2},
                       seed=11)
    sess.backends.set_fault_injector(fi)
    ok, failed = [], []
    lock = threading.Lock()

    def one(r):
        sql, prio, dl = r
        try:
            while True:
                try:
                    out = server.predict(sql, timeout=60.0,
                                         priority=prio, deadline_ms=dl)
                    break
                except Rejected:
                    time.sleep(0.005)    # closed loop: honor backpressure
            with lock:
                ok.append((sql, out))
        except RequestError as e:
            assert isinstance(e.__cause__, InjectedFault), (
                f"chaos leg saw a non-injected failure: {e.__cause__!r}")
            with lock:
                failed.append(sql)

    with ThreadPoolExecutor(concurrency) as pool:
        list(pool.map(one, reqs))
    sess.backends.set_fault_injector(None)
    # parity: every surviving request equals the fault-free answer
    for sql, out in ok:
        np.testing.assert_allclose(out.scores, ref[sql], atol=1e-5)
    # no restart: the SAME server object still serves
    post = server.predict(reqs[0][0], timeout=60.0)
    np.testing.assert_allclose(post.scores, ref[reqs[0][0]], atol=1e-5)
    return ok, failed, fi


# -- driver -----------------------------------------------------------------

def run(n_rows: int = N_ROWS, n_calibrate: int = N_CALIBRATE,
        n_overload: int = N_OVERLOAD, n_chaos: int = N_CHAOS,
        concurrency: int = CONCURRENCY,
        json_path: str = "BENCH_overload.json") -> dict:
    zoo, table, sample = _setup(n_rows)

    # -- leg 0: unloaded interactive latency defines the SLO bound ------
    sess = _make_session(zoo, table, sample)
    be_rows = _rows_of(sess, "x > 20")
    policy = _policy(be_rows)
    server = MorphingServer(session=sess, policy=policy, max_wait_s=0.002)
    server.start()
    ia_reqs = [r for r in _mixed_requests(32, None)
               if r[1] == "interactive"]
    for sql, prio, _ in ia_reqs:
        server.predict(sql, timeout=60.0, priority=prio)
    base_p95 = server.stats().p95_latency_s_by_priority.get(
        "interactive", 0.01)
    slo_ms = max(base_p95 * 1e3 * SLO_FACTOR, SLO_FLOOR_MS)
    emit_value("overload.interactive_slo_ms", slo_ms,
               f"{SLO_FACTOR:.0f}x unloaded p95 (floor {SLO_FLOOR_MS})")

    # -- leg 1: sustainable closed-loop rate ----------------------------
    cal_reqs = _mixed_requests(n_calibrate, slo_ms)
    server.reset_telemetry()
    wall_cal, st_cal = leg_sustainable(server, cal_reqs, concurrency)
    sustainable_rps = n_calibrate / wall_cal
    rows_cal = sum(_rows_of(sess, sql) for sql, _, _ in cal_reqs)
    emit_value("overload.sustainable_rows_per_s", rows_cal / wall_cal,
               f"{sustainable_rps:.1f} req/s closed loop")

    # -- leg 2: open loop at OVERLOAD_X the sustainable rate ------------
    ovl_reqs = _mixed_requests(n_overload, slo_ms)
    server.reset_telemetry()
    wall_ovl, rows_ok, outcomes, st_ovl = leg_overload(
        server, ovl_reqs, sustainable_rps * OVERLOAD_X, concurrency)
    n_by = {p: sum(1 for q, _ in outcomes["ok"] if q == p)
            for p in ("interactive", "batch", "best_effort")}
    rej_by = dict(st_ovl.rejected_by_priority)
    ia_p95_ms = st_ovl.p95_latency_s_by_priority.get(
        "interactive", 0.0) * 1e3
    emit_value("overload.served_rows_per_s", rows_ok / wall_ovl,
               f"{OVERLOAD_X:.0f}x offered load")
    emit_value("overload.interactive_p95_ms", ia_p95_ms,
               f"SLO {slo_ms:.0f}ms")
    emit_value("overload.best_effort_rejected",
               rej_by.get("best_effort", 0),
               f"{len(outcomes['rejected'])} total rejections")
    emit_value("overload.deadline_misses", st_ovl.deadline_misses,
               f"{st_ovl.deadlines_admitted} admitted with deadlines")
    emit_value("overload.budget_shrinks", st_ovl.budget_shrinks,
               "dynamic Eq.11 shrink events")
    server.stop()

    if n_overload >= MIN_REQUESTS_FOR_ASSERT:
        # graceful degradation contract, asserted in-bench:
        assert ia_p95_ms <= slo_ms, (
            f"interactive p95 {ia_p95_ms:.1f}ms blew the "
            f"{slo_ms:.0f}ms SLO under {OVERLOAD_X:.0f}x overload")
        assert rej_by.get("best_effort", 0) > 0, (
            "2x overload must shed best-effort traffic via Rejected "
            f"backpressure (rejections by class: {rej_by})")
        assert rej_by.get("interactive", 0) == 0, (
            f"interactive traffic must not shed: {rej_by}")

    # -- leg 3: chaos — injected batch kills, parity on survivors -------
    sess_c = _make_session(zoo, table, sample)
    chaos_reqs = _mixed_requests(n_chaos, slo_ms)
    ref = {sql: sess_c.sql(sql).rows["_score"]
           for sql, _, _ in chaos_reqs}         # fault-free answers
    srv_c = MorphingServer(session=sess_c, policy=_policy(be_rows),
                           max_wait_s=0.002)
    with srv_c:
        srv_c.predict(chaos_reqs[0][0], timeout=60.0)     # warm/stage
        ok, failed, fi = leg_chaos(srv_c, sess_c, chaos_reqs, ref,
                                   CHAOS_ERROR_RATE, concurrency)
        st_chaos = srv_c.stats()
    kill_rate = fi.injected_errors / max(fi.calls, 1)
    emit_value("chaos.injected_batch_kill_rate", kill_rate,
               f"{fi.injected_errors}/{fi.calls} trunk batches")
    emit_value("chaos.failed_requests", len(failed),
               f"{len(ok)} survivors, parity checked")
    emit_value("chaos.retries", st_chaos.retries, "transient recoveries")
    assert len(ok) + len(failed) == n_chaos, "requests lost, not failed"
    assert fi.injected_errors > 0, (
        "chaos leg injected nothing — raise CHAOS_ERROR_RATE or n_chaos")
    # survivors' parity + post-chaos serve were asserted inside leg_chaos

    result = {
        "rows_table": n_rows, "concurrency": concurrency,
        "overload_x": OVERLOAD_X,
        "sustainable": {
            "requests": n_calibrate, "wall_s": wall_cal,
            "rows_per_s": rows_cal / wall_cal,
            "requests_per_s": sustainable_rps,
        },
        "overload": {
            "requests": n_overload,
            "interactive_slo_ms": slo_ms,
            "served_rows_per_s": rows_ok / wall_ovl,
            "interactive": {
                "p95_latency_ms": ia_p95_ms,
                "completed": n_by["interactive"],
                "rejected": rej_by.get("interactive", 0),
            },
            "batch": {"completed": n_by["batch"],
                      "rejected": rej_by.get("batch", 0)},
            "best_effort": {"completed": n_by["best_effort"],
                            "rejected": rej_by.get("best_effort", 0)},
            "failed": len(outcomes["failed"]),
            "deadline_misses": st_ovl.deadline_misses,
            "deadlines_admitted": st_ovl.deadlines_admitted,
            "budget_shrinks": st_ovl.budget_shrinks,
            "budget_grows": st_ovl.budget_grows,
        },
        "chaos": {
            "requests": n_chaos,
            "error_rate": CHAOS_ERROR_RATE,
            "injected_batch_kill_rate": kill_rate,
            "injected_errors": int(fi.injected_errors),
            "trunk_calls": int(fi.calls),
            "failed_requests": len(failed),
            "ok_requests": len(ok),
            "retries": st_chaos.retries,
            "failed_batches": st_chaos.failed_batches,
            "breaker_trips": st_chaos.breaker_trips,
        },
    }
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2,
                                              sort_keys=True))
        print(f"# wrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=N_ROWS)
    ap.add_argument("--requests", type=int, default=N_OVERLOAD,
                    help="open-loop overload request count")
    ap.add_argument("--concurrency", type=int, default=CONCURRENCY)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run (keeps the chaos parity asserts; "
                         "skips the percentile asserts)")
    ap.add_argument("--json", default="BENCH_overload.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.smoke:
        run(n_rows=600, n_calibrate=16, n_overload=32, n_chaos=16,
            concurrency=4, json_path=args.json)
    else:
        run(n_rows=args.rows, n_overload=args.requests,
            concurrency=args.concurrency, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
