"""Paper Fig. 10: model selection vs AutoML-style exhaustive evaluation —
accuracy (regret), selection time, memory proxy; plus random baseline and
the k/anchor ablations.
"""
from __future__ import annotations

import time
import tracemalloc

import numpy as np

from benchmarks.common import emit, emit_value, timeit
from repro.core import (ModelSelector, TaskFeaturizer, build_tasks,
                        build_zoo, linear_probe_accuracy, selection_regret,
                        transfer_matrix)
from repro.core.zoo import Task


def run() -> None:
    zoo = build_zoo(24, seed=0)
    hist = build_tasks(48, seed=1)
    t0 = time.time()
    V = transfer_matrix(zoo, hist)
    emit("selection.offline_matrix_48x24", time.time() - t0,
         "historical transfer evals (offline, one-time)")

    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in hist])
    sel = ModelSelector(k=6, n_anchors=4).fit_offline(V, feats, zoo=zoo)
    emit("selection.offline_fit", sel.offline_seconds,
         f"nmf_recon_err={sel.recon_error:.4f}")

    targets = build_tasks(24, seed=99)
    Vt = transfer_matrix(zoo, targets)

    # MorphingDB-style online selection
    regs, ranks, times = [], [], []
    for j, t in enumerate(targets):
        r = selection_regret(sel, Vt[:, j], t.X, t.y)
        regs.append(r["regret"])
        ranks.append(r["rank"])
        times.append(r["online_ms"] / 1e3)
    emit("selection.online_per_task", float(np.mean(times)),
         f"regret={np.mean(regs):.4f} median_rank={np.median(ranks):.0f}/24")

    # exhaustive (AutoML-style evaluate-every-model) baseline
    def exhaustive(t: Task):
        accs = [linear_probe_accuracy(m, t) for m in zoo]
        return int(np.argmax(accs))

    t_ex = timeit(lambda: [exhaustive(t) for t in targets[:6]]) / 6
    ex_regret = float(np.mean(
        [Vt[:, j].max() - Vt[exhaustive(t), j]
         for j, t in enumerate(targets[:6])]))
    emit("selection.exhaustive_per_task", t_ex,
         f"regret={ex_regret:.4f} (oracle-ish, pays full eval)")
    emit_value("selection.speedup_vs_exhaustive",
               t_ex / max(np.mean(times), 1e-9), "x faster online")

    # random baseline
    rng = np.random.default_rng(7)
    rand_regret = float(np.mean(
        [Vt[:, j].max() - Vt[rng.integers(len(zoo)), j]
         for j in range(len(targets))]))
    emit_value("selection.regret_ours", float(np.mean(regs)), "")
    emit_value("selection.regret_random", rand_regret, "")

    # memory proxy (paper Fig 10 resource axis)
    tracemalloc.start()
    sel2 = ModelSelector(k=6, n_anchors=4).fit_offline(V, feats, zoo=zoo)
    sel2.select(targets[0].X, targets[0].y)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    emit_value("selection.peak_mem_mb", peak / 1e6, "offline+online fit")

    # ablation: subspace rank k
    for k in (2, 6, 12):
        s = ModelSelector(k=k, n_anchors=4, nmf_iters=300).fit_offline(
            V, feats, zoo=zoo)
        rr = float(np.mean([selection_regret(s, Vt[:, j], t.X, t.y)["regret"]
                            for j, t in enumerate(targets)]))
        emit_value(f"selection.ablation_k{k}", rr, "regret")
