"""Paper Fig. 13: multi-modal query with device-aware placement +
vector-sharing ablation (in-DB shared embeddings vs per-query embedding).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, emit_value, timeit
from repro.pipeline import (Dag, Node, OpProfile, PipelineExecutor,
                            VectorShareCache, filter_op, join, place_dag,
                            simd_normalize_embed)


def run() -> None:
    rng = np.random.default_rng(0)
    n_img, n_txt = 3000, 3000
    products = {"pid": np.arange(n_img),
                "img": rng.standard_normal((n_img, 768)).astype(np.float32)}
    reviews = {"pid": rng.integers(0, n_img, n_txt),
               "txt": rng.standard_normal((n_txt, 256)).astype(np.float32)}
    Wi = rng.standard_normal((768, 64)).astype(np.float32) * 0.05
    Wt = rng.standard_normal((256, 64)).astype(np.float32) * 0.05

    cache = VectorShareCache()

    def build(shared: bool):
        def img_embed(b):
            out = dict(b)
            if shared:
                out["iemb"] = cache.get_or_embed(
                    "products", "img", b["img"],
                    lambda X: simd_normalize_embed(X, Wi))
            else:
                out["iemb"] = simd_normalize_embed(b["img"], Wi)
            return out

        def txt_embed(b):
            out = dict(b)
            if shared:
                out["temb"] = cache.get_or_embed(
                    "reviews", "txt", b["txt"],
                    lambda X: simd_normalize_embed(X, Wt))
            else:
                out["temb"] = simd_normalize_embed(b["txt"], Wt)
            return out

        def fuse(l, r):
            j = join(l, r, "pid")
            j["score"] = (j["iemb"][:, :64] * j["temb"][:, :64]).sum(1)
            return j

        d = Dag()
        d.add(Node("products", "scan"))
        d.add(Node("reviews", "scan"))
        d.add(Node("ie", "embed", fn=img_embed, cost_hint=8),
              deps=("products",))
        d.add(Node("te", "embed", fn=txt_embed, cost_hint=4),
              deps=("reviews",))
        d.add(Node("fuse", "join", fn=fuse, cost_hint=2,
                   meta={"arg_order": {"ie": 0, "te": 1}}),
              deps=("ie", "te"))
        return d

    # Fig 13a: heavy image model vs lightweight text model — the cost model
    # should split them across devices (paper: GPU image / CPU text).
    placement = place_dag(build(False), {
        "ie": OpProfile(flops_per_row=2 * 600e6, bytes_per_row=768 * 4,
                        model_bytes=25e6 * 4),
        "te": OpProfile(flops_per_row=2 * 256 * 3, bytes_per_row=256 * 4,
                        model_bytes=256 * 3 * 4)}, nrows_hint=3000)
    hetero = placement["ie"] != placement["te"]
    emit_value("sharing.heterogeneous_placement", 1.0 if hetero else 0.0,
               f"img->{placement['ie']} txt->{placement['te']} (Fig 13a)")

    def per_query():
        e = PipelineExecutor(build(False), workers=4)
        for _ in range(4):
            e.execute({"products": products, "reviews": reviews})

    def shared():
        e = PipelineExecutor(build(True), workers=4)
        for _ in range(4):
            e.execute({"products": products, "reviews": reviews})

    t_naive = timeit(per_query, repeats=2)
    t_shared = timeit(shared, repeats=2)
    emit("sharing.4queries_per_query_embed", t_naive)
    emit("sharing.4queries_shared", t_shared,
         f"hit_rate={cache.hit_rate:.2f}")
    emit_value("sharing.speedup", t_naive / t_shared, "x (Fig 13b)")
