"""Paper Figs. 11-12: cost-model device placement across heterogeneous
task types and data skew — the model's pick vs the measured optimum.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit_value
from repro.pipeline import OpProfile, choose_device, op_cost

# representative operator profiles (series MLP / text encoder / image CNN)
PROFILES = {
    "series_mlp": OpProfile(flops_per_row=2 * 90 * 256, bytes_per_row=360,
                            model_bytes=4 * 90 * 256),
    "text_encoder": OpProfile(flops_per_row=2 * 12e6, bytes_per_row=512,
                              model_bytes=12e6 * 4),
    "image_cnn": OpProfile(flops_per_row=2 * 600e6, bytes_per_row=12288,
                           model_bytes=25e6 * 4),
    "remote_llm": OpProfile(flops_per_row=2 * 7e9, bytes_per_row=2048,
                            model_bytes=7e9 * 2, api_latency_s=0.08),
}


def run() -> None:
    # Fig 11: heterogeneous tasks — expected placements
    for rows in (64, 4096):
        for name, prof in PROFILES.items():
            dev = choose_device(prof, rows)
            costs = {d: op_cost(prof, rows, d) for d in ("host", "tpu")}
            if prof.api_latency_s:
                costs["api"] = op_cost(prof, rows, "api")
            best = min(costs, key=costs.get)
            emit_value(f"placement.{name}.rows{rows}",
                       1.0 if dev == best else 0.0,
                       f"picked={dev} optimal={best}")
    # the paper's qualitative claims
    assert choose_device(PROFILES["series_mlp"], 64) == "host", \
        "light series ops belong on CPU (Fig 11a)"
    assert choose_device(PROFILES["image_cnn"], 4096) == "tpu", \
        "image models belong on the accelerator (Fig 11c)"

    # Fig 12: data skew — selectivity changes effective rows
    total = 100_000
    for skew in (0.9, 0.7, 0.5):
        rows = int(total * skew)
        dev = choose_device(PROFILES["text_encoder"], rows)
        cost = op_cost(PROFILES["text_encoder"], rows, dev)
        alt = "host" if dev == "tpu" else "tpu"
        alt_cost = op_cost(PROFILES["text_encoder"], rows, alt)
        emit_value(f"placement.skew{int(skew * 100)}",
                   alt_cost / cost,
                   f"{dev} chosen; {alt} would be this x slower")
