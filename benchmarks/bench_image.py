"""Paper Fig. 8 (image tasks): pre-embedded in-DB vectors vs raw-image
pipeline (decode+normalize+embed per query), CIFAR-style 3x32x32.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, emit_value, timeit
from repro.pipeline import VectorShareCache, run_batched, simd_normalize_embed


def _images(n: int = 2000, seed: int = 0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, (n, 3 * 32 * 32)).astype(np.uint8)


def run() -> None:
    imgs = _images()
    rng = np.random.default_rng(1)
    W = rng.standard_normal((3 * 32 * 32, 64)).astype(np.float32) * 0.02
    Wh = rng.standard_normal((64, 10)).astype(np.float32) * 0.1
    head = lambda f: f @ Wh

    def embed(x):  # normalize (the paper's SIMD step) + project
        return simd_normalize_embed(x.astype(np.float32), W,
                                    mean=127.5, scale=1 / 127.5)

    def raw_pipeline():
        feats = embed(imgs)            # re-embeds per query
        run_batched(list(feats), head, batch_size=16, convert_workers=1)

    cache = VectorShareCache()

    def preembedded():
        feats = cache.get_or_embed("cifar", "img", imgs, embed)
        run_batched(list(feats), head, batch_size=16, convert_workers=1)

    t_raw = timeit(lambda: [raw_pipeline() for _ in range(3)])
    t_pre = timeit(lambda: [preembedded() for _ in range(3)])
    emit("image.3queries_raw", t_raw)
    emit("image.3queries_preembedded", t_pre)
    emit_value("image.preembed_speedup", t_raw / t_pre,
               "x (paper reports >70% reduction)")
