"""Dispatch tier: front-door routing to worker processes vs worker count.

One ``DispatchServer`` front door, same concurrent ``PREDICT`` workload,
two tiers: ``workers=1`` (every lease lands on one process) and
``workers=4`` (the trunk prestaged on all four, coalesced batches
row-balanced across them). Workers run the numpy backend — real
multi-core parallelism with no per-process jax import — and the share
cache is disabled so the timed window measures trunk compute plus the
process-boundary transport, not cache hits. "Warm" means post-placement:
the warmup pass stages the trunk and visits every statement once.

A failover leg runs 2 workers, slows one down, hard-kills it mid-stream
(``Process.terminate``), and requires the survivor to complete the full
request set with fault-free parity — the re-dispatch and duplicate
counters land in the JSON.

Run directly for machine-readable output::

    PYTHONPATH=src:. python benchmarks/bench_dispatch.py \
        --json BENCH_dispatch.json

The >=1.5x speedup target is asserted only where it is physically
meaningful: ``os.cpu_count() >= 4`` (four worker processes on one core
time-slice a single ALU). ``speedup_asserted`` in the JSON records
whether the gate was armed.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np

from benchmarks.common import emit_value
from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import DispatchServer, MorphingSession, PlacementPolicy

N_ROWS = 1500
N_REQUESTS = 16
CONCURRENCY = 8
DIM = 32
# wide trunk: worker compute must dominate the queue transport
TRUNK_WIDTH = 256
WORKER_COUNTS = (1, 4)
TARGET_SPEEDUP = 1.5
MIN_WORKERS_FOR_ASSERT = 4
REPEATS = 3
N_FAILOVER = 10


def _setup(n_rows: int, dim: int = DIM):
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=160, dim=dim, classes=3)
    zoo = [pretrain_model(src, width=TRUNK_WIDTH, seed=1,
                          name="dispatch-m0")]
    rng = np.random.default_rng(0)
    table = {"len": rng.integers(1, 200, n_rows),
             "emb": rng.standard_normal((n_rows, dim)).astype(np.float32)}
    sample = make_task(rng, "gauss", n=128, dim=dim, classes=3)
    return zoo, table, sample


def _make_server(zoo, table, sample, workers: int) -> DispatchServer:
    # numpy front + workers: the front door never runs trunk compute,
    # and share is off so leases measure real worker forwards
    sess = MorphingSession(zoo=zoo, model_store="decoupled",
                           backend="numpy", enable_share=False)
    sess.register_table("reviews", {k: v.copy() for k, v in table.items()})
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0   # single-model zoo: no selector
    sess.resolve_task("sent", sample.X, sample.y)
    return DispatchServer(session=sess, workers=workers,
                          worker_backend="numpy",
                          placement=PlacementPolicy(watermark_rows=1 << 20),
                          max_wait_s=0.002)


def _statements(n_requests: int):
    # varied predicates: each request selects a different row window, as
    # concurrent clients would
    return [f"PREDICT emb USING TASK sent FROM reviews WHERE len > "
            f"{20 + (i % 16)}" for i in range(n_requests)]


def _rows_served(sess, stmts) -> int:
    lens = {s: int((sess.tables["reviews"]["len"]
                    > int(s.rsplit(">", 1)[1])).sum()) for s in set(stmts)}
    return sum(lens[s] for s in stmts)


def _bench(server: DispatchServer, stmts, concurrency: int):
    """Best-of-REPEATS wall over the statement set; the warmup pass
    places + stages the trunk on every worker and visits each statement
    once, and telemetry is re-based per repeat."""
    def one(stmt):
        return server.predict(stmt, timeout=120.0)

    server.prestage("sent")          # steady-state: all workers serve
    with ThreadPoolExecutor(concurrency) as pool:
        list(pool.map(one, stmts))               # warm
        best, p95s, outs = float("inf"), [], None
        for _ in range(REPEATS):
            server.reset_telemetry()
            t0 = time.perf_counter()
            got = list(pool.map(one, stmts))
            wall = time.perf_counter() - t0
            p95s.append(server.stats().p95_latency_s)
            if wall < best:
                best, outs = wall, got
    return best, outs, float(np.median(p95s))


def _failover_leg(zoo, table, sample, n_requests: int) -> dict:
    """2 workers, victim slowed then hard-killed mid-stream: the full
    request set must complete on the survivor with fault-free parity."""
    server = _make_server(zoo, table, sample, workers=2)
    sess = server.session
    thrs = [10 + 7 * i for i in range(n_requests)]
    refs = {thr: np.asarray(sess.sql(
        "PREDICT emb USING TASK sent FROM reviews "
        f"WHERE len > {thr}").rows["_score"]) for thr in thrs}
    with server:
        warm = server.predict("PREDICT emb USING TASK sent FROM reviews "
                              "WHERE len > 190", timeout=120.0)
        assert warm.rows >= 0
        st0 = server.stats()
        victim = [w for w, b in st0.staged_bytes_by_worker.items()
                  if b > 0][0]
        server.inject_fault(victim, {"slow_rate": 1.0, "slow_s": 0.4})
        ids = {thr: server.submit("PREDICT emb USING TASK sent FROM "
                                  f"reviews WHERE len > {thr}")
               for thr in thrs}
        time.sleep(0.3)              # leases in flight on the victim
        server.kill_worker(victim)
        completed = 0
        for thr, rid in ids.items():
            out = server.result(rid, timeout=120.0)
            np.testing.assert_allclose(out.scores, refs[thr], atol=1e-5)
            completed += 1
        st = server.stats()
    assert completed == n_requests, "failover must complete the full set"
    assert st.worker_deaths == 1 and st.redispatches >= 1
    emit_value("dispatch.failover_redispatches", st.redispatches,
               f"completed={completed}/{n_requests} "
               f"dup_dropped={st.duplicates_dropped}")
    return {
        "requests": n_requests,
        "completed": completed,
        "worker_deaths": st.worker_deaths,
        "redispatches": st.redispatches,
        "duplicates_dropped": st.duplicates_dropped,
        "survivor_parity": True,
    }


def run(n_rows: int = N_ROWS, n_requests: int = N_REQUESTS,
        concurrency: int = CONCURRENCY,
        worker_counts=WORKER_COUNTS,
        n_failover: int = N_FAILOVER,
        json_path: str = "BENCH_dispatch.json") -> dict:
    zoo, table, sample = _setup(n_rows)
    stmts = _statements(n_requests)
    cpus = os.cpu_count() or 1

    per_workers = {}
    outs_by_workers = {}
    for workers in worker_counts:
        server = _make_server(zoo, table, sample, workers)
        rows_total = _rows_served(server.session, stmts)
        with server:
            wall, outs, p95 = _bench(server, stmts, concurrency)
            st = server.stats()
        per_workers[workers] = {
            "workers": workers,
            "wall_s": wall,
            "rows_per_s_warm": rows_total / wall,
            "p95_latency_ms": p95 * 1e3,
            "leases": st.leases,
            "worker_deaths": st.worker_deaths,
        }
        outs_by_workers[workers] = outs
        emit_value(f"dispatch.workers{workers}_rows_per_s",
                   rows_total / wall, f"leases={st.leases}")
        emit_value(f"dispatch.workers{workers}_p95_latency_ms", p95 * 1e3,
                   "post-warmup window")

    # answers are worker-count invariant (pool.map keeps order)
    lo, hi = min(worker_counts), max(worker_counts)
    for a, b in zip(outs_by_workers[lo], outs_by_workers[hi]):
        np.testing.assert_allclose(a.scores, b.scores, atol=1e-5)

    speedup = (per_workers[hi]["rows_per_s_warm"]
               / per_workers[lo]["rows_per_s_warm"])
    asserted = (cpus >= MIN_WORKERS_FOR_ASSERT
                and hi >= MIN_WORKERS_FOR_ASSERT)
    emit_value("dispatch.speedup_multi_vs_single", speedup,
               f"x warm {hi}w vs {lo}w, asserted={asserted} (cpus={cpus})")

    failover = _failover_leg(zoo, table, sample, n_failover)

    result = {
        "rows_table": n_rows, "requests": n_requests,
        "concurrency": concurrency, "trunk_width": TRUNK_WIDTH,
        "host_cpu_count": cpus,
        **{f"workers_{w}": per_workers[w] for w in worker_counts},
        "speedup_multi_vs_single": speedup,
        "target_speedup": TARGET_SPEEDUP,
        "speedup_asserted": asserted,
        "failover": failover,
    }
    if asserted:
        assert speedup >= TARGET_SPEEDUP, (
            f"dispatch tier {speedup:.2f}x < {TARGET_SPEEDUP}x target at "
            f"{hi} workers vs {lo} ({cpus} cpus)")
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2,
                                              sort_keys=True))
        print(f"# wrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=N_ROWS)
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--concurrency", type=int, default=CONCURRENCY)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run (2-worker tier, keeps the "
                         "failover parity asserts)")
    ap.add_argument("--json", default="BENCH_dispatch.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    if args.smoke:
        run(n_rows=600, n_requests=8, concurrency=4,
            worker_counts=(1, 2), n_failover=6, json_path=args.json)
    else:
        run(n_rows=args.rows, n_requests=args.requests,
            concurrency=args.concurrency, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
