"""Storage tier: compressed delta fleet, tensor-page dedup, and the
paper's Fig. 9 BLOB / decoupled / API comparison.

The headline leg stores a K=16 fine-tune fleet (one shared trunk, each
variant perturbing ~10% of every layer's entries) twice: once with raw
dense deltas and once with ``compress_deltas=True`` +
``dedup_pages=True``. The compressed store must hold the fleet in
<= 1/2 the bytes (``TARGET_REDUCTION``), and a cold resolve of every
variant — fresh ``Catalog`` + ``DecoupledStore`` per repeat, so the
layer-tensor cache starts empty — must reproduce the uncompressed
answers within the per-layer quantization bound the catalog declares.
``cold_resolve_p95_latency_ms`` is the gated tail metric: decompression
must not turn the byte saving into a latency regression.

A dedup leg saves four byte-identical trunks under distinct model ids
into one page store and checks the content-hashed pages collapse them
to ~one copy. The Fig. 9 leg keeps the original storage-format
comparison (all-in-one BLOB vs layer tables vs latency-bound API).

Run directly for machine-readable output::

    PYTHONPATH=src:. python benchmarks/bench_storage.py \
        --json BENCH_storage.json
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit_value, timeit
from repro.storage import (ApiModelRegistry, BlobStore, Catalog,
                           DecoupledStore)

K_FLEET = 16
N_LAYERS = 6
DIM = 128
TOUCH_FRAC = 0.10          # fraction of each layer a fine-tune perturbs
N_DUP_TRUNKS = 4
REPEATS = 3
TARGET_REDUCTION = 2.0     # x fewer stored bytes, compressed fleet
DEDUP_TARGET = 2.0         # x fewer stored bytes, duplicate trunks


def _trunk_params(layers: int, d: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {f"layer_{i:02d}": {
        "w": rng.standard_normal((d, d)).astype(np.float32),
        "b": rng.standard_normal(d).astype(np.float32)}
        for i in range(layers)}


def _finetune(trunk, frac: float, seed: int):
    """Perturb ``frac`` of every layer's weight entries (sparse additive
    update, the regime where the delta encodings win)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, sub in trunk.items():
        w = sub["w"].copy()
        idx = rng.choice(w.size, size=max(1, int(w.size * frac)),
                         replace=False)
        w.ravel()[idx] += (0.01 * rng.standard_normal(idx.size)
                           .astype(np.float32))
        out[name] = {"w": w, "b": sub["b"]}
    return out


def _save_fleet(root: Path, trunk, fts, **store_kw) -> DecoupledStore:
    ds = DecoupledStore(root / "store", Catalog(root / "cat"), **store_kw)
    ds.save("trunk", {"arch": "mlp"}, trunk)
    for i, ft in enumerate(fts):
        ds.save(f"ft{i:02d}", {"arch": "mlp"}, ft, base_model="trunk")
    return ds


def _cold_reader(root: Path) -> DecoupledStore:
    """Fresh catalog + store over the existing directory: empty layer
    cache, so every load pays the full disk resolve."""
    return DecoupledStore(root / "store", Catalog(root / "cat"))


def _cold_resolve_ms(root: Path, model_ids, repeats: int):
    """Per-model cold-load walls; a fresh store per repeat."""
    samples = []
    for _ in range(repeats):
        ds = _cold_reader(root)
        for mid in model_ids:
            t0 = time.perf_counter()
            ds.load(mid)
            samples.append((time.perf_counter() - t0) * 1e3)
    return samples


def _fleet_leg(td: Path, k: int, layers: int, dim: int,
               repeats: int) -> dict:
    trunk = _trunk_params(layers, dim)
    fts = [_finetune(trunk, TOUCH_FRAC, seed=100 + i) for i in range(k)]
    fleet_ids = [f"ft{i:02d}" for i in range(k)]

    ds_u = _save_fleet(td / "raw", trunk, fts)
    ds_c = _save_fleet(td / "cmp", trunk, fts,
                       compress_deltas=True, dedup_pages=True)

    mb_u = ds_u.disk_footprint() / 1e6
    mb_c = ds_c.disk_footprint() / 1e6
    reduction = mb_u / mb_c
    emit_value("storage.fleet_uncompressed_mb", mb_u,
               f"trunk + {k} dense deltas")
    emit_value("storage.fleet_compressed_mb", mb_c,
               "quant/sparse deltas + paged trunk")
    emit_value("storage.fleet_reduction", reduction,
               f"x fewer stored bytes, target {TARGET_REDUCTION}x")
    assert reduction >= TARGET_REDUCTION, (
        f"compressed fleet {reduction:.2f}x < {TARGET_REDUCTION}x target")

    # parity: cold compressed reads match raw reads within the bound
    # each layer *declares* in the catalog (plus float-compose ulp slack)
    rd_u, rd_c = _cold_reader(td / "raw"), _cold_reader(td / "cmp")
    max_err = max_bound = 0.0
    for mid in fleet_ids:
        bound = max((li.bound for li in
                     rd_c.catalog.get_layers(mid)), default=0.0)
        _, flat_u = rd_u.load(mid)
        _, flat_c = rd_c.load(mid)
        for name, ref in flat_u.items():
            got = flat_c[name]
            slack = 4 * np.finfo(np.float32).eps * float(
                np.max(np.abs(ref)))
            err = float(np.max(np.abs(got.astype(np.float64)
                                      - ref.astype(np.float64))))
            assert err <= bound + slack + 1e-12, (
                f"{mid}:{name} err {err:.3e} > bound {bound:.3e}")
            max_err, max_bound = max(max_err, err), max(max_bound, bound)

    cold_u = _cold_resolve_ms(td / "raw", fleet_ids, repeats)
    cold_c = _cold_resolve_ms(td / "cmp", fleet_ids, repeats)
    p95 = lambda xs: float(np.percentile(xs, 95))
    emit_value("storage.cold_resolve_p95_latency_ms", p95(cold_c),
               f"compressed, {len(cold_c)} cold loads")
    emit_value("storage.uncompressed_cold_resolve_p95_latency_ms",
               p95(cold_u), f"{len(cold_u)} cold loads")

    st = ds_c.stats
    return {
        "k": k, "layers": layers, "dim": dim, "touch_frac": TOUCH_FRAC,
        "uncompressed_mb": mb_u, "compressed_mb": mb_c,
        "reduction_x": reduction, "target_reduction_x": TARGET_REDUCTION,
        "compressed_delta_mb": st.compressed_delta_bytes / 1e6,
        "dedup_pages": st.dedup_pages,
        "dedup_bytes_saved_mb": st.dedup_bytes_saved / 1e6,
        "parity_max_abs_err": max_err,
        "parity_declared_bound": max_bound,
        "cold_resolve": {
            "compressed": {
                "cold_resolve_p95_latency_ms": p95(cold_c),
                "mean_ms": float(np.mean(cold_c))},
            "uncompressed": {
                "cold_resolve_p95_latency_ms": p95(cold_u),
                "mean_ms": float(np.mean(cold_u))},
        },
    }


def _dedup_leg(td: Path, layers: int, dim: int) -> dict:
    """N byte-identical trunks under distinct ids: content-hashed pages
    must collapse them to ~one stored copy."""
    trunk = _trunk_params(layers, dim, seed=7)
    ds = DecoupledStore(td / "dup" / "store", Catalog(td / "dup" / "cat"),
                        dedup_pages=True)
    for i in range(N_DUP_TRUNKS):
        ds.save(f"twin{i}", {"arch": "mlp"}, trunk)
    ds_raw = DecoupledStore(td / "dupraw" / "store",
                            Catalog(td / "dupraw" / "cat"))
    for i in range(N_DUP_TRUNKS):
        ds_raw.save(f"twin{i}", {"arch": "mlp"}, trunk)

    mb_dup = ds.disk_footprint() / 1e6
    mb_raw = ds_raw.disk_footprint() / 1e6
    ratio = mb_raw / mb_dup
    emit_value("storage.dedup_reduction", ratio,
               f"{N_DUP_TRUNKS} identical trunks -> ~1 page set")
    assert ratio >= DEDUP_TARGET, (
        f"dedup {ratio:.2f}x < {DEDUP_TARGET}x for identical trunks")
    # parity + GC: pages survive a delete of one twin, vacuum stays a
    # no-op while references remain
    _, flat = _cold_reader(td / "dup").load("twin0")
    for name, sub in ((n, s) for n, s in trunk.items()):
        np.testing.assert_array_equal(flat[f"{name}/w"], sub["w"])
    ds.delete(f"twin{N_DUP_TRUNKS - 1}")
    removed, _ = ds.vacuum()
    assert removed == 0, "vacuum collected pages still referenced"
    _, flat2 = ds.load("twin0")
    np.testing.assert_array_equal(flat2["layer_00/w"],
                                  trunk["layer_00"]["w"])
    return {
        "models": N_DUP_TRUNKS,
        "dedup_mb": mb_dup, "raw_mb": mb_raw, "reduction_x": ratio,
        "dedup_pages": ds.stats.dedup_pages,
        "dedup_bytes_saved_mb": ds.stats.dedup_bytes_saved / 1e6,
        "vacuum_removed_after_delete": removed,
    }


def _fig9_leg(td: Path, layers: int, dim: int) -> dict:
    """Paper Fig. 9: storage / load / access for BLOB vs decoupled vs
    API-based model storage."""
    cat = Catalog(td / "f9cat")
    blob = BlobStore(td / "f9blob", cat)
    dec = DecoupledStore(td / "f9dec", cat)
    params = _trunk_params(layers, dim, seed=0)

    blob.save("m", {"arch": "mlp"}, params)
    dec.save("m-dec", {"arch": "mlp"}, params)
    ft = {k: dict(v) for k, v in params.items()}
    ft["layer_00"]["w"] = ft["layer_00"]["w"] + 1
    dec.save("m-ft", {"arch": "mlp"}, ft, base_model="m-dec")

    blob_mb = (td / "f9blob" / "m.blob").stat().st_size / 1e6
    dec_mb = dec.stored_bytes("m-dec") / 1e6
    ft_mb = dec.stored_bytes("m-ft") / 1e6
    emit_value("storage.blob_mb", blob_mb, "all-in-one")
    emit_value("storage.finetune_delta_mb", ft_mb,
               "1 layer changed (Fig 9a)")

    t_blob = timeit(lambda: blob.load("m", template=params))
    t_partial = timeit(lambda: dec.load(
        "m-ft", layer_filter=lambda n: n.startswith("layer_00")))

    api = ApiModelRegistry(cat)
    api.register("remote", lambda x: np.asarray(x) * 2, latency_s=0.03)
    rng = np.random.default_rng(0)
    t_api = timeit(lambda: api.invoke("remote", rng.standard_normal(4),
                                      rng), repeats=1, warmup=0)
    return {
        "blob_mb": blob_mb, "decoupled_mb": dec_mb,
        "finetune_delta_mb": ft_mb,
        "load_blob_us": t_blob * 1e6,
        "load_partial_1layer_us": t_partial * 1e6,
        "api_invoke_us": max(t_api, 0.03) * 1e6,
    }


def run(k: int = K_FLEET, layers: int = N_LAYERS, dim: int = DIM,
        repeats: int = REPEATS,
        json_path: str = "BENCH_storage.json") -> dict:
    with tempfile.TemporaryDirectory() as tds:
        td = Path(tds)
        fleet = _fleet_leg(td, k, layers, dim, repeats)
        dedup = _dedup_leg(td, layers, dim)
        fig9 = _fig9_leg(td, layers, dim)
    result = {"fleet": fleet, "dedup": dedup, "fig9": fig9}
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2,
                                              sort_keys=True))
        print(f"# wrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fleet", type=int, default=K_FLEET)
    ap.add_argument("--layers", type=int, default=N_LAYERS)
    ap.add_argument("--dim", type=int, default=DIM)
    ap.add_argument("--repeats", type=int, default=REPEATS)
    ap.add_argument("--json", default="BENCH_storage.json",
                    help="output path ('' disables the JSON artifact)")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI smoke")
    args = ap.parse_args(argv)
    if args.smoke:
        args.fleet, args.dim, args.repeats = 6, 48, 1
    run(k=args.fleet, layers=args.layers, dim=args.dim,
        repeats=args.repeats, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
