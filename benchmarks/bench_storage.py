"""Paper Fig. 9: storage usage / model load time / inference access for
BLOB vs decoupled vs API-based model storage.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, emit_value, timeit
from repro.storage import (ApiModelRegistry, BlobStore, Catalog,
                           DecoupledStore)


def _params(layers: int = 24, d: int = 512, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {f"layer_{i:02d}": {
        "w": rng.standard_normal((d, d)).astype(np.float32),
        "b": rng.standard_normal(d).astype(np.float32)}
        for i in range(layers)}


def run() -> None:
    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        cat = Catalog(td / "cat")
        blob = BlobStore(td / "blob", cat)
        dec = DecoupledStore(td / "dec", cat)
        params = _params()

        blob.save("m", {"arch": "mlp24"}, params)
        dec.save("m-dec", {"arch": "mlp24"}, params)
        # fine-tune touching 2 of 24 layers
        ft = {k: dict(v) for k, v in params.items()}
        ft["layer_00"]["w"] = ft["layer_00"]["w"] + 1
        ft["layer_12"]["w"] = ft["layer_12"]["w"] * 2
        dec.save("m-ft", {"arch": "mlp24"}, ft, base_model="m-dec")

        blob_bytes = (td / "blob" / "m.blob").stat().st_size
        dec_bytes = dec.stored_bytes("m-dec")
        ft_bytes = dec.stored_bytes("m-ft")
        emit_value("storage.blob_mb", blob_bytes / 1e6, "all-in-one")
        emit_value("storage.decoupled_mb", dec_bytes / 1e6, "layer tables")
        emit_value("storage.finetune_delta_mb", ft_bytes / 1e6,
                   "2/24 layers changed")
        emit_value("storage.delta_saving", dec_bytes / max(ft_bytes, 1),
                   "x less disk for the variant (Fig 9a)")

        t_blob = timeit(lambda: blob.load("m", template=params))
        t_dec = timeit(lambda: dec.load("m-ft", template=params))
        t_partial = timeit(lambda: dec.load(
            "m-ft", layer_filter=lambda n: n.startswith("layer_00")))
        emit("storage.load_blob", t_blob, "full deserialization (Fig 9b)")
        emit("storage.load_decoupled", t_dec)
        emit("storage.load_partial_1layer", t_partial,
             "partial loading (Fig 9b)")

        # API-based: negligible storage, latency-bound inference (Fig 9c)
        api = ApiModelRegistry(cat)
        api.register("remote", lambda x: np.asarray(x) * 2,
                     latency_s=0.03)
        rng = np.random.default_rng(0)
        t_api = timeit(lambda: api.invoke("remote", rng.standard_normal(4),
                                          rng), repeats=1, warmup=0)
        emit("storage.api_invoke", max(t_api, 0.03),
             "latency-bound (Fig 9c)")
