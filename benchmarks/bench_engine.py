"""Engine ablation: naive per-row inference vs the full task-centric
engine (pre-embedding share cache + window batching + chunked stage
overlap) on the same task-centric query over a >=5k-row table, plus the
execution-backend ablation (numpy host path vs jax-jitted path with
shape-bucketed compilation) that the backend registry makes switchable.

Run directly for machine-readable output::

    PYTHONPATH=src python benchmarks/bench_engine.py --backend both \
        --rows 6000 --json BENCH_engine.json

``BENCH_engine.json`` records rows/s per backend, the share hit rate,
compile/stage counts for the jitted path, and the jax-vs-numpy speedup so
the perf trajectory is tracked per PR.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, emit_value, timeit
from repro.core import (ModelSelector, TaskFeaturizer, build_tasks,
                        build_zoo, make_task, transfer_matrix)
from repro.engine import MorphingSession
from repro.pipeline.backend import JaxBackend
from repro.pipeline.operators import groupby_agg

N_ROWS = 6000
QUERY = ("SELECT gender, AVG(sent(emb)) FROM reviews "
         "WHERE len > 20 GROUP BY gender")
# below this the backend ablation is recorded but not asserted (compile
# and fixed overheads dominate tiny tables)
MIN_ROWS_FOR_SPEEDUP_ASSERT = 4000
# the jit win is a device claim: on TPU (native Pallas) the jitted path
# must beat the vectorized numpy host path; on CPU the linear-mode kernel
# runs in *interpret* mode, so parity (not speedup) is the honest gate
TARGET_SPEEDUP = 1.3
INTERPRET_SANITY_SPEEDUP = 0.7


def _setup(n_rows: int):
    zoo = build_zoo(16, seed=0)
    history = build_tasks(32, seed=1)
    V = transfer_matrix(zoo, history)
    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in history])
    sel = ModelSelector(k=6, n_anchors=3).fit_offline(V, feats, zoo=zoo)
    rng = np.random.default_rng(0)
    table = {"gender": rng.integers(0, 2, n_rows),
             "len": rng.integers(1, 200, n_rows),
             "emb": rng.standard_normal((n_rows, 16)).astype(np.float32)}
    sample = make_task(rng, "gauss", n=128, dim=16, classes=3)
    return sel, zoo, table, sample


def _make_session(sel, zoo, table, sample, *, backend="auto",
                  enable_share=True):
    sess = MorphingSession(selector=sel, zoo=zoo, backend=backend,
                           enable_share=enable_share)
    sess.register_table("reviews",
                        {k: v.copy() for k, v in table.items()})
    sess.sql("CREATE TASK sent (INPUT=Series, OUTPUT IN ('P','N'), "
             "TYPE='Classification')")
    model = sess.resolve_task("sent", sample.X, sample.y)
    return sess, model


def _bench_backend(sel, zoo, table, sample, backend: str, n_scored: int):
    """Steady-state rows/s of one execution backend with the share cache
    disabled, so the timed runs exercise the actual inference hot path
    (jit stays warm after the first run; weights staged at resolve)."""
    sess, _ = _make_session(sel, zoo, table, sample, backend=backend,
                            enable_share=False)
    t0 = time.perf_counter()
    cold = sess.sql(QUERY)                       # first run: compiles
    t_cold = time.perf_counter() - t0
    # best-of-5 with warmup: the warm wall is ~10ms at smoke sizes, so
    # scheduler jitter needs several samples to shake out (CI gates on
    # this number)
    t_warm = timeit(lambda: sess.sql(QUERY), repeats=5, warmup=1)
    rec = {"t_cold_s": t_cold, "t_warm_s": t_warm,
           "rows_per_s_cold": n_scored / t_cold,
           "rows_per_s_warm": n_scored / t_warm}
    jaxish = {id(b): b for b in sess.backends.values()
              if isinstance(b, JaxBackend)}
    if jaxish:
        rec["compile_count"] = sum(b.compile_count
                                   for b in jaxish.values())
        rec["stage_count"] = sum(b.stage_count for b in jaxish.values())
    return rec, cold.rows["mean__score"]


def run(n_rows: int = N_ROWS, backends=("numpy", "jax"),
        json_path: str = "BENCH_engine.json") -> dict:
    sel, zoo, table, sample = _setup(n_rows)
    n_scored = int((table["len"] > 20).sum())

    # -- naive: per-row model call, no sharing/batching/overlap ----------
    sess, model = _make_session(sel, zoo, table, sample, backend="numpy")

    def naive():
        mask = table["len"] > 20
        emb = table["emb"][mask]
        scores = np.empty(len(emb), np.float32)
        for i in range(len(emb)):
            scores[i] = model.head(model.features(emb[i:i + 1]))[0]
        return groupby_agg({"gender": table["gender"][mask],
                            "_score": scores}, "gender", "_score")

    # -- engine: shared pre-embedding + window batching + chunk overlap --
    def engine():
        return sess.sql(QUERY)

    ref = naive()
    t_naive = timeit(naive, repeats=2, warmup=0)

    def cold_once():
        """First-ever run on a fresh session: empty share cache."""
        s2, _ = _make_session(sel, zoo, table, sample, backend="numpy")
        t0 = time.perf_counter()
        s2.sql(QUERY)
        return time.perf_counter() - t0

    t_cold = min(cold_once() for _ in range(2))    # best-of-2: less noisy
    res = engine()                                 # cache now filled
    np.testing.assert_allclose(ref["mean__score"],
                               res.rows["mean__score"], rtol=1e-4)
    t_warm = timeit(engine, repeats=2, warmup=0)
    warm = engine()

    emit("engine.naive_per_row", t_naive,
         f"{n_scored / t_naive:.0f} rows/s")
    emit("engine.full_cold", t_cold, f"{n_scored / t_cold:.0f} rows/s")
    emit("engine.full_warm", t_warm,
         f"{n_scored / t_warm:.0f} rows/s "
         f"hit_rate={warm.report.share_hit_rate:.2f}")
    emit_value("engine.speedup_cold", t_naive / t_cold, "x vs per-row")
    emit_value("engine.speedup_warm", t_naive / t_warm, "x vs per-row")
    emit_value("engine.warm_share_hit_rate", warm.report.share_hit_rate,
               "second-run cache hits")
    # cold sits within measurement noise of the naive loop on a loaded
    # machine (share cache is empty; the engine's wins are warm) — gate
    # on "not materially slower" and keep the warm asserts strict
    assert t_naive / t_cold > 0.75, "cold engine materially slower than per-row"
    assert t_naive / t_warm > 1.0, "warm engine must beat per-row inference"
    assert warm.report.share_hit_rate > 0.0, "warm run must hit the cache"

    # -- share-cache fingerprinting: per-row hashing vs vectorized -------
    # the serving row tier fingerprints whole chunks in one numpy pass;
    # this micro-bench records the per-row hashlib overhead it removes
    from repro.pipeline.share import fingerprint, fingerprint_rows

    X_fp = table["emb"]
    t_row_hash = timeit(
        lambda: [fingerprint(X_fp[i:i + 1]) for i in range(len(X_fp))],
        repeats=2, warmup=1)
    t_vec_hash = timeit(lambda: fingerprint_rows(X_fp),
                        repeats=5, warmup=1)
    fp_speedup = t_row_hash / t_vec_hash
    emit("engine.fingerprint_per_row", t_row_hash,
         f"{t_row_hash / len(X_fp) * 1e6:.2f} us/row hashlib")
    emit("engine.fingerprint_vectorized", t_vec_hash,
         f"{t_vec_hash / len(X_fp) * 1e6:.3f} us/row one-pass")
    emit_value("engine.speedup_fingerprint_vectorized", fp_speedup,
               "x vs per-row hashing")
    if n_rows >= MIN_ROWS_FOR_SPEEDUP_ASSERT:
        assert fp_speedup > 5.0, (
            f"vectorized fingerprinting {fp_speedup:.1f}x <= 5x over "
            "per-row hashing — the serving hot path regressed to "
            "per-row Python cost")

    # -- backend ablation: numpy host path vs jax-jitted path ------------
    result = {"rows": n_rows, "scored_rows": n_scored,
              "query": QUERY,
              "naive_rows_per_s": n_scored / t_naive,
              "share_hit_rate_warm": warm.report.share_hit_rate,
              "share_fingerprint": {
                  "rows": len(X_fp),
                  "per_row_us_per_row": t_row_hash / len(X_fp) * 1e6,
                  "vectorized_us_per_row": t_vec_hash / len(X_fp) * 1e6,
                  "speedup_vectorized": fp_speedup},
              "backends": {}}
    parity = {}
    for backend in backends:
        rec, scores = _bench_backend(sel, zoo, table, sample, backend,
                                     n_scored)
        result["backends"][backend] = rec
        emit(f"engine.backend_{backend}_warm", rec["t_warm_s"],
             f"{rec['rows_per_s_warm']:.0f} rows/s")
        parity[backend] = scores
    if len(parity) > 1:
        vals = list(parity.values())
        for v in vals[1:]:
            np.testing.assert_allclose(vals[0], v, atol=1e-5)
    if "numpy" in result["backends"] and "jax" in result["backends"]:
        speedup = (result["backends"]["jax"]["rows_per_s_warm"]
                   / result["backends"]["numpy"]["rows_per_s_warm"])
        result["speedup_jax_vs_numpy"] = speedup
        emit_value("engine.speedup_jax_vs_numpy", speedup,
                   "warm rows/s ratio")
        if n_rows >= MIN_ROWS_FOR_SPEEDUP_ASSERT:
            import jax
            interpret = jax.default_backend() != "tpu"
            target = (INTERPRET_SANITY_SPEEDUP if interpret
                      else TARGET_SPEEDUP)
            assert speedup >= target, (
                f"jitted backend {speedup:.2f}x < {target}x target over "
                f"numpy on the warm {n_rows}-row workload "
                f"(interpret={interpret})")
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2,
                                              sort_keys=True))
        print(f"# wrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", choices=("numpy", "jax", "both"),
                    default="both",
                    help="execution backend(s) to ablate (default both)")
    ap.add_argument("--rows", type=int, default=N_ROWS)
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    # --backend jax still runs numpy as the comparison baseline (the
    # speedup target is defined against it)
    backends = (("numpy",) if args.backend == "numpy"
                else ("numpy", "jax"))
    print("name,us_per_call,derived")
    run(n_rows=args.rows, backends=backends, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
