"""Engine ablation: naive per-row inference vs the full task-centric
engine (pre-embedding share cache + window batching + chunked stage
overlap) on the same task-centric query over a >=5k-row table.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, emit_value, timeit
from repro.core import (ModelSelector, TaskFeaturizer, build_tasks,
                        build_zoo, make_task, transfer_matrix)
from repro.engine import MorphingSession
from repro.pipeline.operators import groupby_agg

N_ROWS = 6000
QUERY = ("SELECT gender, AVG(sent(emb)) FROM reviews "
         "WHERE len > 20 GROUP BY gender")


def run() -> None:
    zoo = build_zoo(16, seed=0)
    history = build_tasks(32, seed=1)
    V = transfer_matrix(zoo, history)
    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in history])
    sel = ModelSelector(k=6, n_anchors=3).fit_offline(V, feats, zoo=zoo)

    rng = np.random.default_rng(0)
    table = {"gender": rng.integers(0, 2, N_ROWS),
             "len": rng.integers(1, 200, N_ROWS),
             "emb": rng.standard_normal((N_ROWS, 16)).astype(np.float32)}

    sess = MorphingSession(selector=sel, zoo=zoo)
    sess.register_table("reviews", table)
    sess.sql("CREATE TASK sent (INPUT=Series, OUTPUT IN ('P','N'), "
             "TYPE='Classification')")
    sample = make_task(rng, "gauss", n=128, dim=16, classes=3)
    model = sess.resolve_task("sent", sample.X, sample.y)

    # -- naive: per-row model call, no sharing/batching/overlap ----------
    def naive():
        mask = table["len"] > 20
        emb = table["emb"][mask]
        scores = np.empty(len(emb), np.float32)
        for i in range(len(emb)):
            scores[i] = model.head(model.features(emb[i:i + 1]))[0]
        return groupby_agg({"gender": table["gender"][mask],
                            "_score": scores}, "gender", "_score")

    # -- engine: shared pre-embedding + window batching + chunk overlap --
    def engine():
        return sess.sql(QUERY)

    ref = naive()
    t_naive = timeit(naive, repeats=2, warmup=0)
    t_cold = timeit(engine, repeats=1, warmup=0)   # first-ever run: cold
    res = engine()                                 # cache now filled
    np.testing.assert_allclose(ref["mean__score"],
                               res.rows["mean__score"], rtol=1e-4)
    t_warm = timeit(engine, repeats=2, warmup=0)
    warm = engine()

    n_scored = int((table["len"] > 20).sum())
    emit("engine.naive_per_row", t_naive,
         f"{n_scored / t_naive:.0f} rows/s")
    emit("engine.full_cold", t_cold, f"{n_scored / t_cold:.0f} rows/s")
    emit("engine.full_warm", t_warm,
         f"{n_scored / t_warm:.0f} rows/s "
         f"hit_rate={warm.report.share_hit_rate:.2f}")
    emit_value("engine.speedup_cold", t_naive / t_cold, "x vs per-row")
    emit_value("engine.speedup_warm", t_naive / t_warm, "x vs per-row")
    emit_value("engine.warm_share_hit_rate", warm.report.share_hit_rate,
               "second-run cache hits")
    assert t_naive / t_cold > 1.0, "engine should beat per-row inference"
    assert warm.report.share_hit_rate > 0.0, "warm run must hit the cache"
