"""Semantic share cache ablation: ANN-indexed embedding reuse on a
near-duplicate serving workload vs the exact-only share cache, plus
``ORDER BY SIMILARITY(...) LIMIT k`` top-k latency against a brute-force
trunk scan.

The serving workload models recurring near-duplicate traffic (retries,
lightly edited rows, sensor jitter): every timed pass perturbs the base
table within the ANN tier's *calibrated* reuse radius, so the exact
tier's fingerprints never match while the ANN tier serves the rows
within its error bound. The exact-only server pays the trunk for every
pass; the ANN chain pays one IVF probe.

Run directly for machine-readable output::

    PYTHONPATH=src:. python benchmarks/bench_ann.py \
        --rows 2000 --passes 5 --json BENCH_ann.json

``BENCH_ann.json`` records warm rows/s for both cache configurations,
the measured recall and max embedding error on the timed traffic
(asserted against the configured bound), and warm top-k latency for the
lowered index scan vs a brute-force trunk-and-sort baseline (gated by
``scripts/check_bench.py``: rows/s floors, p95 ceilings).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit_value
from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import AnnConfig, EngineConfig, MorphingServer, \
    MorphingSession
from repro.engine.serve import _SHARE_TABLE

N_ROWS = 2000
N_PASSES = 5
DIM = 64
# radial (RBF-to-centers) trunk: per-row cost scales with centers x dim
# and doesn't collapse into one BLAS call — the inference cost class
# ANN reuse is built to remove (a single-matmul toy trunk is cheaper
# than any index probe and would make the ablation meaningless)
TRUNK_WIDTH = 256
K_TOP = 10
TOPK_CALLS = 30
# below this the speedup target is recorded but not asserted (fixed
# overheads dominate tiny tables)
MIN_ROWS_FOR_ASSERT = 1000
TARGET_ANN_SPEEDUP = 1.3
TARGET_RECALL = 0.95
ANN_CFG = AnnConfig(error_bound=0.1, audit_rate=0.02, nlist=32, nprobe=4)


def _setup(n_rows: int):
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=800, dim=DIM, classes=3)
    zoo = [pretrain_model(src, width=TRUNK_WIDTH, seed=1, name="ann-m0",
                          mode="radial")]
    rng = np.random.default_rng(0)
    base = rng.standard_normal((n_rows, DIM)).astype(np.float32)
    sample = make_task(rng, "gauss", n=128, dim=DIM, classes=3)
    return zoo, base, sample


def _make_session(zoo, sample, tiers):
    cfg = EngineConfig(model_store="decoupled", backend="numpy",
                       cache_tiers=tiers,
                       ann=ANN_CFG if "ann" in tiers else None)
    sess = MorphingSession(zoo=zoo, config=cfg)
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0
    sess.resolve_task("sent", sample.X, sample.y)
    return sess


def _serve_pass(srv, rows):
    srv.session.register_table("reviews", {"emb": rows})
    return srv.predict("PREDICT emb USING TASK sent FROM reviews",
                       timeout=120.0)


def _perturb(rng, base, scale):
    noise = rng.standard_normal(base.shape).astype(np.float32)
    noise /= np.linalg.norm(noise, axis=1, keepdims=True)
    return base + noise * scale


def bench_serving(zoo, base, sample, tiers, passes):
    """Near-duplicate passes through the serving lanes: returns
    (wall_seconds, rows_served, server, perturbation_scale, last_rows).
    Pass 1 fills the cache, pass 2 calibrates the ANN radius (both
    untimed for either configuration); timed passes perturb within 30%
    of the calibrated radius so the workload is reuse-eligible by
    construction."""
    sess = _make_session(zoo, sample, tiers)
    rng = np.random.default_rng(7)
    n = len(base)
    srv = MorphingServer(session=sess, max_wait_s=0.002)
    with srv:
        _serve_pass(srv, base)                               # fill
        _serve_pass(srv, _perturb(rng, base, 1e-3))          # calibrate
        ann = sess.ann
        if ann is not None:
            with ann._lock:
                block = next(iter(ann._blocks.values()))
                scale = 0.3 * ann._radius_of(block)
            assert scale > 0, "ANN tier failed to calibrate"
        else:
            scale = 1e-3        # same row geometry for the ablation
        srv.reset_telemetry()
        t0 = time.perf_counter()
        for _ in range(passes):
            last = _perturb(rng, base, scale)
            _serve_pass(srv, last)
        wall = time.perf_counter() - t0
        st = srv.stats()
    return wall, passes * n, st, scale, last, sess


def bench_topk(zoo, base, sample):
    """Warm top-k: the lowered index scan (cache-chain gather + argsort,
    zero trunk rows) vs a brute-force baseline that runs the trunk over
    the whole table and sorts. Uses the chain configuration: the chain's
    row-granular blocks are what the index scan gathers from."""
    sess = _make_session(zoo, sample, ("exact", "ann"))
    sess.register_table("reviews", {"id": np.arange(len(base)),
                                    "emb": base})
    sess.sql("PREDICT emb USING TASK sent FROM reviews")       # warm
    q = base[len(base) // 2]
    vec = "[" + ", ".join(f"{x:.6f}" for x in q) + "]"
    stmt = (f"PREDICT emb USING TASK sent FROM reviews "
            f"ORDER BY SIMILARITY(emb, {vec}) LIMIT {K_TOP}")
    res = sess.sql(stmt)
    assert res.report.index_scan, "similarity query must lower"
    assert res.report.sim_trunk_rows == 0, (
        "warm top-k must not run the trunk")
    lat = []
    for _ in range(TOPK_CALLS):
        t0 = time.perf_counter()
        sess.sql(stmt)
        lat.append(time.perf_counter() - t0)

    rm = sess.models["sent"]
    table = sess.tables["reviews"]
    qE = np.asarray(rm.features(q[None]), np.float32)[0]

    def brute():
        E = np.asarray(rm.features(table["emb"]), np.float32)
        top = np.argsort(np.linalg.norm(E - qE[None], axis=1))[:K_TOP]
        return rm.head(E[top])

    blat = []
    for _ in range(TOPK_CALLS):
        t0 = time.perf_counter()
        brute()
        blat.append(time.perf_counter() - t0)
    return (float(np.percentile(lat, 95)),
            float(np.percentile(blat, 95)))


def run(n_rows: int = N_ROWS, passes: int = N_PASSES,
        json_path: str = "BENCH_ann.json") -> dict:
    zoo, base, sample = _setup(n_rows)

    t_exact, rows, st_ex, _, _, _ = bench_serving(
        zoo, base, sample, ("exact",), passes)
    t_ann, _, st_ann, scale, last, sess_ann = bench_serving(
        zoo, base, sample, ("exact", "ann"), passes)

    recall = st_ann.approx_hits / max(rows, 1)
    speedup = t_exact / t_ann

    # error audit on the actual serving block: every row the ANN tier
    # would serve for the final perturbed batch, compared to the trunk
    ann = sess_ann.ann
    rm = sess_ann.models["sent"]
    key = rm.trunk_fp or rm.version
    tl = ann.lookup_many(_SHARE_TABLE, key, last, version=key)
    hit = ~tl.miss
    assert hit.any(), "probe batch must hit the ANN tier"
    exact = np.asarray(rm.features(last[hit]), np.float32)
    max_err = float(np.linalg.norm(
        tl.found[hit].astype(np.float64) - exact, axis=1).max())

    p95_topk, p95_brute = bench_topk(zoo, base, sample)

    emit_value("ann.exact_rows_per_s_warm", rows / t_exact,
               "trunk every pass")
    emit_value("ann.ann_rows_per_s_warm", rows / t_ann,
               f"recall={recall:.3f} radius_frac=0.3")
    emit_value("ann.speedup_ann_vs_exact", speedup, "x near-dup passes")
    emit_value("ann.recall", recall, f"target {TARGET_RECALL}")
    emit_value("ann.max_embed_error", max_err,
               f"bound {ANN_CFG.error_bound}")
    emit_value("ann.false_accepts", st_ann.false_accepts,
               f"{st_ann.approx_hits} approx hits")
    emit_value("ann.topk_warm_p95_latency_ms", p95_topk * 1e3,
               f"index scan k={K_TOP}")
    emit_value("ann.topk_brute_p95_latency_ms", p95_brute * 1e3,
               "trunk + full sort")

    result = {
        "rows_table": n_rows,
        "passes": passes,
        "dim": DIM,
        "trunk_width": TRUNK_WIDTH,
        "error_bound": ANN_CFG.error_bound,
        "exact_only": {"rows_per_s_warm": rows / t_exact,
                       "wall_s": t_exact,
                       "share_hits": st_ex.share_hits,
                       "share_misses": st_ex.share_misses},
        "ann_chain": {"rows_per_s_warm": rows / t_ann,
                      "wall_s": t_ann,
                      "recall": recall,
                      "max_embed_error": max_err,
                      "approx_hits": st_ann.approx_hits,
                      "false_accepts": st_ann.false_accepts,
                      "perturbation_scale": float(scale)},
        "speedup_ann_vs_exact": speedup,
        "topk": {"k": K_TOP,
                 "warm_p95_latency_ms": p95_topk * 1e3,
                 "brute_p95_latency_ms": p95_brute * 1e3,
                 "speedup_vs_brute": p95_brute / p95_topk},
    }
    assert max_err <= ANN_CFG.error_bound, (
        f"served embedding error {max_err:.4f} exceeds the "
        f"{ANN_CFG.error_bound} bound")
    if n_rows >= MIN_ROWS_FOR_ASSERT:
        assert recall >= TARGET_RECALL, (
            f"ANN recall {recall:.3f} < {TARGET_RECALL} on the "
            f"in-radius near-duplicate workload")
        assert speedup >= TARGET_ANN_SPEEDUP, (
            f"ANN chain {speedup:.2f}x < {TARGET_ANN_SPEEDUP}x target "
            f"over exact-only on the near-duplicate workload")
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2,
                                              sort_keys=True))
        print(f"# wrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=N_ROWS)
    ap.add_argument("--passes", type=int, default=N_PASSES)
    ap.add_argument("--json", default="BENCH_ann.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(n_rows=args.rows, passes=args.passes, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
