"""Paper Fig. 6 (series tasks): pipelined batch inference vs per-row
inference vs no-pipeline, on an MLP series classifier (YearPredict-style
synthetic data: 90 feature columns).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, emit_value, timeit
from repro.pipeline import (Dag, Node, PipelineExecutor, filter_op,
                            run_batched, window_op)


def _series_table(n: int = 20000, cols: int = 90, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {"x": rng.standard_normal((n, cols)).astype(np.float32),
            "year": rng.integers(1922, 2011, n)}


def _mlp(cols: int = 90, hidden: int = 256, seed: int = 1):
    rng = np.random.default_rng(seed)
    W1 = rng.standard_normal((cols, hidden)).astype(np.float32) * 0.05
    W2 = rng.standard_normal((hidden, 1)).astype(np.float32) * 0.05

    def infer(x):
        return np.maximum(x @ W1, 0.0) @ W2
    return infer


def run() -> None:
    table = _series_table()
    infer = _mlp()
    n = len(table["year"])

    def per_row():
        for i in range(0, 2000):  # row-at-a-time (scaled sample)
            infer(table["x"][i:i + 1])

    def batched():
        run_batched(list(table["x"][:2000]), infer, batch_size=32,
                    convert_workers=1)

    t_row = timeit(per_row)
    t_batch = timeit(batched)
    emit("series.per_row_2k", t_row)
    emit("series.batched32_2k", t_batch)
    emit_value("series.batch_speedup", t_row / t_batch, "x vs per-row")

    # full pipeline: filter -> window -> predict (throughput rows/s)
    def predict_node(b):
        out = dict(b)
        out["pred"] = infer(b["x"])[:, 0]
        return out

    dag = Dag()
    dag.add(Node("t", "scan"))
    dag.add(Node("f", "filter",
                 fn=lambda b: filter_op(b, lambda x: x["year"] > 1950)),
            deps=("t",))
    dag.add(Node("w", "window", fn=lambda b: window_op(b, "year", 8)),
            deps=("f",))
    dag.add(Node("p", "predict", fn=predict_node, cost_hint=8), deps=("w",))
    ex = PipelineExecutor(dag, workers=4)

    def pipelined():
        ex.execute_chunked("t", table, chunk_rows=2048, sink_id="p")

    def sequential():
        ex.execute({"t": table})

    t_pipe = timeit(pipelined)
    t_seq = timeit(sequential)
    emit("series.pipeline_20k", t_pipe, f"{n / t_pipe:.0f} rows/s")
    emit("series.sequential_20k", t_seq, f"{n / t_seq:.0f} rows/s")
    emit_value("series.pipeline_speedup", t_seq / t_pipe, "x vs one-shot")
