"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, seconds: float, derived: str = "") -> None:
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def emit_value(name: str, value: float, derived: str = "") -> None:
    ROWS.append((name, value, derived))
    print(f"{name},{value:.4f},{derived}")
