"""Mesh-parallel trunk embed lanes: serving throughput vs device count.

One ``MorphingServer`` front-end, same concurrent ``PREDICT`` workload,
two backend pools: ``devices=1`` (the parity-exact single-device jit
path) and ``devices=2`` (the ``MeshJaxBackend`` pool — trunk weights
staged once per mesh, embed batches split over the ``("data",)`` axis
with ``shard_map``). The share cache is disabled so the timed window
measures the trunk forward itself, not cache hits; "warm" means
post-compile (every shape bucket is visited by the warmup pass).

Run directly for machine-readable output::

    PYTHONPATH=src:. python benchmarks/bench_sharding.py \
        --json BENCH_sharding.json

Simulated host devices come from ``--xla_force_host_platform_device_
count`` which must be set *before* jax first initializes — this module
sets it at import time when jax is not yet loaded (standalone runs, the
CI leg); under ``benchmarks/run.py`` after a bench that already touched
jax it degrades to however many devices exist and records that.

The >=1.6x speedup target is asserted only where it is physically
meaningful: ``os.cpu_count() >= 2`` (two simulated devices on one core
time-slice a single ALU) *and* the mesh actually formed with 2 devices.
``speedup_asserted`` in the JSON records whether the gate was armed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

DEVICE_COUNT = 2


def _ensure_host_devices(n: int) -> None:
    """Ask XLA for ``n`` simulated host devices — a no-op when jax is
    already imported (device topology is fixed at first import) or when
    the caller pinned XLA_FLAGS themselves."""
    if "jax" in sys.modules:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


_ensure_host_devices(DEVICE_COUNT)

import numpy as np                                       # noqa: E402
from concurrent.futures import ThreadPoolExecutor        # noqa: E402

from benchmarks.common import emit_value                 # noqa: E402
from repro.core import make_task, pretrain_model         # noqa: E402
from repro.core.task import TaskSpec                     # noqa: E402
from repro.engine import MorphingServer, MorphingSession  # noqa: E402

N_ROWS = 4000
N_REQUESTS = 32
CONCURRENCY = 8
# wide trunk: the embed stage must carry the cost the mesh is splitting
TRUNK_WIDTH = 160
TARGET_SPEEDUP = 1.6
MIN_REQUESTS_FOR_ASSERT = 16
REPEATS = 3


def _setup(n_rows: int, dim: int = 16):
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=160, dim=dim, classes=3)
    zoo = [pretrain_model(src, width=TRUNK_WIDTH, seed=1,
                          name="shard-m0")]
    rng = np.random.default_rng(0)
    table = {"len": rng.integers(1, 200, n_rows),
             "emb": rng.standard_normal((n_rows, dim)).astype(np.float32)}
    sample = make_task(rng, "gauss", n=128, dim=dim, classes=3)
    return zoo, table, sample


def _make_server(zoo, table, sample, devices: int) -> MorphingServer:
    sess = MorphingSession(zoo=zoo, model_store="decoupled",
                           backend="jax", device_count=devices,
                           enable_share=False)   # measure the trunk, not
    #                                            # the cache
    sess.register_table("reviews", {k: v.copy() for k, v in table.items()})
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0   # single-model zoo: no selector
    sess.resolve_task("sent", sample.X, sample.y)
    return MorphingServer(session=sess, max_wait_s=0.002)


def _statements(n_requests: int):
    # varied predicates: each request selects a different row window —
    # and thus a different shape bucket mix — as concurrent clients would
    return [f"PREDICT emb USING TASK sent FROM reviews WHERE len > "
            f"{20 + (i % 16)}" for i in range(n_requests)]


def _rows_served(sess, stmts) -> int:
    lens = {s: int((sess.tables["reviews"]["len"]
                    > int(s.rsplit(">", 1)[1])).sum()) for s in set(stmts)}
    return sum(lens[s] for s in stmts)


def _bench(server: MorphingServer, stmts, concurrency: int):
    """Best-of-REPEATS wall over the statement set; the warmup pass runs
    every statement once so each shape bucket is compiled before the
    timed window, and telemetry is re-based per repeat."""
    def one(stmt):
        return server.predict(stmt, timeout=120.0)

    with ThreadPoolExecutor(concurrency) as pool:
        list(pool.map(one, stmts))               # warm: all buckets
        best, best_stats, p95s, outs = float("inf"), None, [], None
        for _ in range(REPEATS):
            server.reset_telemetry()
            t0 = time.perf_counter()
            got = list(pool.map(one, stmts))
            wall = time.perf_counter() - t0
            rep = server.stats()
            p95s.append(rep.p95_latency_s)
            if wall < best:
                best, best_stats, outs = wall, rep, got
        best_stats.p95_latency_s = float(np.median(p95s))
    return best, outs, best_stats


def run(n_rows: int = N_ROWS, n_requests: int = N_REQUESTS,
        concurrency: int = CONCURRENCY,
        json_path: str = "BENCH_sharding.json") -> dict:
    zoo, table, sample = _setup(n_rows)
    stmts = _statements(n_requests)
    cpus = os.cpu_count() or 1

    per_devices = {}
    outs_by_devices = {}
    for devices in (1, DEVICE_COUNT):
        server = _make_server(zoo, table, sample, devices)
        rows_total = _rows_served(server.session, stmts)
        with server:
            wall, outs, st = _bench(server, stmts, concurrency)
        backend = server.session.backends["tpu"]
        eff = server.devices
        lane_rows = [lane.batch_rows for lane in server._lanes.values()]
        per_devices[devices] = {
            "devices_effective": eff,
            "wall_s": wall,
            "rows_per_s_warm": rows_total / wall,
            "p95_latency_ms": st.p95_latency_s * 1e3,
            "mesh_rows_per_s": st.mesh_rows_per_s,
            "lane_batch_rows": max(lane_rows),
            "stage_count": backend.stage_count,
        }
        outs_by_devices[devices] = outs
        emit_value(f"sharding.devices{devices}_rows_per_s",
                   rows_total / wall,
                   f"mesh={eff} lane_rows={max(lane_rows)}")
        emit_value(f"sharding.devices{devices}_p95_latency_ms",
                   st.p95_latency_s * 1e3, "post-warmup window")

    # serving answers are device-count invariant (pool.map keeps order)
    for a, b in zip(outs_by_devices[1], outs_by_devices[DEVICE_COUNT]):
        np.testing.assert_allclose(a.scores, b.scores, atol=1e-5)

    one_d, mesh_d = per_devices[1], per_devices[DEVICE_COUNT]
    speedup = mesh_d["rows_per_s_warm"] / one_d["rows_per_s_warm"]
    mesh_formed = mesh_d["devices_effective"] == DEVICE_COUNT
    asserted = (mesh_formed and cpus >= DEVICE_COUNT
                and n_requests >= MIN_REQUESTS_FOR_ASSERT)
    emit_value("sharding.speedup_mesh_vs_single", speedup,
               f"x warm, asserted={asserted} (cpus={cpus})")

    # trunk weights staged once per pool, not once per device (compile
    # telemetry parity is proven deterministically in
    # tests/test_sharding.py — coalesced serving batch sizes are
    # scheduler-timing dependent, so compile counts are not benchable)
    assert mesh_d["stage_count"] == one_d["stage_count"] == 1

    result = {
        "rows_table": n_rows, "requests": n_requests,
        "concurrency": concurrency, "trunk_width": TRUNK_WIDTH,
        "host_cpu_count": cpus,
        "devices_1": one_d,
        "devices_2": mesh_d,
        "speedup_mesh_vs_single": speedup,
        "target_speedup": TARGET_SPEEDUP,
        "speedup_asserted": asserted,
    }
    if asserted:
        assert speedup >= TARGET_SPEEDUP, (
            f"mesh serving {speedup:.2f}x < {TARGET_SPEEDUP}x target at "
            f"{DEVICE_COUNT} devices, concurrency {concurrency} "
            f"({cpus} cpus)")
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2,
                                              sort_keys=True))
        print(f"# wrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=N_ROWS)
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--concurrency", type=int, default=CONCURRENCY)
    ap.add_argument("--json", default="BENCH_sharding.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(n_rows=args.rows, n_requests=args.requests,
        concurrency=args.concurrency, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
