"""Paper Fig. 7 (NLP tasks): sentiment classification throughput with and
without pre-embedding sharing + batch pipeline (ALBERT-style encoder stub:
token embedding avg + 2-layer MLP head on CPU).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, emit_value, timeit
from repro.pipeline import VectorShareCache, run_batched, simd_normalize_embed


def _texts(n: int = 4000, seq: int = 128, vocab: int = 30000, seed: int = 0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, (n, seq)).astype(np.int32)


def _encoder(vocab: int = 30000, d: int = 128, seed: int = 1):
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((vocab, d)).astype(np.float32) * 0.05

    def encode(tokens):  # [B, S] -> [B, d]  (embedding mean pool)
        return emb[tokens].mean(axis=1)
    return encode


def _head(d: int = 128, seed: int = 2):
    rng = np.random.default_rng(seed)
    W1 = rng.standard_normal((d, 64)).astype(np.float32) * 0.1
    W2 = rng.standard_normal((64, 3)).astype(np.float32) * 0.1

    def infer(feats):
        return np.maximum(feats @ W1, 0) @ W2
    return infer


def run() -> None:
    tokens = _texts()
    encode, head = _encoder(), _head()

    def naive_once():
        # every query re-embeds then classifies, row-at-a-time batches of 8
        feats = encode(tokens)
        run_batched(list(feats), head, batch_size=8, convert_workers=1)

    cache = VectorShareCache()

    def shared_once():
        feats = cache.get_or_embed("sst2", "text", tokens, encode)
        run_batched(list(feats), head, batch_size=32, convert_workers=1)

    t_naive = timeit(lambda: [naive_once() for _ in range(3)])
    t_shared = timeit(lambda: [shared_once() for _ in range(3)])
    emit("nlp.3queries_reembed", t_naive)
    emit("nlp.3queries_shared", t_shared,
         f"hit_rate={cache.hit_rate:.2f}")
    emit_value("nlp.sharing_speedup", t_naive / t_shared,
               "x for repeated queries (Fig 7/13)")
