"""Paper Table 3: inference time across batch sizes (4..128) + the cost
model's chosen batch size.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, emit_value, timeit
from repro.pipeline import OpProfile, choose_batch_size, run_batched


def run() -> None:
    rng = np.random.default_rng(0)
    d, h = 512, 1024
    W1 = rng.standard_normal((d, h)).astype(np.float32) * 0.02
    W2 = rng.standard_normal((h, 16)).astype(np.float32) * 0.02

    def infer(x):
        return np.maximum(x @ W1, 0) @ W2

    rows = [rng.standard_normal(d).astype(np.float32) for _ in range(4096)]
    times = {}
    for bs in (4, 8, 16, 32, 64, 128):
        t = timeit(lambda: run_batched(rows, infer, batch_size=bs,
                                       convert_workers=1), repeats=2)
        times[bs] = t
        emit(f"batchsize.bs{bs}", t, f"{len(rows) / t:.0f} rows/s")
    best = min(times, key=times.get)
    emit_value("batchsize.measured_best_throughput", best,
               "single-core CPU: no contention, monotone in bs")
    # Table 3's non-monotonic sweet spot comes from the concurrency /
    # latency trade-off (paper §5.2): under a per-batch latency bound the
    # cost model lands in the paper's 8-32 range.
    prof = OpProfile(flops_per_row=2 * (d * h + h * 16),
                     bytes_per_row=4 * (d + h),
                     model_bytes=4 * (d * h + h * 16))
    lat32 = (prof.flops_per_row * 32 / 5e10) * 4  # serving latency budget
    chosen = choose_batch_size(prof, "host",
                               mem_cap_bytes=prof.model_bytes + 2e5,
                               latency_bound_s=lat32)
    emit_value("batchsize.cost_model_choice", chosen,
               f"within_paper_sweet_spot={4 <= chosen <= 32} "
               "(mem cap + latency bound)")
