"""Serving-path ablation: per-request query execution vs the
continuous-batching ``MorphingServer`` on the same concurrent
``PREDICT ... USING TASK`` workload; the share-aware trunk-lane server
vs per-task full-predict lanes on an *overlapping-request* workload
(where warm rows should cost head-only work); the fine-tune
*delta-fleet* workload (K fine-tunes of one base serve through a single
shared embed lane at base + K·delta loaded bytes, vs K per-task lanes
re-running the trunk); plus the partial-load resolution story
(loaded-vs-stored bytes on the decoupled store).

Run directly for machine-readable output::

    PYTHONPATH=src:. python benchmarks/bench_serving.py \
        --requests 64 --rows 2000 --json BENCH_serving.json

``BENCH_serving.json`` records warm rows/s for all paths, the server's
p50/p95 latency (measured over a post-warmup telemetry window: the
server is ``reset_telemetry()``-ed after warmup so percentiles never mix
pre- and post-warmup samples), share-hit/dedup rates, coalescing factor,
and the partial-load byte accounting, so the serving perf trajectory is
tracked per PR (gated by ``scripts/check_bench.py`` in CI, including a
p95 tail-latency ceiling).
"""
from __future__ import annotations

import argparse
import json
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np

from benchmarks.common import emit_value
from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import MorphingServer, MorphingSession

N_ROWS = 2000
N_REQUESTS = 64
CONCURRENCY = 8
# below this the speedup targets are recorded but not asserted (thread
# startup and compile overheads dominate tiny request counts)
MIN_REQUESTS_FOR_ASSERT = 32
TARGET_SPEEDUP = 2.0
# share-aware trunk lanes vs the per-task full-predict lanes on the
# overlapping workload: warm rows approach head-only cost
TARGET_SHARE_SPEEDUP = 1.5
# the overlap ablation runs a wider trunk so the embed stage carries the
# cost the share cache is supposed to remove
OVERLAP_TRUNK_WIDTH = 160
# fine-tune fleet: K delta variants of one base, served through one
# shared embed lane; the ablation gives each task its own full-predict
# lane (K trunk recomputations). Loaded bytes must stay near the
# marginal cost base + K·delta, not K·full.
DELTA_FLEET_K = 4
TARGET_DELTA_SPEEDUP = 1.5
DELTA_BYTES_FACTOR = 1.5


def _setup(n_rows: int, dim: int = 16, width: int = 24,
           name: str = "serve-m0"):
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=160, dim=dim, classes=3)
    zoo = [pretrain_model(src, width=width, seed=1, name=name)]
    rng = np.random.default_rng(0)
    table = {"gender": rng.integers(0, 2, n_rows),
             "len": rng.integers(1, 200, n_rows),
             "emb": rng.standard_normal((n_rows, dim)).astype(np.float32)}
    sample = make_task(rng, "gauss", n=128, dim=dim, classes=3)
    return zoo, table, sample


def _make_session(zoo, table, sample, **kw):
    sess = MorphingSession(zoo=zoo, model_store="decoupled",
                           backend="numpy", **kw)
    sess.register_table("reviews", {k: v.copy() for k, v in table.items()})
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0   # single-model zoo: no selector
    sess.resolve_task("sent", sample.X, sample.y)
    return sess


def _statements(n_requests: int):
    # varied predicates: each request selects a different row window, as
    # concurrent clients would
    return [f"PREDICT emb USING TASK sent FROM reviews WHERE len > "
            f"{20 + (i % 16)}" for i in range(n_requests)]


def _make_fleet_session(zoo, table, sample, k: int):
    """Base session + K registered fine-tunes (head deltas of the base),
    each bound to its own task via resolve_task(model_id=)."""
    sess = _make_session(zoo, table, sample)   # resolves 'sent' -> base
    rng = np.random.default_rng(7)
    base = zoo[0]
    width = int(base.W.shape[1])
    for i in range(k):
        w = np.abs(rng.standard_normal(width)).astype(np.float32)
        w /= w.sum()
        sess.register_finetune(f"{base.name}-ft{i}", base.name,
                               {"head/w": w})
        sess.create_task(TaskSpec(f"sent_ft{i}", "series", ("P", "N")))
        sess.resolve_task(f"sent_ft{i}", sample.X, sample.y,
                          model_id=f"{base.name}-ft{i}")
    return sess


def _fleet_statements(n_requests: int, k: int):
    return [f"PREDICT emb USING TASK sent_ft{i % k} FROM reviews "
            f"WHERE len > {20 + (i % 16)}" for i in range(n_requests)]


def _rows_served(sess, stmts) -> int:
    lens = {s: int((sess.tables["reviews"]["len"]
                    > int(s.rsplit(">", 1)[1])).sum()) for s in set(stmts)}
    return sum(lens[s] for s in stmts)


REPEATS = 3      # best-of: the warm walls are ~100ms, noise-prone


def bench_per_request(sess, stmts, concurrency: int) -> float:
    """Each request is its own full query: parse -> plan -> chunked
    executor, from ``concurrency`` client threads."""
    with ThreadPoolExecutor(concurrency) as pool:
        list(pool.map(sess.sql, stmts[:concurrency]))        # warm
        best = float("inf")
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            list(pool.map(sess.sql, stmts))
            best = min(best, time.perf_counter() - t0)
        return best


def bench_server(server, stmts, concurrency: int, warm_all: bool = False):
    """Same statements through the continuous-batching server. After the
    warmup pass the telemetry window is re-based, so the stats (latency
    percentiles, share/dedup rates) describe only the timed traffic."""
    def one(stmt):
        return server.predict(stmt, timeout=60.0)

    with ThreadPoolExecutor(concurrency) as pool:
        # warm_all runs every statement once so a share-aware server
        # enters the timed window with the full working set cached
        list(pool.map(one, stmts if warm_all else stmts[:concurrency]))
        warm_stats = server.stats()      # cold-phase counters (dedup)
        # each repeat gets its own telemetry window: percentiles never
        # mix warmup samples, counters come from the best-wall repeat
        # (matching the best-of timing convention) and the reported tail
        # latency is the *median* of the per-repeat p95s — one straggler
        # repeat on a loaded box must not define the latency contract
        best, best_stats, p95s = float("inf"), None, []
        for _ in range(REPEATS):
            server.reset_telemetry()
            t0 = time.perf_counter()
            outs = list(pool.map(one, stmts))
            wall = time.perf_counter() - t0
            rep = server.stats()
            p95s.append(rep.p95_latency_s)
            if wall < best:
                best, best_stats = wall, rep
        best_stats.p95_latency_s = float(np.median(p95s))
    return best, outs, warm_stats, best_stats


def run(n_rows: int = N_ROWS, n_requests: int = N_REQUESTS,
        concurrency: int = CONCURRENCY,
        json_path: str = "BENCH_serving.json") -> dict:
    zoo, table, sample = _setup(n_rows)
    stmts = _statements(n_requests)

    # -- baseline: every PREDICT is its own full query -------------------
    sess_base = _make_session(zoo, table, sample)
    t_per_req = bench_per_request(sess_base, stmts, concurrency)
    rows_total = _rows_served(sess_base, stmts)

    # -- server: continuous batching over shared trunk embed lanes -------
    sess_srv = _make_session(zoo, table, sample)
    server = MorphingServer(session=sess_srv, max_wait_s=0.002)
    with server:
        t_server, outs, _, st = bench_server(server, stmts, concurrency)

    # parity: a served request matches the engine answer
    ref = sess_base.sql(stmts[0]).rows["_score"]
    got = outs[0].scores                 # pool.map preserves order
    np.testing.assert_allclose(np.sort(got), np.sort(ref), atol=1e-5)

    speedup = t_per_req / t_server
    emit_value("serving.per_request_rows_per_s", rows_total / t_per_req,
               f"{concurrency} clients")
    emit_value("serving.server_rows_per_s", rows_total / t_server,
               f"coalesced x{st.mean_coalesced:.1f}")
    emit_value("serving.speedup_server_vs_per_request", speedup, "x warm")
    emit_value("serving.p50_latency_ms", st.p50_latency_s * 1e3,
               "post-warmup window")
    emit_value("serving.p95_latency_ms", st.p95_latency_s * 1e3,
               "post-warmup window")
    emit_value("serving.share_hit_rate", st.share_hit_rate, "warm rows")

    # -- overlap ablation: share-aware trunk lanes vs per-task lanes -----
    # concurrent requests select overlapping row windows; the share-aware
    # server embeds each distinct row once (cache + in-flight dedup) and
    # warm traffic pays head-only cost, while per-task full-predict lanes
    # recompute every window end to end
    zoo_o, table_o, sample_o = _setup(n_rows, width=OVERLAP_TRUNK_WIDTH,
                                      name="serve-share")
    sess_task = _make_session(zoo_o, table_o, sample_o)
    srv_task = MorphingServer(session=sess_task, max_wait_s=0.002,
                              share_lanes=False)
    with srv_task:
        t_task, _, _, _ = bench_server(srv_task, stmts, concurrency,
                                       warm_all=True)
    sess_share = _make_session(zoo_o, table_o, sample_o)
    srv_share = MorphingServer(session=sess_share, max_wait_s=0.002)
    with srv_share:
        t_share, outs_share, cold_share, st_share = bench_server(
            srv_share, stmts, concurrency, warm_all=True)

    # deterministic in-flight-dedup exercise: identical concurrent
    # requests against a cold cache under a generous coalescing window
    # (the 2ms production window makes batch composition — and thus the
    # dedup counter — scheduler-timing dependent; asserting on it would
    # flake on loaded runners)
    sess_probe = _make_session(zoo_o, table_o, sample_o)
    srv_probe = MorphingServer(session=sess_probe, max_wait_s=0.2)
    with srv_probe:
        with ThreadPoolExecutor(concurrency) as pool:
            list(pool.map(lambda s: srv_probe.predict(s, timeout=60.0),
                          [stmts[0]] * concurrency))
    dedup_probe = srv_probe.stats()
    ref_o = sess_task.sql(stmts[0]).rows["_score"]
    got_o = outs_share[0].scores         # pool.map preserves order
    np.testing.assert_allclose(np.sort(got_o), np.sort(ref_o), atol=1e-5)
    share_speedup = t_task / t_share
    emit_value("serving.overlap_task_lane_rows_per_s",
               rows_total / t_task, "full predict per lane")
    emit_value("serving.overlap_share_rows_per_s",
               rows_total / t_share,
               f"hit_rate={st_share.share_hit_rate:.2f} "
               f"cold_dedup={cold_share.dedup_rate:.2f}")
    emit_value("serving.speedup_share_vs_task_lanes", share_speedup,
               "x warm overlapping rows")
    emit_value("serving.dedup_probe_rate", dedup_probe.dedup_rate,
               f"{dedup_probe.dedup_rows} in-flight rows folded")
    assert st_share.share_hit_rate > 0.0, (
        "overlapping warm traffic must hit the share cache")
    assert dedup_probe.dedup_rows > 0, (
        "identical concurrent requests must exercise in-flight dedup")

    # -- delta fleet: K fine-tunes of one base share one embed lane -----
    # the heavy trunk runs once per distinct row window regardless of
    # which fine-tune asked; per-task full-predict lanes (the ablation)
    # recompute it K times and stage K trunk copies
    zoo_d, table_d, sample_d = _setup(n_rows, width=OVERLAP_TRUNK_WIDTH,
                                      name="serve-delta")
    fleet_stmts = _fleet_statements(n_requests, DELTA_FLEET_K)
    sess_dtask = _make_fleet_session(zoo_d, table_d, sample_d,
                                     DELTA_FLEET_K)
    srv_dtask = MorphingServer(session=sess_dtask, max_wait_s=0.002,
                               share_lanes=False)
    with srv_dtask:
        t_dtask, _, _, _ = bench_server(srv_dtask, fleet_stmts,
                                        concurrency, warm_all=True)
    sess_fleet = _make_fleet_session(zoo_d, table_d, sample_d,
                                     DELTA_FLEET_K)
    srv_fleet = MorphingServer(session=sess_fleet, max_wait_s=0.002)
    with srv_fleet:
        t_fleet, outs_fleet, _, st_fleet = bench_server(
            srv_fleet, fleet_stmts, concurrency, warm_all=True)
    rows_fleet = _rows_served(sess_fleet, fleet_stmts)

    # parity: a served fine-tune matches its analytics answer
    ref_d = sess_dtask.sql(fleet_stmts[0]).rows["_score"]
    np.testing.assert_allclose(np.sort(outs_fleet[0].scores),
                               np.sort(ref_d), atol=1e-5)
    # the whole fleet rides ONE embed lane (shared base trunk identity)
    assert st_fleet.lanes == 1 and st_fleet.delta_tasks == DELTA_FLEET_K, (
        f"expected one shared embed lane for {DELTA_FLEET_K} fine-tunes, "
        f"got lanes={st_fleet.lanes} delta_tasks={st_fleet.delta_tasks}")
    # loaded bytes stay at marginal cost: base once + K small deltas
    base_rm = sess_fleet.models["sent"]
    fleet_loaded = base_rm.loaded_bytes + st_fleet.delta_loaded_bytes
    fleet_budget = DELTA_BYTES_FACTOR * (base_rm.stored_bytes
                                         + st_fleet.delta_stored_bytes)
    assert fleet_loaded < fleet_budget, (
        f"delta fleet loaded {fleet_loaded}B >= {fleet_budget:.0f}B "
        f"(base {base_rm.stored_bytes}B + "
        f"{DELTA_FLEET_K}·delta {st_fleet.delta_stored_bytes}B)")
    delta_speedup = t_dtask / t_fleet
    emit_value("serving.delta_fleet_task_lane_rows_per_s",
               rows_fleet / t_dtask, f"{DELTA_FLEET_K} full lanes")
    emit_value("serving.delta_fleet_share_rows_per_s",
               rows_fleet / t_fleet,
               f"1 embed lane, {DELTA_FLEET_K} heads, "
               f"hit_rate={st_fleet.share_hit_rate:.2f}")
    emit_value("serving.speedup_delta_fleet_vs_task_lanes", delta_speedup,
               "x warm fleet rows")
    emit_value("serving.delta_fleet_loaded_bytes", fleet_loaded,
               f"budget {fleet_budget:.0f}")

    # -- partial load: a head-only predict loads head bytes, not trunk --
    sess_head = _make_session(zoo, table, sample)
    sess_head.sql(stmts[0])               # warms the share cache
    # count true disk bytes (the in-memory layer cache would serve the
    # head layer for free after the first resolution)
    sess_head.dstore.cache_layers = False
    sess_head.create_task(TaskSpec("sent2", "series", ("P", "N")))
    sess_head.registry._resolution["sent2"] = 0
    rm2 = sess_head.resolve_task("sent2", sample.X, sample.y, mode="head")
    sess_head.sql("PREDICT emb USING TASK sent2 FROM reviews "
                  "WHERE len > 20")       # embeds come from the share
    head_loaded = rm2.loaded_bytes
    emit_value("serving.head_only_loaded_bytes", head_loaded,
               f"of {rm2.stored_bytes} stored")
    assert head_loaded < rm2.stored_bytes, (
        "head-only predict must load less than the stored model")
    assert not rm2.zoo_model.materialized, (
        "share-cache hits must keep the trunk on disk")

    result = {
        "rows_table": n_rows, "requests": n_requests,
        "concurrency": concurrency, "rows_served": rows_total,
        "per_request": {"wall_s": t_per_req,
                        "rows_per_s_warm": rows_total / t_per_req},
        "server": {"wall_s": t_server,
                   "rows_per_s_warm": rows_total / t_server,
                   "p50_latency_ms": st.p50_latency_s * 1e3,
                   "p95_latency_ms": st.p95_latency_s * 1e3,
                   "batches": st.batches,
                   "mean_coalesced": st.mean_coalesced,
                   "share_hit_rate": st.share_hit_rate},
        "speedup_server_vs_per_request": speedup,
        "overlap": {
            "trunk_width": OVERLAP_TRUNK_WIDTH,
            "task_lanes": {"wall_s": t_task,
                           "rows_per_s_warm": rows_total / t_task},
            "share_lanes": {"wall_s": t_share,
                            "rows_per_s_warm": rows_total / t_share,
                            "p95_latency_ms":
                                st_share.p95_latency_s * 1e3,
                            "share_hit_rate": st_share.share_hit_rate,
                            "cold_dedup_rate": cold_share.dedup_rate,
                            "dedup_probe_rate": dedup_probe.dedup_rate,
                            "dedup_probe_rows": dedup_probe.dedup_rows,
                            "embed_rows": st_share.embed_rows,
                            "head_rows": st_share.head_rows},
            "speedup_share_vs_task_lanes": share_speedup,
        },
        "delta_fleet": {
            "k": DELTA_FLEET_K,
            "trunk_width": OVERLAP_TRUNK_WIDTH,
            "task_lanes": {"wall_s": t_dtask,
                           "rows_per_s_warm": rows_fleet / t_dtask},
            "share_lanes": {"wall_s": t_fleet,
                            "rows_per_s_warm": rows_fleet / t_fleet,
                            "p95_latency_ms":
                                st_fleet.p95_latency_s * 1e3,
                            "share_hit_rate": st_fleet.share_hit_rate,
                            "lanes": st_fleet.lanes,
                            "delta_tasks": st_fleet.delta_tasks},
            "speedup_share_vs_task_lanes": delta_speedup,
            "base_stored_bytes": int(base_rm.stored_bytes),
            "delta_stored_bytes": int(st_fleet.delta_stored_bytes),
            "loaded_bytes": int(fleet_loaded),
            "loaded_budget_bytes": int(fleet_budget),
        },
        "partial_load": {"head_only_loaded_bytes": int(head_loaded),
                         "stored_bytes": int(rm2.stored_bytes),
                         "loaded_fraction": head_loaded
                         / max(rm2.stored_bytes, 1)},
    }
    if n_requests >= MIN_REQUESTS_FOR_ASSERT:
        assert speedup >= TARGET_SPEEDUP, (
            f"server {speedup:.2f}x < {TARGET_SPEEDUP}x target over "
            f"per-request execution at concurrency {concurrency}")
        assert share_speedup >= TARGET_SHARE_SPEEDUP, (
            f"share-aware lanes {share_speedup:.2f}x < "
            f"{TARGET_SHARE_SPEEDUP}x target over per-task lanes on the "
            f"overlapping workload at concurrency {concurrency}")
        assert delta_speedup >= TARGET_DELTA_SPEEDUP, (
            f"delta fleet through the shared embed lane "
            f"{delta_speedup:.2f}x < {TARGET_DELTA_SPEEDUP}x target over "
            f"{DELTA_FLEET_K} per-task lanes at concurrency {concurrency}")
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2,
                                              sort_keys=True))
        print(f"# wrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=N_ROWS)
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--concurrency", type=int, default=CONCURRENCY)
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(n_rows=args.rows, n_requests=args.requests,
        concurrency=args.concurrency, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
