"""Serving-path ablation: per-request query execution vs the
continuous-batching ``MorphingServer`` on the same concurrent
``PREDICT ... USING TASK`` workload, plus the partial-load resolution
story (loaded-vs-stored bytes on the decoupled store).

Run directly for machine-readable output::

    PYTHONPATH=src:. python benchmarks/bench_serving.py \
        --requests 64 --rows 2000 --json BENCH_serving.json

``BENCH_serving.json`` records warm rows/s for both paths, the server's
p50/p95 latency and coalescing factor, and the partial-load byte
accounting, so the serving perf trajectory is tracked per PR (gated by
``scripts/check_bench.py`` in CI).
"""
from __future__ import annotations

import argparse
import json
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np

from benchmarks.common import emit_value
from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import MorphingServer, MorphingSession

N_ROWS = 2000
N_REQUESTS = 64
CONCURRENCY = 8
# below this the 2x speedup target is recorded but not asserted (thread
# startup and compile overheads dominate tiny request counts)
MIN_REQUESTS_FOR_ASSERT = 32
TARGET_SPEEDUP = 2.0


def _setup(n_rows: int, dim: int = 16):
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=160, dim=dim, classes=3)
    zoo = [pretrain_model(src, width=24, seed=1, name="serve-m0")]
    rng = np.random.default_rng(0)
    table = {"gender": rng.integers(0, 2, n_rows),
             "len": rng.integers(1, 200, n_rows),
             "emb": rng.standard_normal((n_rows, dim)).astype(np.float32)}
    sample = make_task(rng, "gauss", n=128, dim=dim, classes=3)
    return zoo, table, sample


def _make_session(zoo, table, sample, **kw):
    sess = MorphingSession(zoo=zoo, model_store="decoupled",
                           backend="numpy", **kw)
    sess.register_table("reviews", {k: v.copy() for k, v in table.items()})
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0   # single-model zoo: no selector
    sess.resolve_task("sent", sample.X, sample.y)
    return sess


def _statements(n_requests: int):
    # varied predicates: each request selects a different row window, as
    # concurrent clients would
    return [f"PREDICT emb USING TASK sent FROM reviews WHERE len > "
            f"{20 + (i % 16)}" for i in range(n_requests)]


def _rows_served(sess, stmts) -> int:
    lens = {s: int((sess.tables["reviews"]["len"]
                    > int(s.rsplit(">", 1)[1])).sum()) for s in set(stmts)}
    return sum(lens[s] for s in stmts)


REPEATS = 3      # best-of: the warm walls are ~100ms, noise-prone


def bench_per_request(sess, stmts, concurrency: int) -> float:
    """Each request is its own full query: parse -> plan -> chunked
    executor, from ``concurrency`` client threads."""
    with ThreadPoolExecutor(concurrency) as pool:
        list(pool.map(sess.sql, stmts[:concurrency]))        # warm
        best = float("inf")
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            list(pool.map(sess.sql, stmts))
            best = min(best, time.perf_counter() - t0)
        return best


def bench_server(server, stmts, concurrency: int):
    """Same statements through the continuous-batching server."""
    def one(stmt):
        return server.predict(stmt, timeout=60.0)

    with ThreadPoolExecutor(concurrency) as pool:
        list(pool.map(one, stmts[:concurrency]))             # warm
        best = float("inf")
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            outs = list(pool.map(one, stmts))
            best = min(best, time.perf_counter() - t0)
    return best, outs


def run(n_rows: int = N_ROWS, n_requests: int = N_REQUESTS,
        concurrency: int = CONCURRENCY,
        json_path: str = "BENCH_serving.json") -> dict:
    zoo, table, sample = _setup(n_rows)
    stmts = _statements(n_requests)

    # -- baseline: every PREDICT is its own full query -------------------
    sess_base = _make_session(zoo, table, sample)
    t_per_req = bench_per_request(sess_base, stmts, concurrency)
    rows_total = _rows_served(sess_base, stmts)

    # -- server: continuous batching over per-task lanes -----------------
    sess_srv = _make_session(zoo, table, sample)
    server = MorphingServer(session=sess_srv, max_wait_s=0.002)
    with server:
        t_server, outs = bench_server(server, stmts, concurrency)
    st = server.stats()

    # parity: a served request matches the engine answer
    ref = sess_base.sql(stmts[0]).rows["_score"]
    got = next(o.scores for o in outs
               if o.rows == len(ref))
    np.testing.assert_allclose(np.sort(got), np.sort(ref), atol=1e-5)

    speedup = t_per_req / t_server
    emit_value("serving.per_request_rows_per_s", rows_total / t_per_req,
               f"{concurrency} clients")
    emit_value("serving.server_rows_per_s", rows_total / t_server,
               f"coalesced x{st.mean_coalesced:.1f}")
    emit_value("serving.speedup_server_vs_per_request", speedup, "x warm")
    emit_value("serving.p50_latency_ms", st.p50_latency_s * 1e3, "")
    emit_value("serving.p95_latency_ms", st.p95_latency_s * 1e3, "")

    # -- partial load: a head-only predict loads head bytes, not trunk --
    sess_head = _make_session(zoo, table, sample)
    sess_head.sql(stmts[0])               # warms the share cache
    # count true disk bytes (the in-memory layer cache would serve the
    # head layer for free after the first resolution)
    sess_head.dstore.cache_layers = False
    sess_head.create_task(TaskSpec("sent2", "series", ("P", "N")))
    sess_head.registry._resolution["sent2"] = 0
    rm2 = sess_head.resolve_task("sent2", sample.X, sample.y, mode="head")
    sess_head.sql("PREDICT emb USING TASK sent2 FROM reviews "
                  "WHERE len > 20")       # embeds come from the share
    head_loaded = rm2.loaded_bytes
    emit_value("serving.head_only_loaded_bytes", head_loaded,
               f"of {rm2.stored_bytes} stored")
    assert head_loaded < rm2.stored_bytes, (
        "head-only predict must load less than the stored model")
    assert not rm2.zoo_model.materialized, (
        "share-cache hits must keep the trunk on disk")

    result = {
        "rows_table": n_rows, "requests": n_requests,
        "concurrency": concurrency, "rows_served": rows_total,
        "per_request": {"wall_s": t_per_req,
                        "rows_per_s_warm": rows_total / t_per_req},
        "server": {"wall_s": t_server,
                   "rows_per_s_warm": rows_total / t_server,
                   "p50_latency_ms": st.p50_latency_s * 1e3,
                   "p95_latency_ms": st.p95_latency_s * 1e3,
                   "batches": st.batches,
                   "mean_coalesced": st.mean_coalesced},
        "speedup_server_vs_per_request": speedup,
        "partial_load": {"head_only_loaded_bytes": int(head_loaded),
                         "stored_bytes": int(rm2.stored_bytes),
                         "loaded_fraction": head_loaded
                         / max(rm2.stored_bytes, 1)},
    }
    if n_requests >= MIN_REQUESTS_FOR_ASSERT:
        assert speedup >= TARGET_SPEEDUP, (
            f"server {speedup:.2f}x < {TARGET_SPEEDUP}x target over "
            f"per-request execution at concurrency {concurrency}")
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2,
                                              sort_keys=True))
        print(f"# wrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=N_ROWS)
    ap.add_argument("--requests", type=int, default=N_REQUESTS)
    ap.add_argument("--concurrency", type=int, default=CONCURRENCY)
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="output path ('' disables)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(n_rows=args.rows, n_requests=args.requests,
        concurrency=args.concurrency, json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
