"""Roofline summary (ours): aggregates the dry-run artifacts into headline
numbers per arch x shape (single pod), so `python -m benchmarks.run`
reports the perf state without recompiling.
"""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import emit_value
from repro.analysis.report import load_records, roofline_row

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run() -> None:
    if not ART.exists():
        emit_value("roofline.missing", 0.0,
                   "run: python -m repro.launch.dryrun --all --mesh both")
        return
    rows = [roofline_row(r) for r in load_records(ART, "single")]
    for r in rows:
        emit_value(f"roofline.{r['arch']}.{r['shape']}",
                   r["roofline_fraction"],
                   f"dom={r['dominant']} 6ND/HLO="
                   f"{(r['useful_ratio'] or 0):.3f}")
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        best = max(rows, key=lambda r: r["roofline_fraction"])
        emit_value("roofline.worst_fraction", worst["roofline_fraction"],
                   f"{worst['arch']}/{worst['shape']}")
        emit_value("roofline.best_fraction", best["roofline_fraction"],
                   f"{best['arch']}/{best['shape']}")
