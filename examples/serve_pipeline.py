"""Serving example: batched greedy decoding for a reduced zoo LM through
the cost-model-sized serving engine, plus an API-registered remote model
participating in the same pipeline (paper §3.1 API-based storage).

Run:  PYTHONPATH=src python examples/serve_pipeline.py
"""
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import ServingEngine
from repro.models import build_model
from repro.pipeline import OpProfile, choose_batch_size
from repro.storage import ApiModelRegistry


def main() -> None:
    cfg = smoke_config("h2o-danube-1.8b")
    model = build_model(cfg, attn_impl="naive")
    params = model.init(jax.random.PRNGKey(0))

    n = cfg.param_count()
    prof = OpProfile(flops_per_row=2.0 * n, bytes_per_row=cfg.d_model * 2,
                     model_bytes=n * 2)
    slots = choose_batch_size(prof, "tpu", mem_cap_bytes=4e9,
                              candidates=(1, 2, 4, 8, 16))
    engine = ServingEngine(model, params, max_len=64, batch_slots=slots)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (8, 32)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(prompts, gen_tokens=16)
    dt = time.time() - t0
    print(f"local zoo model: {out.shape[0] * out.shape[1]} tokens "
          f"in {dt:.2f}s (batch slots={slots}, SWA window="
          f"{cfg.sliding_window})")

    # remote API model registered as a logical operator with retry+cache
    api = ApiModelRegistry()
    api.register("frontier-llm", lambda toks: np.asarray(toks)[..., ::-1],
                 latency_s=0.02, failure_rate=0.3, max_retries=5)
    res = api.invoke("frontier-llm", prompts[:2], np.random.default_rng(1))
    st = api.stats["frontier-llm"]
    print(f"api model: calls={st['calls']} retries={st['retries']} "
          f"-> result {res.shape} (failures retried transparently)")
    res2 = api.invoke("frontier-llm", prompts[:2], np.random.default_rng(2))
    print(f"api cache hits: {api.stats['frontier-llm']['cache_hits']}")


if __name__ == "__main__":
    main()
