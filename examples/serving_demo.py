"""Serving demo: concurrent PREDICT requests through ``MorphingServer``,
next to the batch-analytics surface of ``examples/task_centric_sql.py``.

Eight client threads fire ``PREDICT ... USING TASK`` statements at the
server; requests whose tasks resolve to the same *trunk* are coalesced
into one cost-model-sized embed lane (warm rows come from the share
cache, in-flight duplicates compute once) and scored by cheap per-task
head stages, while resolution rides the decoupled store's partial-load
path (only the layers a request needs leave the disk). Run:
  PYTHONPATH=src python examples/serving_demo.py

With ``--delta`` the workload becomes a fine-tune fleet: one base model
plus three head-delta variants registered via
``MorphingSession.register_finetune`` and bound with
``resolve_task(model_id=)``. All four tasks share the base trunk's
embed lane — the trunk is staged once and only the small per-head delta
bytes are read from disk (see docs/serving.md):
  PYTHONPATH=src python examples/serving_demo.py --delta

With ``--workers N`` the same traffic runs through the multi-process
dispatch tier instead: a ``DispatchServer`` front door spawns N worker
processes over the shared store, routes coalesced batches to them as
leases, and keeps each trunk on as few workers as its load needs
(``--delta --workers 2`` shows the whole fleet staged on one worker's
shared embed lane). The stats dump covers placement, leases, and the
per-worker aggregates (see docs/serving.md "Dispatch tier"):
  PYTHONPATH=src python examples/serving_demo.py --workers 2 --delta
"""
import argparse
import threading

import numpy as np

from repro.core import (ModelSelector, TaskFeaturizer, build_tasks,
                        build_zoo, make_task, transfer_matrix)
from repro.engine import DispatchServer, MorphingServer, MorphingSession

N_FINETUNES = 3


def main(delta: bool = False, workers: int = 0) -> None:
    zoo = build_zoo(16, seed=0)
    history = build_tasks(32, seed=1)
    V = transfer_matrix(zoo, history)
    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in history])
    sel = ModelSelector(k=6, n_anchors=3).fit_offline(V, feats, zoo=zoo)

    sess = MorphingSession(selector=sel, zoo=zoo, model_store="decoupled")
    rng = np.random.default_rng(0)
    n = 3000
    sess.register_table("reviews", {
        "gender": rng.integers(0, 2, n),
        "len": rng.integers(1, 200, n),
        "emb": rng.standard_normal((n, 16)).astype(np.float32)})
    print(sess.sql(
        "CREATE TASK sentiment (INPUT=Series, OUTPUT IN ('POS','NEG'), "
        "TYPE='Classification');"))
    sample = make_task(rng, "gauss", n=128, dim=16, classes=3)

    if workers:
        # front door + N worker processes over the shared store root
        server = DispatchServer(session=sess, workers=workers,
                                max_wait_s=0.005)
    else:
        server = MorphingServer(session=sess, max_wait_s=0.005)
    # partial-load resolution ahead of traffic: the slice is keyed to
    # the sample's width, which matches the reviews.emb schema here
    server.resolve_task("sentiment", sample.X, sample.y, mode="partial")
    tasks = ["sentiment"]
    if delta:
        # fine-tune fleet: the system-resolved model becomes the base;
        # each variant stores only a new head (delta layers) and rides
        # the base trunk's embed lane when served
        base_id = sess.models["sentiment"].model_id
        base_dim = sess.models["sentiment"].head_dim
        for i in range(N_FINETUNES):
            w = np.abs(rng.standard_normal(base_dim)).astype(np.float32)
            w /= w.sum()
            ft_id = f"{base_id}-ft{i}"
            sess.register_finetune(ft_id, base_id, {"head/w": w})
            name = f"sentiment_ft{i}"
            sess.sql(f"CREATE TASK {name} (INPUT=Series, "
                     "OUTPUT IN ('POS','NEG'), TYPE='Classification');")
            sess.resolve_task(name, sample.X, sample.y, model_id=ft_id)
            tasks.append(name)

    with server:
        results = {}

        def client(cid: int) -> None:
            for i in range(6):
                task = tasks[(cid + i) % len(tasks)]
                out = server.predict(
                    f"PREDICT emb USING TASK {task} FROM reviews "
                    f"WHERE len > {20 + 10 * (i % 4)}",
                    sample=(sample.X, sample.y), timeout=30.0)
                results[(cid, i)] = out

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st = server.stats()          # workers answer while still alive

    rm = sess.models["sentiment"]
    print(f"(system resolved sentiment -> {rm.model_id}, "
          f"{rm.store} store, mode={rm.load_mode})")
    if workers:
        print(f"dispatch tier: {st.alive_workers}/{st.workers} workers, "
              f"{st.requests} requests / {st.rows} rows over "
              f"{st.leases} leases "
              f"(redispatches={st.redispatches}, "
              f"scale out/in={st.scale_outs}/{st.scale_ins})")
        print(f"placement: replicas {st.replicas_by_trunk}; "
              f"staged bytes by worker {st.staged_bytes_by_worker}")
        print(f"front latency p50={st.p50_latency_s * 1e3:.1f}ms "
              f"p95={st.p95_latency_s * 1e3:.1f}ms; "
              f"{st.rows_per_second:.0f} rows/s worker inference; "
              f"share hit rate {st.share_hit_rate:.2f}")
    else:
        print(f"served {st.requests} requests / {st.rows} rows in "
              f"{st.batches} batches (x{st.mean_coalesced:.1f} coalesced)")
        print(f"latency p50={st.p50_latency_s * 1e3:.1f}ms "
              f"p95={st.p95_latency_s * 1e3:.1f}ms; "
              f"{st.rows_per_second:.0f} rows/s inference")
        print(f"partial load: {st.loaded_bytes}B read of "
              f"{st.stored_bytes}B stored")
        if delta:
            print(f"delta fleet: {len(tasks)} tasks over {st.lanes} embed "
                  f"lane(s) {st.tasks_by_lane}; {st.delta_tasks} "
                  f"fine-tunes read {st.delta_loaded_bytes}B "
                  f"({st.delta_stored_bytes}B of deltas on disk); "
                  f"share hit rate {st.share_hit_rate:.2f}")
    one = results[(0, 0)]
    print(f"(request {one.req_id}: {one.rows} rows, "
          f"mean score {one.scores.mean():+.4f})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--delta", action="store_true",
                    help="serve a fine-tune fleet (base + "
                         f"{N_FINETUNES} head-delta variants) through "
                         "one shared embed lane")
    ap.add_argument("--workers", type=int, default=0,
                    help="route through the multi-process dispatch tier "
                         "with N worker processes (0 = in-process "
                         "MorphingServer)")
    args = ap.parse_args()
    main(delta=args.delta, workers=args.workers)
