"""Serving demo: concurrent PREDICT requests through ``MorphingServer``,
next to the batch-analytics surface of ``examples/task_centric_sql.py``.

Eight client threads fire ``PREDICT ... USING TASK`` statements at the
server; same-task requests are coalesced into cost-model-sized batches
and executed through the task's staged backend, while resolution rides
the decoupled store's partial-load path (only the layers a request
needs leave the disk). Run:
  PYTHONPATH=src python examples/serving_demo.py
"""
import threading

import numpy as np

from repro.core import (ModelSelector, TaskFeaturizer, build_tasks,
                        build_zoo, make_task, transfer_matrix)
from repro.engine import MorphingServer, MorphingSession


def main() -> None:
    zoo = build_zoo(16, seed=0)
    history = build_tasks(32, seed=1)
    V = transfer_matrix(zoo, history)
    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in history])
    sel = ModelSelector(k=6, n_anchors=3).fit_offline(V, feats, zoo=zoo)

    sess = MorphingSession(selector=sel, zoo=zoo, model_store="decoupled")
    rng = np.random.default_rng(0)
    n = 3000
    sess.register_table("reviews", {
        "gender": rng.integers(0, 2, n),
        "len": rng.integers(1, 200, n),
        "emb": rng.standard_normal((n, 16)).astype(np.float32)})
    print(sess.sql(
        "CREATE TASK sentiment (INPUT=Series, OUTPUT IN ('POS','NEG'), "
        "TYPE='Classification');"))
    sample = make_task(rng, "gauss", n=128, dim=16, classes=3)

    server = MorphingServer(session=sess, max_wait_s=0.005)
    # partial-load resolution ahead of traffic: the slice is keyed to
    # the sample's width, which matches the reviews.emb schema here
    server.resolve_task("sentiment", sample.X, sample.y, mode="partial")
    with server:
        results = {}

        def client(cid: int) -> None:
            for i in range(6):
                out = server.predict(
                    "PREDICT emb USING TASK sentiment FROM reviews "
                    f"WHERE len > {20 + 10 * (i % 4)}",
                    sample=(sample.X, sample.y), timeout=30.0)
                results[(cid, i)] = out

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    st = server.stats()
    rm = sess.models["sentiment"]
    print(f"(system resolved sentiment -> {rm.model_id}, "
          f"{rm.store} store, mode={rm.load_mode})")
    print(f"served {st.requests} requests / {st.rows} rows in "
          f"{st.batches} batches (x{st.mean_coalesced:.1f} coalesced)")
    print(f"latency p50={st.p50_latency_s * 1e3:.1f}ms "
          f"p95={st.p95_latency_s * 1e3:.1f}ms; "
          f"{st.rows_per_second:.0f} rows/s inference")
    print(f"partial load: {st.loaded_bytes}B read of "
          f"{st.stored_bytes}B stored")
    one = results[(0, 0)]
    print(f"(request {one.req_id}: {one.rows} rows, "
          f"mean score {one.scores.mean():+.4f})")


if __name__ == "__main__":
    main()
