"""Quickstart: the MorphingDB task-centric flow in 60 lines.

  1. Build a model zoo + historical transfer matrix (offline).
  2. Fit the two-phase selector (NMF subspace + feature regressor).
  3. CREATE TASK, resolve it to a model for *your* data, run a query.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (ModelSelector, TaskFeaturizer, TaskRegistry,
                        TaskSpec, build_tasks, build_zoo, make_task,
                        transfer_matrix)
from repro.pipeline import Dag, Node, PipelineExecutor, filter_op, groupby_agg


def main() -> None:
    # ---- offline phase (done once, per §4.2) --------------------------
    zoo = build_zoo(16, seed=0)
    history = build_tasks(32, seed=1)
    V = transfer_matrix(zoo, history)          # historical transfer matrix
    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in history])
    selector = ModelSelector(k=6, n_anchors=3).fit_offline(V, feats, zoo=zoo)
    print(f"offline: |zoo|={len(zoo)} |history|={len(history)} "
          f"NMF recon err={selector.recon_error:.4f}")

    # ---- task-centric declaration (Table 1) ---------------------------
    registry = TaskRegistry(selector=selector, zoo=zoo)
    registry.create_task(TaskSpec(
        name="sentiment_classifier", input_type="series",
        output_labels=("POS", "NEG", "NEU"), kind="classification"))

    # a new, unseen task arrives with sample data
    rng = np.random.default_rng(42)
    task = make_task(rng, "ring", n=200, dim=16, classes=3)
    chosen = registry.resolve("sentiment_classifier", task.X, task.y)
    print(f"online: resolved to zoo model #{chosen} "
          f"({zoo[chosen].name}) in {selector.select(task.X, task.y).online_ms:.1f} ms")

    # ---- declarative query over the resolved task ---------------------
    predict = registry.predict_fn("sentiment_classifier")
    n = 500
    reviews = {"gender": rng.integers(0, 2, n),
               "len": rng.integers(1, 200, n),
               "emb": rng.standard_normal((n, 16)).astype(np.float32)}

    def predict_node(b):
        out = dict(b)
        out["sentiment"] = predict(b["emb"]).mean(axis=1)
        return out

    dag = Dag()
    dag.add(Node("reviews", "scan"))
    dag.add(Node("flt", "filter",
                 fn=lambda b: filter_op(b, lambda x: x["len"] > 20)),
            deps=("reviews",))
    dag.add(Node("pred", "predict", fn=predict_node, cost_hint=5),
            deps=("flt",))
    dag.add(Node("agg", "groupby",
                 fn=lambda b: groupby_agg(b, "gender", "sentiment")),
            deps=("pred",))
    res = PipelineExecutor(dag).execute({"reviews": reviews})
    for g, s in zip(res["agg"]["gender"], res["agg"]["mean_sentiment"]):
        print(f"  gender={g}: avg sentiment {s:+.4f}")


if __name__ == "__main__":
    main()
