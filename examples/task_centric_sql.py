"""Table-1 demo: the task-centric SQL surface, as a thin client of the
query engine (`repro.engine.MorphingSession`).

The paper's two statements:

  CREATE TASK sentiment_classifier (INPUT=Series, OUTPUT IN ('POS','NEG'),
      TYPE='Classification');
  SELECT gender, AVG(sentiment_classifier(emb)) FROM reviews
      WHERE len > 20 GROUP BY gender;

vs. the model-centric equivalent where the user must pick
TextCNNForSentiAnalysisV_2_0 themselves. The session resolves the task to
a model through the transferability subspace, persists it through the
BLOB store + catalog, pre-embeds via the vector-share cache, window-
batches the inference, and streams chunks through the DAG runtime. Run:
  PYTHONPATH=src python examples/task_centric_sql.py
"""
import numpy as np

from repro.core import (ModelSelector, TaskFeaturizer, build_tasks,
                        build_zoo, make_task, transfer_matrix)
from repro.engine import MorphingSession


def main() -> None:
    zoo = build_zoo(16, seed=0)
    history = build_tasks(32, seed=1)
    V = transfer_matrix(zoo, history)
    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in history])
    sel = ModelSelector(k=6, n_anchors=3).fit_offline(V, feats, zoo=zoo)

    db = MorphingSession(selector=sel, zoo=zoo)
    rng = np.random.default_rng(0)
    n = 600
    db.register_table("reviews", {
        "gender": rng.integers(0, 2, n),
        "len": rng.integers(1, 200, n),
        "emb": rng.standard_normal((n, 16)).astype(np.float32)})

    print(db.sql(
        "CREATE TASK sentiment_classifier (INPUT=Series, "
        "OUTPUT IN ('POS','NEG','NEU'), TYPE='Classification');"))

    sample = make_task(rng, "gauss", n=128, dim=16, classes=3)
    res = db.sql(
        "SELECT gender, AVG(sentiment_classifier(emb)) FROM reviews "
        "WHERE len > 20 GROUP BY gender;",
        sample=(sample.X, sample.y))
    rep = res.report
    print(f"(system resolved sentiment_classifier -> "
          f"{rep.resolution['sentiment_classifier']})")
    for g, s in zip(res.rows["gender"], res.rows["mean__score"]):
        print(f"  gender={g}: AVG(sentiment)={s:+.4f}")
    print(f"(plan: {rep.plan})")
    print(f"(rows {rep.rows_in} -> {rep.rows_out}, "
          f"batches={rep.batch_batches}, "
          f"share {rep.share_hits}h/{rep.share_misses}m)")

    # the same query again: pre-embeddings come back from the share cache
    res2 = db.sql(
        "SELECT gender, AVG(sentiment_classifier(emb)) FROM reviews "
        "WHERE len > 20 GROUP BY gender;")
    print(f"(second run share hit rate: "
          f"{res2.report.share_hit_rate:.2f})")


if __name__ == "__main__":
    main()
