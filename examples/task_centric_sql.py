"""Table-1 demo: the task-centric SQL surface, as a thin client of the
query engine (`repro.engine.MorphingSession`).

The paper's two statements:

  CREATE TASK sentiment_classifier (INPUT=Series, OUTPUT IN ('POS','NEG'),
      TYPE='Classification');
  SELECT gender, AVG(sentiment_classifier(emb)) FROM reviews
      WHERE len > 20 GROUP BY gender;

vs. the model-centric equivalent where the user must pick
TextCNNForSentiAnalysisV_2_0 themselves. The session resolves the task to
a model through the transferability subspace, persists it through the
BLOB store + catalog, pre-embeds via the vector-share cache, window-
batches the inference, and streams chunks through the DAG runtime. Run:
  PYTHONPATH=src python examples/task_centric_sql.py

``--delta`` switches to the decoupled store and adds a fine-tune: a
head-delta variant of the system-resolved model is registered
(``register_finetune``), bound to its own task
(``resolve_task(model_id=)``), and queried — its embeddings come
straight from the share cache because fine-tunes of one base share
their trunk identity (docs/architecture.md):
  PYTHONPATH=src python examples/task_centric_sql.py --delta
"""
import argparse

import numpy as np

from repro.core import (ModelSelector, TaskFeaturizer, build_tasks,
                        build_zoo, make_task, transfer_matrix)
from repro.engine import MorphingSession


def main(delta: bool = False) -> None:
    zoo = build_zoo(16, seed=0)
    history = build_tasks(32, seed=1)
    V = transfer_matrix(zoo, history)
    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in history])
    sel = ModelSelector(k=6, n_anchors=3).fit_offline(V, feats, zoo=zoo)

    # fine-tune deltas live in decoupled layer tables; the default demo
    # keeps the BLOB store the paper's Table-1 flow uses
    db = MorphingSession(selector=sel, zoo=zoo,
                         model_store="decoupled" if delta else "blob")
    rng = np.random.default_rng(0)
    n = 600
    db.register_table("reviews", {
        "gender": rng.integers(0, 2, n),
        "len": rng.integers(1, 200, n),
        "emb": rng.standard_normal((n, 16)).astype(np.float32)})

    print(db.sql(
        "CREATE TASK sentiment_classifier (INPUT=Series, "
        "OUTPUT IN ('POS','NEG','NEU'), TYPE='Classification');"))

    sample = make_task(rng, "gauss", n=128, dim=16, classes=3)
    res = db.sql(
        "SELECT gender, AVG(sentiment_classifier(emb)) FROM reviews "
        "WHERE len > 20 GROUP BY gender;",
        sample=(sample.X, sample.y))
    rep = res.report
    print(f"(system resolved sentiment_classifier -> "
          f"{rep.resolution['sentiment_classifier']})")
    for g, s in zip(res.rows["gender"], res.rows["mean__score"]):
        print(f"  gender={g}: AVG(sentiment)={s:+.4f}")
    print(f"(plan: {rep.plan})")
    print(f"(rows {rep.rows_in} -> {rep.rows_out}, "
          f"batches={rep.batch_batches}, "
          f"share {rep.share_hits}h/{rep.share_misses}m)")

    # the same query again: pre-embeddings come back from the share cache
    res2 = db.sql(
        "SELECT gender, AVG(sentiment_classifier(emb)) FROM reviews "
        "WHERE len > 20 GROUP BY gender;")
    print(f"(second run share hit rate: "
          f"{res2.report.share_hit_rate:.2f})")

    if delta:
        # a head-only fine-tune of the resolved model: stored as deltas
        # (unchanged layers are references, the new head a delta file)
        # and served by base+delta composition — the trunk identity is
        # inherited, so even its *first* query hits the share cache
        base = db.models["sentiment_classifier"]
        w = np.abs(rng.standard_normal(base.head_dim)).astype(np.float32)
        w /= w.sum()
        ft_id = f"{base.model_id}-ft0"
        db.register_finetune(ft_id, base.model_id, {"head/w": w})
        print(db.sql(
            "CREATE TASK sentiment_ft (INPUT=Series, "
            "OUTPUT IN ('POS','NEG','NEU'), TYPE='Classification');"))
        rm = db.resolve_task("sentiment_ft", sample.X, sample.y,
                             model_id=ft_id)
        print(f"(fine-tune {ft_id}: {rm.delta_bytes}B of deltas on disk, "
              f"{rm.loaded_bytes}B read at resolve, shares trunk "
              f"{rm.trunk_fp == base.trunk_fp})")
        res3 = db.sql(
            "SELECT gender, AVG(sentiment_ft(emb)) FROM reviews "
            "WHERE len > 20 GROUP BY gender;")
        for g, s in zip(res3.rows["gender"], res3.rows["mean__score"]):
            print(f"  gender={g}: AVG(sentiment_ft)={s:+.4f}")
        print(f"(fine-tune first-query share hit rate: "
              f"{res3.report.share_hit_rate:.2f}, "
              f"delta bytes in report: {res3.report.delta_bytes})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--delta", action="store_true",
                    help="add a fine-tune delta variant sharing the "
                         "base trunk's cached embeddings")
    main(delta=ap.parse_args().delta)
