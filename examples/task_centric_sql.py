"""Table-1 demo: the task-centric SQL surface.

A minimal SQL-ish parser for the paper's two statements:

  CREATE TASK sentiment_classifier (INPUT=Series, OUTPUT IN ('POS','NEG'),
      TYPE='Classification');
  SELECT gender, AVG(sentiment_classifier(emb)) FROM reviews
      WHERE len > 20 GROUP BY gender;

vs. the model-centric equivalent where the user must pick
TextCNNForSentiAnalysisV_2_0 themselves. Run:
  PYTHONPATH=src python examples/task_centric_sql.py
"""
import re

import numpy as np

from repro.core import (ModelSelector, TaskFeaturizer, TaskRegistry,
                        TaskSpec, build_tasks, build_zoo, make_task,
                        transfer_matrix)
from repro.pipeline import Dag, Node, PipelineExecutor, filter_op, groupby_agg

CREATE_RE = re.compile(
    r"CREATE\s+TASK\s+(\w+)\s*\(\s*INPUT\s*=\s*(\w+)\s*,\s*OUTPUT\s+IN\s*"
    r"\(([^)]*)\)\s*,\s*TYPE\s*=\s*'(\w+)'\s*\)", re.I)
SELECT_RE = re.compile(
    r"SELECT\s+(\w+)\s*,\s*AVG\(\s*(\w+)\((\w+)\)\s*\)\s+FROM\s+(\w+)"
    r"(?:\s+WHERE\s+(\w+)\s*>\s*(\d+))?\s+GROUP\s+BY\s+(\w+)", re.I)


class MiniSQL:
    """Executes the paper's task-centric statements over columnar tables."""

    def __init__(self, registry: TaskRegistry):
        self.registry = registry
        self.tables = {}

    def register_table(self, name, table):
        self.tables[name] = table

    def execute(self, sql: str, sample=None):
        sql = sql.strip().rstrip(";")
        m = CREATE_RE.match(sql)
        if m:
            name, inp, outs, kind = m.groups()
            labels = tuple(s.strip().strip("'\"")
                           for s in outs.split(","))
            self.registry.create_task(TaskSpec(name, inp.lower(), labels,
                                               kind.lower()))
            return f"TASK {name} CREATED"
        m = SELECT_RE.match(sql)
        if m:
            group_col, task, col, table, wcol, wval, gcol2 = m.groups()
            if task not in [t.name for t in self.registry.list_tasks()]:
                raise ValueError(f"unknown task {task}")
            if sample is not None:
                self.registry.resolve(task, *sample)
            predict = self.registry.predict_fn(task)
            tbl = self.tables[table]

            def predict_node(b):
                out = dict(b)
                out["_score"] = predict(b[col]).mean(axis=1)
                return out

            dag = Dag()
            dag.add(Node(table, "scan"))
            prev = table
            if wcol:
                dag.add(Node("where", "filter",
                             fn=lambda b: filter_op(
                                 b, lambda x: x[wcol] > int(wval))),
                        deps=(prev,))
                prev = "where"
            dag.add(Node("pred", "predict", fn=predict_node, cost_hint=5),
                    deps=(prev,))
            dag.add(Node("agg", "groupby",
                         fn=lambda b: groupby_agg(b, group_col, "_score")),
                    deps=("pred",))
            res = PipelineExecutor(dag).execute({table: tbl})
            return res["agg"]
        raise ValueError(f"unsupported statement: {sql[:50]}")


def main() -> None:
    zoo = build_zoo(16, seed=0)
    history = build_tasks(32, seed=1)
    V = transfer_matrix(zoo, history)
    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in history])
    sel = ModelSelector(k=6, n_anchors=3).fit_offline(V, feats, zoo=zoo)
    db = MiniSQL(TaskRegistry(selector=sel, zoo=zoo))

    rng = np.random.default_rng(0)
    n = 600
    db.register_table("reviews", {
        "gender": rng.integers(0, 2, n),
        "len": rng.integers(1, 200, n),
        "emb": rng.standard_normal((n, 16)).astype(np.float32)})

    print(db.execute(
        "CREATE TASK sentiment_classifier (INPUT=Series, "
        "OUTPUT IN ('POS','NEG','NEU'), TYPE='Classification');"))

    sample = make_task(rng, "gauss", n=128, dim=16, classes=3)
    out = db.execute(
        "SELECT gender, AVG(sentiment_classifier(emb)) FROM reviews "
        "WHERE len > 20 GROUP BY gender;",
        sample=(sample.X, sample.y))
    chosen = db.registry._resolution["sentiment_classifier"]
    print(f"(system resolved sentiment_classifier -> {zoo[chosen].name})")
    for g, s in zip(out["gender"], out["mean__score"]):
        print(f"  gender={g}: AVG(sentiment)={s:+.4f}")


if __name__ == "__main__":
    main()
