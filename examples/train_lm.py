"""End-to-end training driver: a ~15M-param gemma-family LM trained for a
few hundred steps on the synthetic Markov corpus, with async checkpointing,
a simulated mid-run preemption (restart from checkpoint), and loss curve.

Run:  PYTHONPATH=src python examples/train_lm.py  (~2-4 min on CPU)
"""
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticCorpus
from repro.models import build_model
from repro.storage import CheckpointManager
from repro.training import OptimizerConfig, init_state, make_train_step
from repro.training.fault import TrainController


def main(steps: int = 250) -> None:
    cfg = smoke_config("gemma-2b").replace(
        num_layers=4, d_model=256, d_ff=512, vocab_size=512,
        num_heads=4, head_dim=64)
    print(f"arch={cfg.arch_id}(reduced) params="
          f"{cfg.param_count() / 1e6:.1f}M")
    model = build_model(cfg, attn_impl="naive")
    opt_cfg = OptimizerConfig(learning_rate=3e-3, warmup_steps=20,
                              total_steps=steps, weight_decay=0.01)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=128, global_batch=8, seed=3,
                                      branching=4))

    params = model.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    losses = []
    fail_once = {steps // 2}

    def one_step(state, step):
        if step in fail_once:          # simulated preemption mid-run
            fail_once.clear()
            raise RuntimeError("simulated host preemption")
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        p, o, out = step_fn(p, o, batch)
        losses.append((step, float(out["loss"])))
        if step % 25 == 0:
            print(f"  step {step:4d}: loss {out['loss']:.4f}")
        return (p, o)

    with tempfile.TemporaryDirectory() as td:
        ckpt = CheckpointManager(Path(td) / "ck")
        tc = TrainController(one_step, ckpt, ckpt_every=50)
        t0 = time.time()
        state, step = tc.run((params, opt), steps)
        dt = time.time() - t0
    first = losses[0][1]
    last = losses[-1][1]
    events = [k for k, _ in tc.events]
    print(f"{step} steps in {dt:.0f}s; loss {first:.3f} -> {last:.3f} "
          f"(drop {first - last:.3f}); events: "
          f"failures={events.count('failure')} "
          f"restarts={events.count('restart')} "
          f"checkpoints={events.count('checkpoint')}")
    assert last < first - 0.5, "model must learn the bigram structure"


if __name__ == "__main__":
    main()
