#!/usr/bin/env python
"""Docs gate: keep ``docs/*.md`` + ``README.md`` honest.

Two checks, both run by the CI ``docs`` job:

- **links** (always): every relative markdown link must point at an
  existing file, and every ``#anchor`` (in-page or cross-page) must
  match a real heading in the target, using GitHub's slug rules.
  External ``http(s)://`` links are not fetched (no network in CI gates)
  — keep external references few and stable.
- **quickstart** (``--quickstart``): fenced code blocks whose info
  string contains ``quickstart`` (e.g. :literal:`\\`\\`\\`python quickstart`)
  are executed with ``PYTHONPATH=src``, so the examples the docs open
  with cannot rot. A failing snippet fails the job with its output.

Usage::

    python scripts/check_docs.py [--quickstart] [paths...]
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from pathlib import Path
from typing import Dict, List, Tuple

REPO = Path(__file__).resolve().parent.parent
DEFAULT_PATHS = ("README.md", "docs")

_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
_FENCE_RE = re.compile(r"^(```+|~~~+)(.*)$")


def _slugify(heading: str, seen: Dict[str, int]) -> str:
    """GitHub-style heading slug: lowercase, drop punctuation, spaces to
    hyphens, numeric suffix for duplicates."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)     # strip inline code
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # link text only
    slug = re.sub(r"[^\w\- ]", "", text.lower(), flags=re.UNICODE)
    slug = slug.replace(" ", "-")
    n = seen.get(slug, 0)
    seen[slug] = n + 1
    return slug if n == 0 else f"{slug}-{n}"


def _parse(path: Path) -> Tuple[List[Tuple[int, str]], List[str],
                                List[Tuple[str, str]]]:
    """(links, anchors, quickstart blocks) of one markdown file. Links
    inside fenced code blocks are ignored; fences tagged ``quickstart``
    are collected for execution."""
    links: List[Tuple[int, str]] = []
    anchors: List[str] = []
    blocks: List[Tuple[str, str]] = []      # (info, code)
    seen: Dict[str, int] = {}
    fence, info, code = None, "", []
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        m = _FENCE_RE.match(line.strip())
        if m:
            tok = m.group(1)
            if fence is None:
                fence, info, code = tok, m.group(2).strip(), []
                continue
            # a closing fence uses the same character, is at least as
            # long as the opener, and carries no info string — anything
            # shorter (e.g. ``` inside a ```` block) is content
            if (tok[0] == fence[0] and len(tok) >= len(fence)
                    and not m.group(2).strip()):
                if "quickstart" in info.split():
                    blocks.append((info, "\n".join(code)))
                fence = None
                continue
        if fence is not None:
            code.append(line)
            continue
        h = _HEADING_RE.match(line)
        if h:
            anchors.append(_slugify(h.group(2), seen))
        for lm in _LINK_RE.finditer(line):
            links.append((lineno, lm.group(1)))
    return links, anchors, blocks


def check_docs(paths: List[Path], run_quickstart: bool) -> List[str]:
    files = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.glob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            return [f"{p}: documentation file missing"]
    parsed = {f: _parse(f) for f in files}
    anchors_of: Dict[Path, List[str]] = {
        f.resolve(): p[1] for f, p in parsed.items()}
    failures: List[str] = []
    n_links = 0
    for f, (links, _own_anchors, _blocks) in parsed.items():
        for lineno, target in links:
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            n_links += 1
            ref, _, frag = target.partition("#")
            dest = (f.resolve() if not ref
                    else (f.parent / ref).resolve())
            if not dest.exists():
                failures.append(f"{f}:{lineno}: broken link -> {target}")
                continue
            if frag:
                anchs = anchors_of.get(dest)
                if anchs is None and dest.suffix == ".md":
                    anchs = _parse(dest)[1]
                    anchors_of[dest] = anchs
                if anchs is not None and frag not in anchs:
                    failures.append(
                        f"{f}:{lineno}: broken anchor -> {target} "
                        f"(have: {', '.join(anchs)})")
    print(f"checked {n_links} relative links across {len(files)} files")
    if run_quickstart:
        env = dict(os.environ)
        env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        n_blocks = 0
        for f, (_l, _a, blocks) in parsed.items():
            for info, code in blocks:
                n_blocks += 1
                proc = subprocess.run(
                    [sys.executable, "-"], input=code, text=True,
                    capture_output=True, env=env, cwd=REPO, timeout=300)
                if proc.returncode != 0:
                    failures.append(
                        f"{f}: quickstart block ({info}) failed:\n"
                        f"{proc.stdout}{proc.stderr}")
                else:
                    print(f"quickstart OK: {f} ({info})")
        if n_blocks == 0:
            failures.append("no quickstart blocks found: the docs job "
                            "expects at least one executable example")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help="markdown files/dirs (default: README.md docs/)")
    ap.add_argument("--quickstart", action="store_true",
                    help="also execute fenced blocks tagged 'quickstart'")
    args = ap.parse_args(argv)
    paths = [Path(p) for p in (args.paths or DEFAULT_PATHS)]
    failures = check_docs(paths, run_quickstart=args.quickstart)
    if failures:
        print("\ndocs gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("docs gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
