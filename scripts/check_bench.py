#!/usr/bin/env python
"""Bench-regression gate: compare fresh BENCH_*.json artifacts against
committed baselines and fail CI when the perf trajectory regresses.

Rules (applied recursively over the baseline's JSON tree):

- any metric named ``rows_per_s*`` / ``*rows_per_s_warm`` is
  higher-is-better: the fresh value must stay above ``(1 - threshold)``
  of the baseline (default threshold 0.25, i.e. a >25% warm-rows/s
  regression fails). ``speedup_*`` ratios are not gated here — the
  benches assert their own speedup targets;
- any metric named ``compile_count`` must not grow: more jit compiles
  for the same workload means shape bucketing regressed;
- any metric named ``*p95_latency_ms`` is lower-is-better: the fresh
  value must stay below ``(1 + threshold)`` of the baseline (tail
  latency is a serving contract, not just a throughput side effect);
- metrics present in the baseline but missing from the fresh run fail
  (a silently dropped metric is a regression of the bench itself).

Baselines live in ``benchmarks/baselines/`` and are regenerated with the
same CLI the CI smoke uses; refresh them deliberately (commit the new
JSON) when a PR moves the expected numbers. Record throughput baselines
from a *median* run (their floor already grants -25%), but tail-latency
baselines from the *max* over several runs: a p95 baseline defines a
ceiling contract, and seeding it with one lucky scheduler draw turns
ordinary machine noise into gate failures.

Usage::

    python scripts/check_bench.py \
        --pair BENCH_engine.json=benchmarks/baselines/BENCH_engine.json \
        --pair BENCH_serving.json=benchmarks/baselines/BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Iterator, List, NamedTuple, Optional, Tuple

DEFAULT_THRESHOLD = 0.25


class Violation(NamedTuple):
    """One gate violation; every violation found is collected and
    reported in a single metric/actual/limit table before the non-zero
    exit (a run never stops at the first failure)."""
    artifact: str
    metric: str
    rule: str                        # '>= floor' | '<= ceiling' | ...
    actual: Optional[float]          # None = metric missing from fresh
    limit: Optional[float]
    baseline: Optional[float]

    def row(self) -> Tuple[str, str, str, str, str, str]:
        fmt = (lambda v: "missing" if v is None else f"{v:,.2f}")
        return (self.artifact, self.metric, self.rule, fmt(self.actual),
                fmt(self.limit), fmt(self.baseline))


_TABLE_HEADER = ("artifact", "metric", "rule", "actual", "limit",
                 "baseline")


def render_violations(violations: List["Violation"]) -> str:
    """Aligned table of every violation (written to stderr on failure)."""
    rows = [_TABLE_HEADER] + [v.row() for v in violations]
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
             for r in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)

# higher-is-better throughput metrics (suffix match on the key). The
# speedup_* ratios are deliberately NOT gated: a ratio of two noisy
# measurements amplifies noise, and the speedup properties themselves
# are asserted inside the benches (bench_engine's jit target,
# bench_serving's 2x serving target).
_HIGHER_BETTER = ("rows_per_s", "rows_per_s_warm")
# cold numbers include compile time and are too noisy to gate on
_SKIP = ("rows_per_s_cold", "naive_rows_per_s")
# lower-is-better tail-latency metrics (p50 is deliberately ungated: the
# median moves with coalescing-window tuning, the tail is the contract)
_LOWER_BETTER = ("p95_latency_ms",)


def _walk(tree: dict, prefix: str = "") -> Iterator[Tuple[str, float]]:
    for key, val in tree.items():
        path = f"{prefix}.{key}" if prefix else key
        if isinstance(val, dict):
            yield from _walk(val, path)
        elif isinstance(val, (int, float)) and not isinstance(val, bool):
            yield path, float(val)


def _lookup(tree: dict, path: str):
    node = tree
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def check_pair(fresh: dict, baseline: dict, threshold: float,
               label: str) -> List[Violation]:
    violations: List[Violation] = []
    for path, base_val in _walk(baseline):
        key = path.rsplit(".", 1)[-1]
        fresh_val = _lookup(fresh, path)
        if key.endswith(_SKIP):
            continue
        if key.endswith(_HIGHER_BETTER):
            floor = base_val * (1.0 - threshold)
            if fresh_val is None:
                violations.append(Violation(label, path, ">= floor",
                                            None, floor, base_val))
                continue
            status = "OK" if fresh_val >= floor else "FAIL"
            print(f"[{status}] {label}:{path} fresh={fresh_val:.1f} "
                  f"baseline={base_val:.1f} floor={floor:.1f}")
            if fresh_val < floor:
                violations.append(Violation(label, path, ">= floor",
                                            fresh_val, floor, base_val))
        elif key.endswith(_LOWER_BETTER):
            ceil = base_val * (1.0 + threshold)
            if fresh_val is None:
                violations.append(Violation(label, path, "<= ceiling",
                                            None, ceil, base_val))
                continue
            status = "OK" if fresh_val <= ceil else "FAIL"
            print(f"[{status}] {label}:{path} fresh={fresh_val:.2f} "
                  f"baseline={base_val:.2f} ceiling={ceil:.2f}")
            if fresh_val > ceil:
                violations.append(Violation(label, path, "<= ceiling",
                                            fresh_val, ceil, base_val))
        elif key == "compile_count":
            if fresh_val is None:
                violations.append(Violation(label, path, "no growth",
                                            None, base_val, base_val))
                continue
            status = "OK" if fresh_val <= base_val else "FAIL"
            print(f"[{status}] {label}:{path} fresh={fresh_val:.0f} "
                  f"baseline={base_val:.0f} (must not grow)")
            if fresh_val > base_val:
                violations.append(Violation(label, path, "no growth",
                                            fresh_val, base_val,
                                            base_val))
    return violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pair", action="append", required=True,
                    metavar="FRESH=BASELINE",
                    help="fresh artifact and committed baseline "
                         "(repeatable)")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="allowed fractional rows/s regression "
                         "(default 0.25)")
    args = ap.parse_args(argv)
    violations: List[Violation] = []
    for pair in args.pair:
        fresh_path, _, base_path = pair.partition("=")
        if not base_path:
            ap.error(f"--pair must be FRESH=BASELINE, got {pair!r}")
        label = Path(fresh_path).name
        try:
            fresh = json.loads(Path(fresh_path).read_text())
        except FileNotFoundError:
            violations.append(Violation(label, "(artifact)",
                                        "file exists", None, None, None))
            continue
        baseline = json.loads(Path(base_path).read_text())
        violations.extend(check_pair(fresh, baseline, args.threshold,
                                     label))
    if violations:
        print(f"\nbench-regression gate FAILED "
              f"({len(violations)} violation(s)):\n", file=sys.stderr)
        print(render_violations(violations), file=sys.stderr)
        return 1
    print("\nbench-regression gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
