"""Per-kernel allclose sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,Hq,Hkv,S,D,bq,bk", [
    (1, 2, 1, 128, 32, 64, 64),
    (2, 4, 2, 256, 64, 64, 128),
    (1, 8, 8, 256, 16, 128, 64),   # MHA (no GQA)
    (2, 8, 1, 128, 64, 64, 64),    # MQA
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 96)])
def test_flash_attention_sweep(dtype, B, Hq, Hkv, S, D, bq, bk, causal,
                               window):
    rng = jax.random.PRNGKey(B * 13 + S)
    q = jax.random.normal(rng, (B, Hq, S, D), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, S, D),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, S, D),
                          jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - want.astype(jnp.float32)).max())
    assert err < _tol(dtype), err


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,Hq,Hkv,S,D,bk", [
    (2, 8, 2, 512, 64, 128),
    (1, 4, 4, 256, 32, 64),
    (3, 16, 2, 384, 16, 128),
])
@pytest.mark.parametrize("length_frac", [1.0, 0.6, 0.1])
def test_decode_attention_sweep(dtype, B, Hq, Hkv, S, D, bk, length_frac):
    rng = jax.random.PRNGKey(S)
    q = jax.random.normal(rng, (B, Hq, D), jnp.float32).astype(dtype)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, S, D),
                           jnp.float32).astype(dtype)
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, S, D),
                           jnp.float32).astype(dtype)
    L = max(1, int(S * length_frac))
    out = ops.decode_attention(q, kc, vc, L, block_k=bk, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, L)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - want.astype(jnp.float32)).max())
    assert err < _tol(dtype), err


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("N,D,br", [(256, 512, 64), (512, 1024, 256),
                                    (128, 384, 128)])
def test_rmsnorm_sweep(dtype, N, D, br):
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D),
                          jnp.float32).astype(dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (D,)) * 0.1)
    out = ops.rmsnorm(x, w, block_rows=br, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - want.astype(jnp.float32)).max())
    assert err < _tol(dtype), err


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("N,D,K,br", [(256, 128, 64, 64), (512, 64, 32, 128)])
@pytest.mark.parametrize("mean,scale", [(0.0, 1.0), (0.5, 2.0)])
def test_fused_embed_sweep(dtype, N, D, K, br, mean, scale):
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D),
                          jnp.float32).astype(dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (D, K)) * 0.05)
    out = ops.fused_embed(x, w, mean=mean, scale=scale, block_rows=br,
                          interpret=True)
    want = ref.fused_embed_ref(x, w, mean, scale)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - want.astype(jnp.float32)).max())
    assert err < _tol(dtype), err


@pytest.mark.parametrize("N", [1, 100, 300, 511])
def test_fused_embed_ragged_rows(N):
    """Row counts not divisible by the block size (ragged final table
    chunks) must pad internally and slice, not assert."""
    x = jax.random.normal(jax.random.PRNGKey(2), (N, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 32)) * 0.05
    out = ops.fused_embed(x, w, block_rows=256, interpret=True)
    want = ref.fused_embed_ref(x, w)
    assert out.shape == (N, 32)
    err = float(jnp.abs(out - want).max())
    assert err < _tol(jnp.float32), err


def test_fused_embed_zero_rows():
    x = jnp.zeros((0, 16), jnp.float32)
    w = jnp.ones((16, 8), jnp.float32)
    out = ops.fused_embed(x, w, interpret=True)
    assert out.shape == (0, 8)
