"""Fallback shim so the suite collects without the optional ``hypothesis``
dependency.

When the real package is installed this module is a no-op. Otherwise it
installs a tiny deterministic stand-in into ``sys.modules`` that supports
the subset the tests use: ``@given`` over ``integers`` / ``lists`` /
``sampled_from`` / ``floats`` / ``booleans`` strategies and a pass-through
``@settings``. Each ``@given`` test runs a fixed number of seeded examples
(default 10, capped by ``settings(max_examples=...)``) — less thorough
than real property testing, but the invariants still get exercised.
"""
from __future__ import annotations

import random
import sys
import types


def _install_shim() -> None:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    def integers(min_value=0, max_value=100):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)

    def given(*strategies, **kw_strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 10))
                for i in range(min(n, 10)):
                    rng = random.Random(0xC0FFEE + i * 7919)
                    drawn = tuple(s.example(rng) for s in strategies)
                    drawn_kw = {k: s.example(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=10, **_kw):
        def deco(fn):
            target = fn
            # applied above @given: stash the budget on the inner fn too
            target._shim_max_examples = max_examples
            return target
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.lists = lists
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    _install_shim()
