"""Checkpoint manager: atomic commit, async saves, GC, elastic restore."""
import numpy as np
import pytest

from repro.storage import CheckpointManager


@pytest.fixture
def state():
    rng = np.random.default_rng(1)
    return {"p": rng.standard_normal((12, 6)).astype(np.float32),
            "opt": {"m": rng.standard_normal((12, 6)).astype(np.float32)},
            "step": np.int32(5)}


def test_save_restore(tmp_path, state):
    cm = CheckpointManager(tmp_path)
    cm.save(10, state, num_shards=3)
    got, step = cm.restore(state)
    assert step == 10
    np.testing.assert_array_equal(got["p"], state["p"])
    np.testing.assert_array_equal(got["opt"]["m"], state["opt"]["m"])


def test_async_and_gc(tmp_path, state):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        cm.save_async(s, state)
        cm.wait()
    assert cm.all_steps() == [3, 4]  # GC keeps last 2


def test_uncommitted_invisible(tmp_path, state):
    cm = CheckpointManager(tmp_path)
    cm.save(7, state)
    # simulate crash: remove COMMIT marker
    (cm._step_dir(7) / "COMMIT").unlink()
    assert cm.latest_step() is None
    with pytest.raises(FileNotFoundError):
        cm.restore(state)


@pytest.mark.parametrize("save_shards,hosts", [(4, 2), (2, 3), (1, 4),
                                               (3, 3)])
def test_elastic_reshard(tmp_path, state, save_shards, hosts):
    """Restore onto a different host count than the save used."""
    cm = CheckpointManager(tmp_path)
    cm.save(1, state, num_shards=save_shards)
    rows = state["p"].shape[0]
    got_rows = []
    for h in range(hosts):
        lo = rows * h // hosts
        hi = rows * (h + 1) // hosts
        tpl = {"p": state["p"][lo:hi], "opt": {"m": state["opt"]["m"][lo:hi]},
               "step": state["step"]}
        part, _ = cm.restore(tpl, shard=h, num_hosts=hosts)
        np.testing.assert_array_equal(part["p"], state["p"][lo:hi])
        got_rows.append(part["p"])
    np.testing.assert_array_equal(np.concatenate(got_rows), state["p"])
