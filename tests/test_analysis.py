"""Analysis layer: jaxpr FLOP counting + loop-aware HLO cost parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_cost import HloModule, analyze_hlo, shape_bytes
from repro.analysis.jaxpr_flops import count_flops, flops_of


def test_dot_general_flops_exact():
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    assert flops_of(f, a, b) == 2 * 64 * 32 * 16


def test_batched_dot_flops():
    f = lambda a, b: jnp.einsum("bij,bjk->bik", a, b)
    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    assert flops_of(f, a, b) == 2 * 4 * 8 * 16 * 32


def test_scan_multiplies_flops():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
    assert flops_of(f, x, w) == 7 * 2 * 32 ** 3


def test_remat_counts_recompute():
    def f(x, w):
        g = jax.checkpoint(lambda x: jnp.tanh(x @ w))
        return jax.grad(lambda x: g(x).sum())(x).sum()
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    flops = flops_of(f, x, w)
    # fwd + remat-fwd + bwd-dx (no dw: w is closed over) = 3 matmuls
    assert flops == 3 * 2 * 16 ** 3


def test_ragged_dot_counted_once():
    def f(lhs, rhs, gs):
        return jax.lax.ragged_dot(lhs, rhs, gs)
    lhs = jax.ShapeDtypeStruct((64, 8), jnp.float32)
    rhs = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    gs = jax.ShapeDtypeStruct((4,), jnp.int32)
    # 2*m*k*n regardless of group count
    assert flops_of(f, lhs, rhs, gs) == 2 * 64 * 8 * 16


def test_shape_bytes():
    assert shape_bytes("bf16[4,8]{1,0}") == 64
    assert shape_bytes("f32[10]") == 40
    assert shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert shape_bytes("pred[]") == 1


def test_hlo_loop_trip_and_collectives():
    hlo = """
HloModule test

%body (p: (s32[], f32[8]{0})) -> (s32[], f32[8]{0}) {
  %p = (s32[], f32[8]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8]{0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[8]{0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8]{0})) -> pred[] {
  %p = (s32[], f32[8]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8]{0}) -> f32[8]{0} {
  %a = f32[8]{0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8]{0}) tuple(%zero, %a)
  %w = (s32[], f32[8]{0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    hc = analyze_hlo(hlo)
    assert hc.loop_trip_counts == [5]
    assert hc.collective_counts["all-reduce"] == 5.0
    # raw f32 payload; charged at bf16 rate (jax-level dtype correction)
    assert hc.collective_operand_bytes_raw["all-reduce"] == 5 * 32
    assert hc.collective_operand_bytes["all-reduce"] == 5 * 16


def test_real_compiled_scan_cost():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)).compile()
    hc = analyze_hlo(c.as_text())
    assert hc.loop_trip_counts == [6]
    assert hc.dot_flops == 6 * 2 * 128 ** 3
