"""Roofline report aggregation over real dry-run artifacts."""
from pathlib import Path

import pytest

from repro.analysis.report import load_records, markdown_table, roofline_row

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

pytestmark = pytest.mark.skipif(not ART.exists(),
                                reason="run the dry-run sweep first")


def test_all_cells_present():
    singles = load_records(ART, "single")
    multis = load_records(ART, "multi")
    assert len(singles) == 33  # 10 archs x shapes, minus 7 long_500k skips
    assert len(multis) == 33
    archs = {r["arch"] for r in singles}
    assert len(archs) == 10


def test_rows_well_formed():
    for rec in load_records(ART, "single"):
        row = roofline_row(rec)
        assert row["dominant"] in ("compute", "memory", "collective")
        assert 0.0 <= row["roofline_fraction"] <= 1.0
        assert row["compute_s"] >= 0 and row["memory_s"] > 0
        # per-brief record contents
        assert rec["memory_analysis"]["argument_size_in_bytes"] > 0
        assert rec["collectives"]["collective_counts"], rec["arch"]


def test_multi_pod_shards_the_pod_axis():
    """Multi-pod per-device terms must drop vs single pod for train."""
    singles = {(r["arch"], r["shape"]): r
               for r in load_records(ART, "single")}
    multis = {(r["arch"], r["shape"]): r
              for r in load_records(ART, "multi")}
    for key, s in singles.items():
        if key[1] != "train_4k":
            continue
        m = multis[key]
        assert m["flops_per_device"] < s["flops_per_device"] * 0.6, key
        assert (m["memory_analysis"]["argument_size_in_bytes"]
                < s["memory_analysis"]["argument_size_in_bytes"] * 0.75), key


def test_markdown_table_renders():
    rows = [roofline_row(r) for r in load_records(ART, "single")]
    md = markdown_table(rows)
    assert md.count("|") > 100 and "dominant" in md
