"""Data pipeline determinism + serving-engine components."""
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticCorpus
from repro.pipeline import ContinuousBatcher, OpProfile, Request


def test_synthetic_corpus_deterministic_resume():
    """batch(step) is pure: a 'restarted' loader yields identical data."""
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=4, seed=9)
    a = SyntheticCorpus(cfg)
    b = SyntheticCorpus(cfg)  # fresh process after restart
    for step in (0, 7, 123):
        np.testing.assert_array_equal(a.batch(step)["tokens"],
                                      b.batch(step)["tokens"])


def test_synthetic_corpus_host_sharding():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=1)
    c = SyntheticCorpus(cfg)
    h0 = c.batch(3, host=0, num_hosts=4)["tokens"]
    h1 = c.batch(3, host=1, num_hosts=4)["tokens"]
    assert h0.shape == (2, 32)
    assert not np.array_equal(h0, h1)  # hosts see different data


def test_synthetic_corpus_has_structure():
    """Markov structure: successor tokens come from the bigram table far
    more often than chance."""
    cfg = DataConfig(vocab_size=1024, seq_len=256, global_batch=4, seed=2,
                     order_mix=0.8, branching=4)
    c = SyntheticCorpus(cfg)
    toks = c.batch(0)["tokens"]
    hits = 0
    total = 0
    for row in toks:
        for t in range(1, len(row)):
            total += 1
            if row[t] in c._succ[row[t - 1]]:
                hits += 1
    assert hits / total > 0.5  # chance would be ~4/1024


def test_continuous_batcher_serves_all():
    prof = OpProfile(flops_per_row=1e5, bytes_per_row=128, model_bytes=1e6)
    calls = []

    def step(payloads):
        calls.append(len(payloads))
        return [p * 2 for p in payloads]

    cb = ContinuousBatcher(step, prof, device="host", max_wait_s=0.001)
    for i in range(40):
        cb.submit(Request(i, float(i)))
    res = cb.run(total=40)
    assert len(res) == 40
    assert all(res[i] == 2.0 * i for i in range(40))
    assert max(calls) > 1  # actually batched
    assert len(cb.latencies) == 40
