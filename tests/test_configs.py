"""Config registry + parameter accounting."""
import pytest

from repro.configs import (ALL_SHAPES, get_config, list_archs, shapes_for,
                           smoke_config)

EXPECTED_PARAMS_B = {
    "llama3-405b": (390, 420),
    "gemma-2b": (2.0, 3.0),
    "granite-3-8b": (7.0, 9.0),
    "h2o-danube-1.8b": (1.5, 2.1),
    "mamba2-370m": (0.3, 0.5),
    "recurrentgemma-9b": (7.5, 10.0),
    "chameleon-34b": (32, 36),
    "whisper-medium": (0.3, 0.8),
    "olmoe-1b-7b": (6.0, 7.5),
    "kimi-k2-1t-a32b": (950, 1100),
}

EXPECTED_ACTIVE_B = {"olmoe-1b-7b": (1.0, 1.6), "kimi-k2-1t-a32b": (28, 36)}


def test_all_archs_registered():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch", list_archs())
def test_param_counts_match_public_numbers(arch):
    cfg = get_config(arch)
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = cfg.param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n}B outside [{lo},{hi}]"


@pytest.mark.parametrize("arch", sorted(EXPECTED_ACTIVE_B))
def test_active_params_moe(arch):
    cfg = get_config(arch)
    lo, hi = EXPECTED_ACTIVE_B[arch]
    n = cfg.active_param_count() / 1e9
    assert lo <= n <= hi


@pytest.mark.parametrize("arch", list_archs())
def test_shapes_and_long_context_rule(arch):
    cfg = get_config(arch)
    names = [s.name for s in shapes_for(cfg)]
    assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)
    if arch in ("mamba2-370m", "recurrentgemma-9b", "h2o-danube-1.8b"):
        assert "long_500k" in names, "sub-quadratic arch must run long_500k"
    else:
        assert "long_500k" not in names, "full-attention arch must skip it"


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_config_small(arch):
    cfg = smoke_config(arch)
    assert cfg.d_model <= 256 and cfg.param_count() < 5e7


@pytest.mark.parametrize("arch", list_archs())
def test_vocab_padding(arch):
    cfg = get_config(arch)
    assert cfg.padded_vocab % 256 == 0
    assert 0 <= cfg.padded_vocab - cfg.vocab_size < 256
