"""Placement-aware backend pool + mesh-parallel trunk embed lanes.

Two tiers:

- in-process: the pool's dict-compatibility with the old registry, the
  single-device fallback (``devices=1`` must be byte-identical in
  results *and* telemetry to the pre-pool path), and the device-count
  clamp when jax exposes fewer devices than asked for;
- subprocess (``_run``): real 2-device behavior under
  ``--xla_force_host_platform_device_count=2`` — jax fixes the device
  topology at first import, so simulated devices cannot be created
  after the test process has imported jax.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core.zoo import ZooModel
from repro.pipeline.backend import (BackendPool, JaxBackend, InferSpec,
                                    MeshJaxBackend, NumpyBackend,
                                    make_backends)
from repro.pipeline.batcher import BatcherStats
from repro.pipeline.cost import HardwareProfile, calibrate

REPO = Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 2) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def _zoo_model(mode: str, rng, in_dim: int = 16, width: int = 24) -> ZooModel:
    kw = {}
    if mode == "radial":
        kw = dict(centers=rng.standard_normal((8, in_dim))
                  .astype(np.float32), sigma=1.3)
    return ZooModel(name=f"zm_{mode}", source_family="gauss",
                    W=rng.standard_normal((in_dim, width))
                    .astype(np.float32), mode=mode, **kw)


def _spec(zm: ZooModel, version: str) -> InferSpec:
    class _RM:
        zoo_model = zm
        features = staticmethod(zm.features)
        head = staticmethod(lambda F: np.asarray(F).mean(axis=1))
        head_kind = "mean"
    return InferSpec(kind="embed", task="t", col="x", out="f",
                     table="tb", version=version, model=_RM(),
                     stats=BatcherStats())


# -- the pool is a drop-in registry ----------------------------------------

def test_pool_is_dict_compatible_registry():
    pool = make_backends("auto")
    assert isinstance(pool, dict) and isinstance(pool, BackendPool)
    assert pool.device_count == 1 and pool.mesh is None
    assert isinstance(pool["host"], NumpyBackend)
    assert isinstance(pool["tpu"], JaxBackend)
    assert not isinstance(pool["tpu"], MeshJaxBackend)
    assert set(pool) == {"host", "tpu"}
    assert isinstance(pool.backend_for("nonexistent"), NumpyBackend)
    assert len(pool.distinct()) == 2


def test_pool_numpy_kind_never_meshes():
    pool = make_backends("numpy", device_count=4)
    assert pool.device_count == 1 and pool.mesh is None
    assert all(isinstance(b, NumpyBackend) for b in pool.values())


def test_pool_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown backend kind"):
        make_backends("torch")


def test_pool_clamps_to_available_devices():
    """Asking for a wider mesh than jax exposes degrades gracefully: in
    a single-device process the pool must fall back to the plain
    single-device backend (no mesh), not fail."""
    import jax
    if len(jax.devices()) > 1:
        pytest.skip("process has real multi-device jax")
    pool = make_backends("jax", device_count=8)
    assert pool.device_count == 1 and pool.mesh is None
    assert type(pool["tpu"]) is JaxBackend


# -- single-device fallback parity (satellite: devices=1 byte-identical) --

@pytest.mark.parametrize("mode", ["linear", "relu", "proj1d", "radial"])
def test_single_device_pool_parity_vs_oracle(mode):
    """devices=1 through the pool == pre-refactor JaxBackend, byte for
    byte, and both match the numpy oracle within atol 1e-5."""
    rng = np.random.default_rng(0)
    zm = _zoo_model(mode, rng)
    X = rng.standard_normal((37, 16)).astype(np.float32)

    pool = make_backends("jax", device_count=1)
    pooled = pool["tpu"]
    legacy = JaxBackend()            # the pre-pool construction
    sp, sl = _spec(zm, f"v_{mode}"), _spec(zm, f"v_{mode}")
    Ep = np.asarray(pooled.run_infer(sp, {"x": X})["f"])
    El = np.asarray(legacy.run_infer(sl, {"x": X})["f"])
    assert Ep.tobytes() == El.tobytes()          # byte-identical
    Eo = np.asarray(zm.features(X))
    np.testing.assert_allclose(Ep, Eo, atol=1e-5)
    # telemetry parity: same staging, bucketing, and stats accounting
    assert pooled.stage_count == legacy.stage_count == 1
    assert pooled.compile_count == legacy.compile_count
    assert (sp.stats.rows, sp.stats.batches) == \
        (sl.stats.rows, sl.stats.batches) == (37, 1)


def test_session_device_count_clamps_and_serves():
    """A session asking for more devices than exist serves correctly on
    the clamped single-device pool."""
    import jax
    if len(jax.devices()) > 1:
        pytest.skip("process has real multi-device jax")
    from repro.engine import MorphingServer, MorphingSession
    sess = MorphingSession(backend="numpy", device_count=4,
                           auto_calibrate=False)
    assert sess.device_count == 1
    srv = MorphingServer(session=sess)
    assert srv.devices == 1
    assert srv.stats().devices == 1


def test_server_devices_conflicting_with_session_raises():
    from repro.engine import MorphingServer, MorphingSession
    sess = MorphingSession(backend="numpy", auto_calibrate=False)
    with pytest.raises(ValueError, match="conflicts"):
        MorphingServer(session=sess, devices=2)


def test_hardware_profile_mesh_fields_default_single_device():
    hw = HardwareProfile("host", 1e9, 1e9)
    assert hw.device_count == 1
    assert hw.per_device_flops == 1e9
    mesh_hw = HardwareProfile("tpu", 4e9, 1e9, device_count=4)
    assert mesh_hw.per_device_flops == 1e9
    measured = HardwareProfile("tpu", 4e9, 1e9, device_count=4,
                               device_flops_per_s=1.5e9)
    assert measured.per_device_flops == 1.5e9


def test_calibrate_single_device_profile_unchanged_shape():
    prof = calibrate(NumpyBackend(), "host", rows=(64, 256), repeats=1)
    assert prof.measured and prof.device_count == 1
    assert prof.device_flops_per_s == 0.0
    assert prof.per_device_flops == prof.flops_per_s


# -- 2 simulated devices (subprocess) --------------------------------------

def test_mesh_backend_parity_all_modes_two_devices():
    print(_run("""
        import numpy as np
        from repro.core.zoo import ZooModel
        from repro.pipeline.backend import (JaxBackend, MeshJaxBackend,
                                            InferSpec)
        from repro.pipeline.batcher import BatcherStats

        def spec(zm, version):
            class RM:
                zoo_model = zm
                features = staticmethod(zm.features)
                head = staticmethod(lambda F: np.asarray(F).mean(axis=1))
                head_kind = 'mean'
            return InferSpec(kind='embed', task='t', col='x', out='f',
                             table='tb', version=version, model=RM(),
                             stats=BatcherStats())

        rng = np.random.default_rng(0)
        mesh_b = MeshJaxBackend()
        assert mesh_b.device_count == 2, mesh_b.device_count
        single = JaxBackend()
        for mode in ('linear', 'relu', 'proj1d', 'radial'):
            kw = {}
            if mode == 'radial':
                kw = dict(centers=rng.standard_normal((8, 16))
                          .astype(np.float32), sigma=1.3)
            zm = ZooModel(name=f'm_{mode}', source_family='g',
                          W=rng.standard_normal((16, 24))
                          .astype(np.float32), mode=mode, **kw)
            X = rng.standard_normal((37, 16)).astype(np.float32)
            Em = np.asarray(mesh_b.run_infer(spec(zm, f'v{mode}'),
                                             {'x': X})['f'])
            Es = np.asarray(single.run_infer(spec(zm, f'v{mode}'),
                                             {'x': X})['f'])
            Eo = np.asarray(zm.features(X))
            assert Em.tobytes() == Es.tobytes(), mode
            np.testing.assert_allclose(Em, Eo, atol=1e-5)
        # power-of-two buckets are already mesh multiples: identical
        # compile telemetry on a 2-device mesh
        assert mesh_b.compile_count == single.compile_count
        print('mesh parity ok')
    """))


def test_mesh_pool_server_end_to_end_two_devices():
    print(_run("""
        import numpy as np, tempfile
        from repro.core import make_task, pretrain_model
        from repro.core.task import TaskSpec
        from repro.engine import MorphingServer, MorphingSession
        from repro.pipeline.backend import MeshJaxBackend

        rng = np.random.default_rng(0)
        src = make_task(rng, 'gauss', n=120, dim=16, classes=3)
        zoo = [pretrain_model(src, width=48, seed=1, name='m0',
                              mode='linear')]
        X = rng.standard_normal((400, 16)).astype(np.float32)
        y = (X.sum(1) > 0).astype(np.float32)

        def build(devices):
            sess = MorphingSession(zoo=zoo, root=tempfile.mkdtemp(),
                                   backend='jax', device_count=devices,
                                   model_store='decoupled')
            sess.register_table('t', {'x': X})
            sess.create_task(TaskSpec('s', 'series', ('P', 'N')))
            sess.registry._resolution['s'] = 0
            sess.resolve_task('s', X[:64], y[:64])
            return MorphingServer(session=sess)

        s1 = build(1).start()
        a = s1.predict('PREDICT x USING TASK s FROM t').scores
        b1 = list(s1._lanes.values())[0].batch_rows
        s1.stop()

        s2 = build(2).start()
        r = s2.predict('PREDICT x USING TASK s FROM t')
        st = s2.stats()
        assert st.devices == 2, st.devices
        assert st.mesh_rows_per_s > 0
        assert isinstance(s2.session.backends['tpu'], MeshJaxBackend)
        b2 = list(s2._lanes.values())[0].batch_rows
        s2.stop()
        # mesh lanes budget against aggregate throughput (Eq. 11 x N)
        assert b2 >= b1, (b1, b2)
        # serving scores are device-count invariant
        assert np.abs(np.asarray(r.scores) - np.asarray(a)).max() < 1e-6
        print('server mesh ok', b1, b2)
    """))


def test_calibrate_mesh_reports_both_rates_two_devices():
    print(_run("""
        from repro.pipeline.backend import MeshJaxBackend
        from repro.pipeline.cost import calibrate

        prof = calibrate(MeshJaxBackend(), 'tpu', rows=(64, 512),
                         repeats=1)
        assert prof.measured
        assert prof.device_count == 2, prof.device_count
        # mesh-aggregate and per-device rates both measured
        assert prof.flops_per_s > 0
        assert prof.device_flops_per_s > 0
        assert prof.per_device_flops == prof.device_flops_per_s
        print('calibrate mesh ok')
    """))


def test_mesh_bucket_rounding_three_devices():
    """A non-power-of-two mesh rounds buckets up to mesh multiples so
    the batch axis splits evenly under shard_map."""
    print(_run("""
        import numpy as np
        from repro.core.zoo import ZooModel
        from repro.pipeline.backend import MeshJaxBackend, InferSpec
        from repro.pipeline.batcher import BatcherStats

        b = MeshJaxBackend()
        assert b.device_count == 3
        assert b._bucket_for(5) == 33      # pow2->32, rounded to x3
        assert b._bucket_for(40) == 66     # pow2->64, rounded to x3
        rng = np.random.default_rng(0)
        zm = ZooModel(name='m', source_family='g',
                      W=rng.standard_normal((16, 24)).astype(np.float32),
                      mode='relu')
        X = rng.standard_normal((40, 16)).astype(np.float32)

        class RM:
            zoo_model = zm
            features = staticmethod(zm.features)
            head = staticmethod(lambda F: np.asarray(F).mean(axis=1))
            head_kind = 'mean'
        spec = InferSpec(kind='embed', task='t', col='x', out='f',
                         table='tb', version='v', model=RM(),
                         stats=BatcherStats())
        E = np.asarray(b.run_infer(spec, {'x': X})['f'])
        np.testing.assert_allclose(E, zm.features(X), atol=1e-5)
        print('bucket rounding ok')
    """, devices=3))
