"""Property-based round-trip suite for the storage compression layer
(docs/architecture.md "Compressed deltas & tensor-page dedup").

Lossy-but-bounded compression sits on the model-resolve path, where a
silent corruption would change every inference downstream — so the
invariants here are stated as properties over random dtypes, shapes and
sparsity levels rather than hand-picked examples:

- save -> load is **bit-exact** for uncompressed payloads and for
  integer deltas (wraparound composition), with compression enabled;
- compressed float deltas reconstruct within the **declared** bound
  (sparse: the sparsify epsilon; quantized: scale/2), never an
  undeclared one;
- composed base+delta+delta chains match an eagerly materialized
  oracle within the sum of the declared per-hop bounds;
- row-range reads agree exactly with slicing the full decode, for
  every encoding (dense, sparse, quant, paged);
- page dedup refcounts survive interleaved save/delete/register_finetune
  and ``vacuum()`` never collects a referenced page.

Runs through ``tests/_hypothesis_compat`` (conftest installs it), so the
suite is deterministic with or without the real ``hypothesis`` package.
"""
import io
import tempfile
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.storage import Catalog, DecoupledStore, mvec

INT_DTYPES = ["int8", "int16", "int32", "int64", "uint8", "uint32"]
FLOAT_DTYPES = ["float16", "float32", "float64"]


def _rand(rng: np.random.Generator, shape, dtype: str) -> np.ndarray:
    if dtype in FLOAT_DTYPES:
        return rng.standard_normal(shape).astype(dtype)
    info = np.iinfo(dtype)
    return rng.integers(info.min, info.max, size=shape,
                        endpoint=True).astype(dtype)


def _sparsify(rng: np.random.Generator, arr: np.ndarray,
              frac: float) -> np.ndarray:
    out = arr.copy()
    out[rng.random(arr.shape) >= frac] = 0
    return out


def _store(root: str, **kw) -> DecoupledStore:
    root = Path(root)
    return DecoupledStore(root / "layers", Catalog(root / "catalog"), **kw)


def _compose_slack(arr: np.ndarray) -> float:
    """Float rounding slack on top of a declared quant bound: the
    dequantized delta is cast to the logical dtype and composed with the
    base in that dtype, each adding <= 1 ulp of the value's magnitude."""
    if arr.dtype.kind != "f":
        return 0.0
    return 4 * float(np.finfo(arr.dtype).eps) * float(np.max(np.abs(arr)))


# ---------------------------------------------------------------------------
# Mvec payload encodings
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.sampled_from(INT_DTYPES + FLOAT_DTYPES),
       st.integers(1, 37), st.integers(1, 9))
def test_dense_roundtrip_bit_exact(seed, dtype, rows, cols):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (rows, cols), dtype)
    buf = mvec.encode(a)
    out = mvec.decode(buf)
    assert out.dtype == a.dtype and out.shape == a.shape
    assert out.tobytes() == a.tobytes()


@given(st.integers(0, 10_000), st.sampled_from(FLOAT_DTYPES),
       st.floats(0.0, 0.9), st.integers(2, 41))
def test_sparse_roundtrip_exact_floats(seed, dtype, frac, rows):
    rng = np.random.default_rng(seed)
    a = _sparsify(rng, _rand(rng, (rows, 7), dtype), frac)
    buf = mvec.encode_sparse(a, flags=mvec.FLAG_DELTA)
    h = mvec.decode_header(buf)
    assert h.is_sparse and h.is_delta
    out = mvec.decode(buf)
    # eps=0 drops only zeros: value-exact reconstruction
    assert np.array_equal(out, a)
    assert mvec.decode_aux(buf).bound == 0.0


@given(st.integers(0, 10_000), st.sampled_from(INT_DTYPES),
       st.floats(0.0, 0.5), st.integers(1, 33))
def test_sparse_roundtrip_bit_exact_ints(seed, dtype, frac, rows):
    rng = np.random.default_rng(seed)
    a = _sparsify(rng, _rand(rng, (rows, 5), dtype), frac)
    out = mvec.decode(mvec.encode_sparse(a))
    assert out.dtype == a.dtype
    assert out.tobytes() == a.tobytes()


@given(st.integers(0, 10_000), st.integers(3, 29), st.integers(0, 28),
       st.integers(0, 30))
def test_sparse_slice_matches_dense_slice(seed, rows, start, span):
    rng = np.random.default_rng(seed)
    a = _sparsify(rng, _rand(rng, (rows, 6), "float32"), 0.3)
    buf = mvec.encode_sparse(a)
    stop = start + span
    expect = a[min(start, rows):min(max(stop, start), rows)]
    got = mvec.decode_slice(buf, start, stop)
    assert np.array_equal(got, expect)
    arr, nread, aux = mvec.read_slice_counted(io.BytesIO(buf), start, stop)
    assert np.array_equal(arr, expect)
    assert 0 <= nread <= len(buf)


@given(st.integers(0, 10_000), st.sampled_from(["int8", "int16"]),
       st.sampled_from(FLOAT_DTYPES), st.integers(1, 31))
def test_quant_roundtrip_within_declared_bound(seed, code, dtype, rows):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (rows, 5), dtype)
    buf = mvec.encode_quant(a, code)
    aux = mvec.decode_aux(buf)
    assert aux.encoding == "quant" and aux.code_dtype == code
    out = mvec.decode(buf)
    assert out.dtype == a.dtype
    err = np.max(np.abs(out.astype(np.float64) - a.astype(np.float64)))
    # float16 casts of the dequantized value add at most 1 ulp on top
    # of the declared bound; float32/64 stay strictly within it
    slack = np.finfo(dtype).eps * float(np.max(np.abs(a))) if rows else 0.0
    assert err <= aux.bound + slack + 1e-12


@given(st.integers(0, 10_000), st.integers(3, 23), st.integers(0, 25),
       st.integers(0, 25))
def test_quant_slice_consistent_with_full_decode(seed, rows, start, span):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (rows, 4), "float32")
    buf = mvec.encode_quant(a, "int8")
    full = mvec.decode(buf)
    stop = start + span
    lo, hi = min(start, rows), min(max(stop, start), rows)
    assert np.array_equal(mvec.decode_slice(buf, start, stop), full[lo:hi])
    arr, nread, aux = mvec.read_slice_counted(io.BytesIO(buf), start, stop)
    assert np.array_equal(arr, full[lo:hi])
    assert nread <= len(buf)


@given(st.integers(0, 10_000))
def test_quant_zero_entries_stay_zero(seed):
    rng = np.random.default_rng(seed)
    a = _sparsify(rng, _rand(rng, (17, 3), "float32"), 0.4)
    out = mvec.decode(mvec.encode_quant(a, "int8"))
    # symmetric quant (zero_point=0): exact zeros survive exactly, so a
    # delta that leaves an entry untouched still leaves it untouched
    assert np.all(out[a == 0.0] == 0.0)


@given(st.integers(0, 10_000))
def test_quant_int16_bound_tighter_than_int8(seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (11, 7), "float32")
    b8 = mvec.decode_aux(mvec.encode_quant(a, "int8")).bound
    b16 = mvec.decode_aux(mvec.encode_quant(a, "int16")).bound
    assert b16 < b8
    assert b8 == pytest.approx(float(np.max(np.abs(a))) / 127 / 2)


def test_encoding_flag_hygiene():
    a = np.ones((3, 3), np.float32)
    with pytest.raises(ValueError):
        mvec.encode(a, flags=mvec.FLAG_SPARSE)
    with pytest.raises(ValueError):
        mvec.encode_sparse(a, flags=mvec.FLAG_QUANT)
    with pytest.raises(ValueError):
        mvec.encode_quant(a, "int32")
    with pytest.raises(ValueError):
        mvec.encode_quant(a.astype(np.int32))
    tbl = mvec.encode_paged("float32", (3, 3), 64, [b"\0" * 32])
    with pytest.raises(ValueError):
        mvec.decode(tbl)          # paged payloads need the page store
    with pytest.raises(ValueError):
        mvec.encode_paged("float32", (3, 3), 64, [b"short"])


def test_aux_info_survives_file_roundtrip():
    a = np.linspace(-1, 1, 24, dtype=np.float32).reshape(6, 4)
    for buf in (mvec.encode_sparse(a), mvec.encode_quant(a, "int16")):
        h, aux = mvec.read_aux(io.BytesIO(buf))
        assert (h.dtype, h.shape) == ("float32", (6, 4))
        assert aux == mvec.decode_aux(buf)
    tbl = mvec.encode_paged("float32", (6, 4), 16, [b"\1" * 32, b"\2" * 32])
    h, aux = mvec.read_aux(io.BytesIO(tbl))
    assert aux.page_bytes == 16 and len(aux.digests) == 2


# ---------------------------------------------------------------------------
# DecoupledStore round-trips with compression enabled
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.sampled_from(INT_DTYPES + FLOAT_DTYPES))
def test_store_uncompressed_roundtrip_bit_exact(seed, dtype):
    rng = np.random.default_rng(seed)
    params = {"trunk/W": _rand(rng, (19, 6), dtype),
              "head/w": _rand(rng, (6,), dtype)}
    with tempfile.TemporaryDirectory() as td:
        ds = _store(td)
        ds.save("m", {"arch": "t"}, params)
        _, flat = ds.load("m")
        for k, v in params.items():
            assert flat[k].tobytes() == v.tobytes()


@given(st.integers(0, 10_000), st.sampled_from(INT_DTYPES),
       st.floats(0.0, 0.6))
def test_store_integer_delta_bit_exact_compressed(seed, dtype, frac):
    """Integer deltas stay bit-exact through sparse encoding + the
    wraparound compose path, with compression enabled."""
    rng = np.random.default_rng(seed)
    base = {"trunk/W": _rand(rng, (13, 5), dtype)}
    ft = {"trunk/W": base["trunk/W"].copy()}
    mask = rng.random(ft["trunk/W"].shape) < frac
    with np.errstate(over="ignore"):
        ft["trunk/W"][mask] += _rand(rng, (13, 5), dtype)[mask]
    with tempfile.TemporaryDirectory() as td:
        ds = _store(td, compress_deltas=True)
        ds.save("base", {"arch": "t"}, base)
        ds.save("ft", {"arch": "t"}, ft, base_model="base")
        for li in ds.catalog.get_layers("ft"):
            assert li.bound == 0.0       # integer encodings are exact
        _, flat = ds.load("ft")
        assert flat["trunk/W"].tobytes() == ft["trunk/W"].tobytes()


@given(st.integers(0, 10_000), st.sampled_from(["int8", "int16"]),
       st.floats(0.05, 1.0))
def test_store_float_delta_within_declared_bound(seed, quant, frac):
    rng = np.random.default_rng(seed)
    trunk = rng.standard_normal((21, 8)).astype(np.float32)
    ft_trunk = trunk.copy()
    mask = rng.random(trunk.shape) < frac
    ft_trunk[mask] += rng.standard_normal(int(mask.sum())).astype(
        np.float32) * 0.1
    with tempfile.TemporaryDirectory() as td:
        ds = _store(td, compress_deltas=True, quant_dtype=quant)
        ds.save("base", {"arch": "t"}, {"trunk/W": trunk})
        ds.save("ft", {"arch": "t"}, {"trunk/W": ft_trunk},
                base_model="base")
        li = ds.catalog.get_layers("ft")[0]
        _, flat = ds.load("ft")
        err = np.max(np.abs(flat["trunk/W"].astype(np.float64)
                            - ft_trunk.astype(np.float64)))
        assert err <= li.bound + _compose_slack(ft_trunk) + 1e-12
        if li.enc in ("sparse", "quant"):
            # the compressed file must actually be smaller than raw
            assert ds.delta_bytes("ft") < ft_trunk.nbytes
            assert ds.stats.compressed_delta_bytes > 0


@given(st.integers(0, 10_000))
def test_store_sparse_float_delta_exact(seed):
    """A genuinely sparse float delta picks the sparse encoding and
    round-trips exactly (bound 0)."""
    rng = np.random.default_rng(seed)
    trunk = rng.standard_normal((32, 16)).astype(np.float32)
    ft_trunk = trunk.copy()
    idx = rng.integers(0, trunk.size, size=10)
    ft_trunk.reshape(-1)[idx] += 1.5
    with tempfile.TemporaryDirectory() as td:
        ds = _store(td, compress_deltas=True)
        ds.save("base", {"arch": "t"}, {"trunk/W": trunk})
        ds.save("ft", {"arch": "t"}, {"trunk/W": ft_trunk},
                base_model="base")
        li = ds.catalog.get_layers("ft")[0]
        assert li.enc == "sparse" and li.bound == 0.0
        _, flat = ds.load("ft")
        assert np.array_equal(flat["trunk/W"], ft_trunk)


@given(st.integers(0, 10_000), st.integers(0, 30), st.integers(1, 30))
def test_store_row_slice_matches_full_load(seed, start, span):
    rng = np.random.default_rng(seed)
    trunk = rng.standard_normal((30, 6)).astype(np.float32)
    dense_ft = trunk + rng.standard_normal(trunk.shape).astype(
        np.float32) * 0.05
    with tempfile.TemporaryDirectory() as td:
        ds = _store(td, compress_deltas=True)
        ds.save("base", {"arch": "t"}, {"trunk/W": trunk})
        ds.save("ft", {"arch": "t"}, {"trunk/W": dense_ft},
                base_model="base")
        _, flat = ds.load("ft")
        full = flat["trunk/W"]
        stop = min(start + span, 30)
        start = min(start, 30)
        got = ds.load_layer_rows("ft", "trunk/W", start, stop)
        assert np.array_equal(got, full[start:stop])


@given(st.integers(0, 10_000), st.booleans())
def test_chain_compose_matches_eager_oracle(seed, second_hop_sparse):
    """base + delta + delta chains equal an eagerly materialized oracle
    within the sum of the declared per-hop bounds."""
    rng = np.random.default_rng(seed)
    trunk = rng.standard_normal((24, 8)).astype(np.float32)
    v1 = trunk + rng.standard_normal(trunk.shape).astype(np.float32) * 0.05
    v2 = v1.copy()
    if second_hop_sparse:
        v2.reshape(-1)[rng.integers(0, v2.size, 6)] += 0.7
    else:
        v2 += rng.standard_normal(v2.shape).astype(np.float32) * 0.02
    with tempfile.TemporaryDirectory() as td:
        ds = _store(td, compress_deltas=True)
        ds.save("m0", {"arch": "t"}, {"trunk/W": trunk})
        ds.save("m1", {"arch": "t"}, {"trunk/W": v1}, base_model="m0")
        # the oracle composes through what the store *actually* holds at
        # each hop: save v2 against the reconstructed v1, like
        # register_finetune does (load base, overlay, save)
        _, f1 = ds.load("m1")
        recon1 = np.asarray(f1["trunk/W"])
        delta2_target = recon1 + (v2 - v1)
        ds.save("m2", {"arch": "t"}, {"trunk/W": delta2_target},
                base_model="m1")
        bound = sum(li.bound for m in ("m1", "m2")
                    for li in ds.catalog.get_layers(m))
        # cold cache: force disk composition through the whole chain
        ds2 = DecoupledStore(Path(td) / "layers",
                             Catalog(Path(td) / "catalog"))
        _, f2 = ds2.load("m2")
        err = np.max(np.abs(np.asarray(f2["trunk/W"], dtype=np.float64)
                            - delta2_target.astype(np.float64)))
        assert err <= bound + 1e-6
        assert ds2.stats.delta_composes >= 2


@given(st.integers(0, 10_000), st.sampled_from(INT_DTYPES + FLOAT_DTYPES),
       st.sampled_from([64, 256, 1 << 16]))
def test_paged_roundtrip_bit_exact(seed, dtype, page_bytes):
    rng = np.random.default_rng(seed)
    params = {"trunk/W": _rand(rng, (17, 9), dtype)}
    with tempfile.TemporaryDirectory() as td:
        ds = _store(td, dedup_pages=True, page_bytes=page_bytes)
        ds.save("m", {"arch": "t"}, params)
        _, flat = ds.load("m")
        assert flat["trunk/W"].tobytes() == params["trunk/W"].tobytes()


@given(st.integers(0, 10_000), st.integers(0, 25), st.integers(1, 25))
def test_paged_row_slice_matches(seed, start, span):
    rng = np.random.default_rng(seed)
    trunk = rng.standard_normal((25, 11)).astype(np.float32)
    with tempfile.TemporaryDirectory() as td:
        ds = _store(td, dedup_pages=True, page_bytes=128)
        ds.save("m", {"arch": "t"}, {"trunk/W": trunk})
        stop = min(start + span, 25)
        start = min(start, 25)
        got = ds.load_layer_rows("m", "trunk/W", start, stop)
        assert np.array_equal(got, trunk[start:stop])


@given(st.integers(0, 10_000))
def test_paged_partial_read_touches_fewer_bytes(seed):
    rng = np.random.default_rng(seed)
    trunk = rng.standard_normal((256, 16)).astype(np.float32)  # 16 KiB
    with tempfile.TemporaryDirectory() as td:
        ds = _store(td, dedup_pages=True, page_bytes=1024)
        ds.save("m", {"arch": "t"}, {"trunk/W": trunk})
        before = ds.stats.loaded_bytes
        ds.load_layer_rows("m", "trunk/W", 0, 8)   # first page only
        narrow = ds.stats.loaded_bytes - before
        assert narrow < trunk.nbytes / 4


@given(st.integers(0, 10_000))
def test_paged_and_compressed_fleet_matches_oracle(seed):
    """Both layers on at once: paged base + compressed deltas still
    reconstruct each fleet member within its declared bound."""
    rng = np.random.default_rng(seed)
    trunk = rng.standard_normal((40, 12)).astype(np.float32)
    fleet = {}
    for k in range(4):
        v = trunk + rng.standard_normal(trunk.shape).astype(
            np.float32) * 0.03
        fleet[f"ft{k}"] = v
    with tempfile.TemporaryDirectory() as td:
        ds = _store(td, compress_deltas=True, dedup_pages=True,
                    page_bytes=2048)
        ds.save("base", {"arch": "t"}, {"trunk/W": trunk})
        for mid, v in fleet.items():
            ds.save(mid, {"arch": "t"}, {"trunk/W": v}, base_model="base")
        for mid, v in fleet.items():
            li = ds.catalog.get_layers(mid)[0]
            _, flat = ds.load(mid)
            err = np.max(np.abs(np.asarray(flat["trunk/W"], np.float64)
                                - v.astype(np.float64)))
            assert err <= li.bound + _compose_slack(v) + 1e-12


# ---------------------------------------------------------------------------
# Dedup invariants: refcounts, vacuum safety, generations
# ---------------------------------------------------------------------------

def _page_refs(ds: DecoupledStore) -> dict:
    with ds.pages._lock:
        return dict(ds.pages._refs)


def test_refcounts_interleaved_save_delete_finetune(tmp_path):
    rng = np.random.default_rng(7)
    trunk = rng.standard_normal((64, 32)).astype(np.float32)
    ds = _store(tmp_path, compress_deltas=True, dedup_pages=True,
                page_bytes=1024)
    ds.save("base", {"arch": "t"}, {"trunk/W": trunk})
    refs1 = _page_refs(ds)
    assert all(v == 1 for v in refs1.values()) and refs1
    # identical trunk under a second id: same pages, refcount 2
    ds.save("twin", {"arch": "t"}, {"trunk/W": trunk})
    refs2 = _page_refs(ds)
    assert set(refs2) == set(refs1)
    assert all(v == 2 for v in refs2.values())
    # a fine-tune stores only a delta file -> no new page references
    ft = trunk.copy()
    ft[0] += 1.0
    ds.save("ft", {"arch": "t"}, {"trunk/W": ft}, base_model="base")
    assert _page_refs(ds) == refs2
    # delete the twin: back to 1 everywhere, pages intact until vacuum
    ds.delete("twin")
    refs3 = _page_refs(ds)
    assert all(v == 1 for v in refs3.values()) and set(refs3) == set(refs1)
    assert ds.pages.total_bytes() >= trunk.nbytes
    assert ds.vacuum() == (0, 0)     # every page still referenced
    _, flat = ds.load("ft")
    assert np.allclose(flat["trunk/W"], ft)


def test_vacuum_never_collects_referenced_pages(tmp_path):
    rng = np.random.default_rng(11)
    trunk = rng.standard_normal((32, 32)).astype(np.float32)
    ds = _store(tmp_path, compress_deltas=True, dedup_pages=True,
                page_bytes=512)
    ds.save("base", {"arch": "t"}, {"trunk/W": trunk,
                                    "head/w": np.ones(32, np.float32)})
    # ft's head is *unchanged*: stored as an '@base:head/w' reference —
    # base's pages are then reachable only through that reference
    ft = {"trunk/W": trunk + 0.25, "head/w": np.ones(32, np.float32)}
    ds.save("ft", {"arch": "t"}, ft, base_model="base")
    assert any(li.file.startswith("@base:")
               for li in ds.catalog.get_layers("ft"))
    # deleting the base would orphan the reference: must refuse
    with pytest.raises(ValueError):
        ds.delete("base")
    assert ds.vacuum() == (0, 0)
    # reads through the reference still work afterwards
    assert np.allclose(ds.load("ft")[1]["head/w"], 1.0)
    # tearing down in dependency order frees everything
    ds.delete("ft")
    ds.delete("base")
    removed, freed = ds.vacuum()
    assert removed > 0 and freed > 0
    assert ds.pages.total_bytes() == 0


def test_resave_same_id_bumps_generation_without_leaking_pages(tmp_path):
    rng = np.random.default_rng(13)
    ds = _store(tmp_path, dedup_pages=True, page_bytes=1024)
    a = rng.standard_normal((64, 16)).astype(np.float32)
    ds.save("m", {"arch": "t"}, {"trunk/W": a})
    gen1 = ds.catalog.get_model("m").extra["save_gen"]
    fp1 = ds.trunk_fingerprint("m")
    refs1 = _page_refs(ds)
    # re-save different content under the same id
    b = rng.standard_normal((64, 16)).astype(np.float32)
    ds.save("m", {"arch": "t"}, {"trunk/W": b})
    assert ds.catalog.get_model("m").extra["save_gen"] == gen1 + 1
    assert ds.trunk_fingerprint("m") != fp1
    refs2 = _page_refs(ds)
    # old pages fully dereferenced, new ones at refcount 1
    assert not (set(refs1) & set(refs2))
    assert all(v == 1 for v in refs2.values())
    removed, _freed = ds.vacuum()   # collects exactly the old content
    assert removed == len(refs1)
    assert np.array_equal(ds.load("m")[1]["trunk/W"], b)
    # re-saving *identical* content dedups against itself: no growth
    ds.save("m", {"arch": "t"}, {"trunk/W": b})
    assert set(_page_refs(ds)) == set(refs2)
    assert all(v == 1 for v in _page_refs(ds).values())
    assert ds.vacuum() == (0, 0)


def test_dedup_across_models_saves_bytes(tmp_path):
    rng = np.random.default_rng(17)
    trunk = rng.standard_normal((128, 32)).astype(np.float32)
    ds = _store(tmp_path, dedup_pages=True, page_bytes=4096)
    for k in range(3):
        head = rng.standard_normal(32).astype(np.float32)
        ds.save(f"zoo{k}", {"arch": "t"},
                {"trunk/W": trunk, "head/w": head})
    # 3 models, one physical trunk: dedup elided 2 full trunk writes
    assert ds.stats.dedup_bytes_saved >= 2 * trunk.nbytes
    assert ds.stats.dedup_pages >= 2 * (trunk.nbytes // 4096)
    assert ds.disk_footprint() < 2 * sum(
        ds.catalog.get_model(f"zoo{k}").param_count * 4 for k in range(3))
    for k in range(3):
        assert np.array_equal(ds.load(f"zoo{k}")[1]["trunk/W"], trunk)


def test_delete_unknown_model_raises(tmp_path):
    ds = _store(tmp_path)
    with pytest.raises(KeyError):
        ds.delete("nope")


def test_stats_gauges_flow_through_store(tmp_path):
    rng = np.random.default_rng(19)
    trunk = rng.standard_normal((64, 16)).astype(np.float32)
    ds = _store(tmp_path, compress_deltas=True, dedup_pages=True,
                page_bytes=2048)
    ds.save("base", {"arch": "t"}, {"trunk/W": trunk})
    ds.save("ft", {"arch": "t"},
            {"trunk/W": trunk + rng.standard_normal(
                trunk.shape).astype(np.float32) * 0.01},
            base_model="base")
    ds.save("twin", {"arch": "t"}, {"trunk/W": trunk})
    assert ds.stats.compressed_delta_bytes > 0
    assert ds.stats.dedup_pages > 0
    assert ds.stats.dedup_bytes_saved > 0
    assert ds.stats.quant_error_bound == max(
        li.bound for li in ds.catalog.get_layers("ft"))
