"""MoE: ragged == dense oracle (fwd + grad), capacity drops, aux loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.moe import moe_dense, moe_ragged_local, moe_specs
from repro.models.spec import init_params


@pytest.fixture(scope="module")
def world():
    cfg = smoke_config("olmoe-1b-7b")
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(3), "float32")
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 16, cfg.d_model)) * 0.5
    return cfg, p, x


def test_ragged_matches_dense_forward(world):
    cfg, p, x = world
    yd, auxd = moe_dense(cfg, p, x)
    yr, auxr = moe_ragged_local(cfg, p, x)
    assert float(jnp.abs(yd - yr).max()) < 1e-5
    assert float(jnp.abs(auxd - auxr)) < 1e-6


def test_ragged_matches_dense_grad(world):
    cfg, p, x = world
    gd = jax.grad(lambda p: moe_dense(cfg, p, x)[0].sum())(p)
    gr = jax.grad(lambda p: moe_ragged_local(cfg, p, x)[0].sum())(p)
    errs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), gd, gr)
    assert max(jax.tree.leaves(errs)) < 1e-4


def test_aux_loss_uniform_router_is_one(world):
    """With near-uniform routing, E * sum f_e p_e -> ~1."""
    cfg, p, x = world
    p2 = dict(p)
    p2["router"] = jnp.zeros_like(p["router"])  # uniform probs
    _, aux = moe_dense(cfg, p2, x)
    assert 0.9 < float(aux) < 1.1


def test_capacity_drops_tokens():
    cfg = smoke_config("olmoe-1b-7b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                              capacity_factor=0.05))
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, _ = moe_ragged_local(cfg, p, x)
    yd, _ = moe_dense(cfg, p, x)
    # with tiny capacity most copies drop -> outputs differ from dense
    assert float(jnp.abs(y - yd).max()) > 1e-3
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_inside_jit_and_scan(world):
    cfg, p, x = world

    def f(p, x):
        def body(c, _):
            y, aux = moe_ragged_local(cfg, p, c)
            return c + 0.1 * y, aux
        out, auxs = jax.lax.scan(body, x, None, length=3)
        return out.sum() + auxs.sum()

    val = jax.jit(f)(p, x)
    assert jnp.isfinite(val)
