"""Dispatch tier: picklable cross-process envelopes, front-door routing
to worker processes, staging-aware placement, calibration memo, and
worker failover (heartbeats + lease re-dispatch)."""
import json
import pickle
import time

import numpy as np
import pytest

from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import (DispatchServer, EngineConfig, MorphingServer,
                          MorphingSession, PlacementPolicy)
from repro.engine import session as session_mod
from repro.engine.serve import ServerStats
from repro.pipeline.admission import CircuitOpen, Rejected, RequestError
from repro.pipeline.cost import (HardwareProfile, load_profile_memo,
                                 profile_memo_fingerprint,
                                 store_profile_memo)


# -- fixtures --------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_zoo():
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=120, dim=16, classes=3)
    return [pretrain_model(src, width=12, seed=1, name="m0")]


@pytest.fixture(scope="module")
def table():
    rng = np.random.default_rng(0)
    n = 600
    return {"gender": rng.integers(0, 2, n),
            "len": rng.integers(1, 200, n),
            "emb": rng.standard_normal((n, 16)).astype(np.float32)}


@pytest.fixture(scope="module")
def sample():
    return make_task(np.random.default_rng(1), "gauss", n=128, dim=16,
                     classes=3)


def make_session(tmp_path, zoo, table, *, model_store="decoupled",
                 backend="numpy", **kw):
    sess = MorphingSession(zoo=zoo, root=tmp_path, model_store=model_store,
                           backend=backend, **kw)
    sess.register_table("reviews", {k: v.copy() for k, v in table.items()})
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0
    return sess


def make_dispatch(tmp_path, zoo, table, sample, *, workers=2, **kw):
    sess = make_session(tmp_path, zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    kw.setdefault("placement", PlacementPolicy(watermark_rows=1 << 20))
    srv = DispatchServer(session=sess, workers=workers,
                         worker_backend="numpy", **kw)
    return sess, srv


def _ref(sess, thr):
    return np.asarray(sess.sql(
        f"PREDICT emb USING TASK sent FROM reviews "
        f"WHERE len > {thr}").rows["_score"])


# -- satellite: picklable cross-process envelopes --------------------------

def _roundtrip(obj):
    return pickle.loads(pickle.dumps(obj))


def test_rejected_pickles_with_fields():
    e = Rejected("interactive queue full", lane="trunk:abc",
                 priority="interactive", queued_units=512, cap=256,
                 reason="queue_full")
    r = _roundtrip(e)
    assert isinstance(r, Rejected) and str(r) == str(e)
    # regression guard: *every* attribute must survive transport, so a
    # newly added field can't silently break the dispatch tier
    assert r.__dict__ == e.__dict__


def test_circuit_open_pickles_with_fields():
    e = CircuitOpen("lane breaker open", lane="trunk:abc",
                    priority="batch", failures=7)
    r = _roundtrip(e)
    assert isinstance(r, CircuitOpen)
    assert r.failures == 7 and r.reason == "breaker_open"
    assert r.__dict__ == e.__dict__


def test_request_error_pickles_with_fields():
    e = RequestError("batch failed after 3 attempts", lane="trunk:abc",
                     attempts=3, req_ids=(4, 5, 6))
    r = _roundtrip(e)
    assert isinstance(r, RequestError)
    assert r.attempts == 3 and r.req_ids == (4, 5, 6)
    assert r.__dict__ == e.__dict__


def test_server_stats_pickles_equal():
    st = ServerStats(requests=5, rows=100, share_hits=3,
                     requests_by_task={"sent": 5},
                     share_hit_rate_by_lane={"trunk:a": 0.5},
                     breaker_open_lanes=["trunk:a"])
    assert _roundtrip(st) == st


@pytest.mark.parametrize("store", ["decoupled", "blob"])
def test_resolved_model_pickles(tmp_path, serve_zoo, table, sample, store):
    sess = make_session(tmp_path / store, serve_zoo, table,
                        model_store=store)
    rm = sess.resolve_task("sent", sample.X, sample.y)
    rm2 = _roundtrip(rm)
    for f in ("task", "model_id", "version", "load_mode", "store",
              "stored_bytes", "in_dim", "head_dim", "trunk_fp",
              "base_model_id", "delta_bytes"):
        assert getattr(rm2, f) == getattr(rm, f), f
    X = sample.X[:8].astype(np.float32)
    np.testing.assert_allclose(rm2.head(rm2.features(X)),
                               rm.head(rm.features(X)), atol=1e-6)


# -- satellite: on-disk calibration memo -----------------------------------

def test_profile_memo_roundtrip_and_staleness(tmp_path):
    path = tmp_path / "memo.json"
    prof = HardwareProfile(name="host", flops_per_s=1e9, mem_bw=2e9,
                           link_bw=3e9, launch_latency_s=1e-5,
                           measured=True)
    fp = profile_memo_fingerprint(("numpy", None))
    store_profile_memo(path, fp, prof)
    assert load_profile_memo(path)[fp] == prof
    # a second entry merges rather than clobbers
    store_profile_memo(path, fp + "|v2", prof)
    assert set(load_profile_memo(path)) == {fp, fp + "|v2"}
    # staleness guard: a changed topology fingerprint simply misses
    assert load_profile_memo(path).get(fp + "|jaxdev=99") is None


def test_profile_memo_corrupt_and_drifted_entries_reprobe(tmp_path):
    path = tmp_path / "memo.json"
    path.write_text("{not json")
    assert load_profile_memo(path) == {}
    path.write_text(json.dumps({"fp": {"no_such_field": 1}}))
    assert load_profile_memo(path) == {}
    assert load_profile_memo(tmp_path / "absent.json") == {}


def test_fingerprint_embeds_topology():
    host = profile_memo_fingerprint(("numpy", None))
    assert "cpus=" in host and "jax=" not in host
    jax_fp = profile_memo_fingerprint(("jax", False))
    assert "jax=" in jax_fp
    assert host != jax_fp
    assert (profile_memo_fingerprint(("jax-mesh", False, 2))
            != profile_memo_fingerprint(("jax-mesh", False, 4)))


def test_session_auto_calibration_writes_memo(tmp_path, serve_zoo, table):
    memo = tmp_path / "hw_calib_memo.json"
    with session_mod._FAST_CALIB_LOCK:
        saved = dict(session_mod._FAST_CALIB_CACHE)
        session_mod._FAST_CALIB_CACHE.clear()
    try:
        sess = MorphingSession(
            zoo=serve_zoo, root=tmp_path / "s",
            config=EngineConfig(model_store="decoupled", backend="numpy",
                                calib_memo_path=str(memo)))
        assert sess.hw
        entries = load_profile_memo(memo)
        assert entries, "auto-calibration should persist its probe"
        fp = profile_memo_fingerprint(("numpy", None))
        assert fp in entries and entries[fp].measured
        # second session reads the memo instead of re-probing
        with session_mod._FAST_CALIB_LOCK:
            session_mod._FAST_CALIB_CACHE.clear()
        sess2 = MorphingSession(
            zoo=serve_zoo, root=tmp_path / "s2",
            config=EngineConfig(model_store="decoupled", backend="numpy",
                                calib_memo_path=str(memo)))
        assert sess2.hw["host"].flops_per_s == entries[fp].flops_per_s
    finally:
        with session_mod._FAST_CALIB_LOCK:
            session_mod._FAST_CALIB_CACHE.clear()
            session_mod._FAST_CALIB_CACHE.update(saved)


# -- MorphingServer plumbing the tier rides on -----------------------------

def test_submit_rows_matches_sql(tmp_path, serve_zoo, table, sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    ref = _ref(sess, 50)
    X = np.asarray(table["emb"])[np.asarray(table["len"]) > 50]
    with MorphingServer(session=sess) as srv:
        out = srv.result(srv.submit_rows("sent", X), timeout=30)
    np.testing.assert_allclose(out.scores, ref, atol=1e-5)


def test_unstage_trunk_releases_and_relanes(tmp_path, serve_zoo, table,
                                            sample):
    sess = make_session(tmp_path, serve_zoo, table)
    rm = sess.resolve_task("sent", sample.X, sample.y)
    key = rm.trunk_fp or rm.version
    sql = "PREDICT emb USING TASK sent FROM reviews WHERE len > 50"
    with MorphingServer(session=sess) as srv:
        first = srv.predict(sql, timeout=30)
        assert srv.unstage_trunk(key) is True
        assert srv.unstage_trunk(key) is False      # idempotent
        again = srv.predict(sql, timeout=30)        # re-lanes + re-stages
        np.testing.assert_allclose(again.scores, first.scores, atol=1e-5)


# -- dispatch tier: routing, placement, failover ---------------------------

def test_dispatch_requires_decoupled_store(tmp_path, serve_zoo, table):
    sess = make_session(tmp_path, serve_zoo, table, model_store="blob")
    with pytest.raises(ValueError, match="decoupled"):
        DispatchServer(session=sess, workers=1)


def test_dispatch_parity_and_stats(tmp_path, serve_zoo, table, sample):
    sess, srv = make_dispatch(tmp_path, serve_zoo, table, sample)
    refs = {thr: _ref(sess, thr) for thr in (20, 60, 100)}
    with srv:
        ids = {thr: srv.submit("PREDICT emb USING TASK sent FROM reviews "
                               f"WHERE len > {thr}")
               for thr in refs}
        for thr, rid in ids.items():
            out = srv.result(rid, timeout=60)
            np.testing.assert_allclose(out.scores, refs[thr], atol=1e-5)
        st = srv.stats()
        assert st.workers == 2 and st.alive_workers == 2
        assert st.requests == 3 and st.leases >= 1
        assert st.worker_rows >= sum(len(r) for r in refs.values())
        assert st.per_worker and all(isinstance(s, ServerStats)
                                     for s in st.per_worker.values())
        assert st.duplicates_dropped == 0 and st.worker_deaths == 0


def test_finetune_fleet_stages_on_one_worker(tmp_path, serve_zoo, table,
                                             sample):
    """K fine-tunes of one base ride a single worker's shared embed lane
    under light load — the trunk is staged on exactly one worker."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    rng = np.random.default_rng(11)
    dim = sess.models["sent"].head_dim
    tasks = ["sent"]
    for i in range(3):
        w = np.abs(rng.standard_normal(dim)).astype(np.float32)
        w /= w.sum()
        name, mid = f"sent_ft{i}", f"m0-ft{i}"
        sess.register_finetune(mid, "m0", {"head/w": w})
        sess.create_task(TaskSpec(name, "series", ("P", "N")))
        sess.resolve_task(name, sample.X, sample.y, model_id=mid)
        tasks.append(name)
    trunk = sess.models["sent"].trunk_fp
    assert all(sess.models[t].trunk_fp == trunk for t in tasks)
    srv = DispatchServer(session=sess, workers=2, worker_backend="numpy",
                         placement=PlacementPolicy(watermark_rows=1 << 20))
    with srv:
        for t in tasks:
            out = srv.predict(f"PREDICT emb USING TASK {t} FROM reviews "
                              "WHERE len > 40", timeout=60)
            assert out.rows > 0
        st = srv.stats()
        staged = [w for w, b in st.staged_bytes_by_worker.items() if b > 0]
        assert len(staged) == 1, st.staged_bytes_by_worker
        assert st.replicas_by_trunk == {trunk: 1}
        assert st.trunks_by_worker[staged[0]] == [trunk]


def test_scale_out_under_load_then_drain_back(tmp_path, serve_zoo, table,
                                              sample):
    sess, srv = make_dispatch(
        tmp_path, serve_zoo, table, sample,
        placement=PlacementPolicy(watermark_rows=256, cost_gated=False,
                                  idle_scale_in_s=0.5),
        monitor_interval_s=0.1)
    trunk = sess.models["sent"].trunk_fp
    rng = np.random.default_rng(7)
    X = rng.standard_normal((256, 16)).astype(np.float32)
    with srv:
        srv.result(srv.submit_rows("sent", X), timeout=60)   # place trunk
        ids = [srv.submit_rows("sent", X + i) for i in range(40)]
        for rid in ids:
            srv.result(rid, timeout=120)
        st = srv.stats()
        assert st.scale_outs >= 1, "watermark burst should add a replica"
        # idle: the extra replica drains back to one worker
        deadline = time.time() + 30
        while time.time() < deadline:
            st = srv.stats()
            if (st.scale_ins >= 1
                    and st.replicas_by_trunk.get(trunk) == 1):
                break
            time.sleep(0.2)
        assert st.scale_ins >= 1
        assert st.replicas_by_trunk.get(trunk) == 1
        staged = [w for w, b in st.staged_bytes_by_worker.items() if b > 0]
        assert len(staged) == 1


def test_worker_death_redispatches_with_parity(tmp_path, serve_zoo, table,
                                               sample):
    """Hard-kill a worker mid-batch: survivors complete the full request
    set with fault-free answers, no duplicates, re-dispatch counted."""
    sess, srv = make_dispatch(tmp_path, serve_zoo, table, sample,
                              monitor_interval_s=0.1,
                              heartbeat_timeout_s=1.0)
    thrs = list(range(10, 110, 10))
    refs = {thr: _ref(sess, thr) for thr in thrs}
    with srv:
        warm = srv.predict("PREDICT emb USING TASK sent FROM reviews "
                           "WHERE len > 150", timeout=60)
        assert warm.rows > 0
        st0 = srv.stats()
        victim = [w for w, b in st0.staged_bytes_by_worker.items()
                  if b > 0][0]
        # slow the victim's backends so its leases are in flight when it
        # dies (training/fault.py injection over the command channel)
        srv.inject_fault(victim, {"slow_rate": 1.0, "slow_s": 0.5})
        ids = {thr: srv.submit("PREDICT emb USING TASK sent FROM reviews "
                               f"WHERE len > {thr}") for thr in thrs}
        time.sleep(0.3)              # let leases land on the victim
        srv.kill_worker(victim)
        for thr, rid in ids.items():
            out = srv.result(rid, timeout=120)
            np.testing.assert_allclose(out.scores, refs[thr], atol=1e-5)
        st = srv.stats()
        assert st.worker_deaths == 1
        assert st.redispatches >= 1
        assert st.duplicates_dropped == 0
        assert st.alive_workers == 1
        # the trunk moved with the load: a survivor now holds it
        staged = [w for w, b in st.staged_bytes_by_worker.items() if b > 0]
        assert staged and victim not in staged


def test_injected_faults_retried_inside_worker(tmp_path, serve_zoo, table,
                                               sample):
    """Transient backend faults injected in a worker are absorbed by its
    lane retry budget — answers stay correct, no failed batches."""
    sess = make_session(tmp_path, serve_zoo, table, enable_share=False)
    sess.resolve_task("sent", sample.X, sample.y)
    srv = DispatchServer(session=sess, workers=1, worker_backend="numpy",
                         placement=PlacementPolicy(watermark_rows=1 << 20))
    refs = {thr: _ref(sess, thr) for thr in (30, 70)}
    with srv:
        warm = srv.predict("PREDICT emb USING TASK sent FROM reviews "
                           "WHERE len > 150", timeout=60)
        assert warm.rows > 0
        srv.inject_fault(0, {"scripted_errors": [0], "seed": 5})
        for thr, ref in refs.items():
            out = srv.predict("PREDICT emb USING TASK sent FROM reviews "
                              f"WHERE len > {thr}", timeout=60)
            np.testing.assert_allclose(out.scores, ref, atol=1e-5)
        srv.inject_fault(0, None)
        st = srv.stats()
        assert st.retries >= 1
        assert st.failed_batches == 0
        assert st.worker_deaths == 0
