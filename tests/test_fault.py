"""Fault tolerance: restart-from-checkpoint, stragglers, elastic rescale."""
import numpy as np
import pytest

from repro.storage import CheckpointManager
from repro.training.fault import (ElasticScaler, StragglerMonitor,
                                  TrainController)


def test_controller_restarts_after_failure(tmp_path):
    cm = CheckpointManager(tmp_path)
    fail_at = {17}

    def step_fn(state, step):
        if step in fail_at:
            fail_at.clear()  # fail once
            raise RuntimeError("simulated preemption")
        return {"w": state["w"] + 1.0}

    tc = TrainController(step_fn, cm, ckpt_every=5)
    state, step = tc.run({"w": np.zeros(3)}, 30)
    assert step == 30
    # the failed step re-ran from the step-15 checkpoint
    kinds = [k for k, _ in tc.events]
    assert "failure" in kinds and "restart" in kinds
    np.testing.assert_array_equal(state["w"], np.full(3, 30.0))


def test_controller_gives_up_after_max_restarts(tmp_path):
    cm = CheckpointManager(tmp_path)

    def always_fail(state, step):
        raise RuntimeError("dead host")

    tc = TrainController(always_fail, cm, ckpt_every=5, max_restarts=3)
    with pytest.raises(RuntimeError, match="restarts"):
        tc.run({"w": np.zeros(1)}, 10)


def test_controller_resumes_fresh_process(tmp_path):
    cm = CheckpointManager(tmp_path)
    step_fn = lambda s, i: {"w": s["w"] + 1.0}
    tc = TrainController(step_fn, cm, ckpt_every=10)
    tc.run({"w": np.zeros(2)}, 20)
    # "new process": fresh controller resumes from step 20's checkpoint
    tc2 = TrainController(step_fn, cm, ckpt_every=10)
    state, step = tc2.run({"w": np.zeros(2)}, 25)
    assert step == 25
    np.testing.assert_array_equal(state["w"], np.full(2, 25.0))
    assert ("resume", {"step": 20}) in tc2.events


def test_straggler_detection():
    mon = StragglerMonitor(threshold=2.0, window=8, min_samples=4)
    for _ in range(8):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.5)
    assert mon.stragglers() == [2]


def test_straggler_needs_samples():
    mon = StragglerMonitor(min_samples=4)
    mon.record(0, 1.0)
    mon.record(1, 9.0)
    assert mon.stragglers() == []


def test_elastic_scaler_reshard(tmp_path):
    cm = CheckpointManager(tmp_path)
    state = {"p": np.arange(24, dtype=np.float32).reshape(12, 2)}
    cm.save(5, state, num_shards=4)
    es = ElasticScaler(num_hosts=4)
    es.fail(1)
    assert es.layout()["dp_degree"] == 3
    plan = es.reshard_plan(cm, {"p": state["p"][:4]})
    # healthy hosts 0,2,3 each get a contiguous 1/3 of rows
    rows = np.concatenate([plan[h][0]["p"] for h in (0, 2, 3)])
    np.testing.assert_array_equal(rows, state["p"])
    es.recover(1)
    assert es.layout()["dp_degree"] == 4
