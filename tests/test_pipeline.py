"""DAG scheduling (Algorithm 1), cost model, batcher, vector sharing."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.pipeline import (Dag, Node, OpProfile, PipelineExecutor,
                            VectorShareCache, WindowBatcher, batch_cost,
                            choose_batch_size, choose_device, filter_op,
                            groupby_agg, join, op_cost, run_batched,
                            simd_normalize_embed, window_op)


# -- DAG / Algorithm 1 ---------------------------------------------------

def _diamond():
    d = Dag()
    d.add(Node("src", "scan"))
    d.add(Node("a", "filter", fn=lambda x: x, cost_hint=1), deps=("src",))
    d.add(Node("b", "predict", fn=lambda x: x, cost_hint=9), deps=("src",))
    d.add(Node("c", "join", fn=lambda a, b: a, cost_hint=1,
               meta={"arg_order": {"a": 0, "b": 1}}), deps=("a", "b"))
    return d


def test_topological_order_and_priority():
    d = _diamond()
    order = d.execution_order()
    assert d.validate_topological(order)
    # higher-cost ready op scheduled first within a wave
    waves = d.stages()
    assert waves[1][0] == "b"


def test_cycle_detection():
    d = _diamond()
    d.edges.append(type(d.edges[0])("c", "a", "data"))
    with pytest.raises(ValueError):
        d.execution_order()


def test_edge_labels():
    d = _diamond()
    d.add(Node("ddl", "sink", fn=lambda x: x), deps=(),
          control_deps=("c",))
    labels = {(e.src, e.dst): e.label for e in d.label_edges()}
    assert labels[("c", "ddl")] == "control"
    assert labels[("src", "a")] == "data"


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.integers(0, 20))
def test_random_dag_topological(n, extra):
    """Property: random DAGs (edges only i->j, i<j) always get a valid
    topological order."""
    rng = np.random.default_rng(n * 101 + extra)
    d = Dag()
    for i in range(n):
        d.add(Node(f"n{i}", "scan", cost_hint=float(rng.random())),
              deps=tuple(f"n{j}" for j in range(i)
                         if rng.random() < 0.3))
    order = d.execution_order()
    assert d.validate_topological(order)
    assert len(order) == n


# -- cost model ----------------------------------------------------------

def test_cost_model_monotonic_rows():
    p = OpProfile(flops_per_row=1e6, bytes_per_row=1e3, model_bytes=1e7)
    assert op_cost(p, 10, "tpu") <= op_cost(p, 1000, "tpu")
    assert op_cost(p, 10, "host") <= op_cost(p, 1000, "host")


def test_device_choice_scales():
    small = OpProfile(flops_per_row=1e4, bytes_per_row=64, model_bytes=1e5)
    big = OpProfile(flops_per_row=2e9, bytes_per_row=4096, model_bytes=4e9)
    assert choose_device(small, 10) == "host"
    assert choose_device(big, 4096) == "tpu"


def test_api_device_by_latency():
    p = OpProfile(flops_per_row=1e12, bytes_per_row=1e6, model_bytes=8e10,
                  api_latency_s=0.02)
    # giant model, tiny batch: remote endpoint wins
    assert choose_device(p, 1) == "api"


def test_batch_size_tradeoff():
    p = OpProfile(flops_per_row=2e7, bytes_per_row=1e5, model_bytes=1e8)
    b = choose_batch_size(p, "tpu", mem_cap_bytes=4e6 + 1e8)
    assert b <= 32  # memory cap binds
    b2 = choose_batch_size(p, "tpu", mem_cap_bytes=1e12)
    assert b2 >= b


# -- batcher --------------------------------------------------------------

def test_batched_equals_unbatched():
    rng = np.random.default_rng(0)
    W = rng.standard_normal((8, 4)).astype(np.float32)
    rows = [rng.standard_normal(8).astype(np.float32) for _ in range(37)]
    f = lambda x: x @ W
    out1 = np.stack(run_batched(rows, f, batch_size=1))
    out16 = np.stack(run_batched(rows, f, batch_size=16))
    np.testing.assert_allclose(out1, out16, rtol=1e-6)


def test_window_batcher_stats():
    f = lambda x: x.sum(axis=1)
    b = WindowBatcher(f, batch_size=8)
    for i in range(20):
        b.add(i, np.ones(4))
    res = b.finish()
    assert len(res) == 20
    assert b.stats.batches == 3   # 8 + 8 + 4
    assert b.stats.rows == 20


# -- relational ops + sharing ---------------------------------------------

def test_join_groupby_window():
    left = {"k": np.array([1, 2, 2, 3]), "x": np.arange(4.0)}
    right = {"k": np.array([2, 3, 4]), "y": np.array([10.0, 20.0, 30.0])}
    j = join(left, right, "k")
    assert len(j["k"]) == 3  # 2,2,3 match
    g = groupby_agg(j, "k", "y", "mean")
    assert dict(zip(g["k"], g["mean_y"])) == {2: 10.0, 3: 20.0}
    w = window_op({"v": np.arange(10.0)}, "v", 3)
    assert "mean3_v" in w


def test_vector_share_cache_disk_tier(tmp_path):
    calls = {"n": 0}

    def embed(X):
        calls["n"] += 1
        return X @ np.ones((X.shape[1], 4), np.float32)

    c1 = VectorShareCache(tmp_path)
    X = np.ones((10, 8), np.float32)
    c1.get_or_embed("t", "c", X, embed)
    assert calls["n"] == 1
    c1.get_or_embed("t", "c", X, embed)
    assert calls["n"] == 1 and c1.hit_rate == 0.5
    # new process (fresh cache) hits the disk tier
    c2 = VectorShareCache(tmp_path)
    c2.get_or_embed("t", "c", X, embed)
    assert calls["n"] == 1


def test_fingerprint_rows_matches_content():
    from repro.pipeline.share import fingerprint_rows

    rng = np.random.default_rng(0)
    X = rng.standard_normal((64, 16)).astype(np.float32)
    fps = fingerprint_rows(X)
    assert fps.shape == (64,) and fps.dtype == np.uint64
    # deterministic, content-addressed: equal rows hash equal wherever
    # they sit; distinct rows hash distinct
    np.testing.assert_array_equal(fps, fingerprint_rows(X.copy()))
    Y = X.copy()
    Y[3] = X[40]
    fps2 = fingerprint_rows(Y)
    assert fps2[3] == fps[40]
    assert len(set(fps.tolist())) == 64
    # dtype participates: same bytes under another dtype must not alias
    assert (fingerprint_rows(X.view(np.int32)) != fps).any()
    assert fingerprint_rows(np.zeros((0, 4))).shape == (0,)
    # low-entropy rows (zeros with one hot bit) must still spread
    Z = np.zeros((32, 16), np.float32)
    Z[np.arange(32), np.arange(32) % 16] = 1.0 + np.arange(32) // 16
    assert len(set(fingerprint_rows(Z).tolist())) == 32


def test_share_cache_get_many_row_granular():
    cache = VectorShareCache()
    rng = np.random.default_rng(1)
    X = rng.standard_normal((20, 8)).astype(np.float32)
    E = np.tanh(X @ np.ones((8, 4), np.float32))
    keys, found, miss = cache.get_many("t", "c", X, version="v1")
    assert found is None and miss.all() and len(keys) == 20
    cache.put_many("t", "c", keys, E, version="v1")
    # overlapping second chunk: cached rows hit, the new row misses
    X2 = np.concatenate([X[5:], rng.standard_normal((1, 8))
                         .astype(np.float32)])
    k2, found2, miss2 = cache.get_many("t", "c", X2, version="v1")
    assert miss2.sum() == 1 and miss2[-1]
    np.testing.assert_allclose(found2[:-1], E[5:], atol=0)
    # version partitions the key space
    _, f3, m3 = cache.get_many("t", "c", X, version="v2")
    assert f3 is None and m3.all()
    assert cache.stats.hits == 15
    # single-row wrappers ride the same tier
    assert cache.get_row("t", "c", X[0], version="v1") is not None
    np.testing.assert_allclose(cache.get_row("t", "c", X[0],
                                             version="v1"), E[0])
    assert cache.get_row("t", "c", np.full(8, 9.0, np.float32),
                         version="v1") is None
    cache.put_row("t", "c", np.full(8, 9.0, np.float32),
                  np.ones(4, np.float32), version="v1")
    np.testing.assert_allclose(
        cache.get_row("t", "c", np.full(8, 9.0, np.float32),
                      version="v1"), np.ones(4))


def test_share_cache_single_row_block_stays_bounded():
    """A lone row block must shed its oldest rows at capacity instead of
    growing forever (and permanently starving the chunk tier)."""
    row_bytes = 4 * 4 + 8                     # width-4 float32 + fp
    cache = VectorShareCache(capacity_bytes=64 * row_bytes)
    rng = np.random.default_rng(0)
    for i in range(8):                        # 8 x 32 fresh rows, 1 block
        X = rng.standard_normal((32, 8)).astype(np.float32)
        keys, _, _ = cache.get_many("t", "c", X)
        cache.put_many("t", "c", keys, np.ones((32, 4), np.float32))
        assert cache._rows_used <= cache.capacity
        # the newest rows survive the shedding
        _, _, miss = cache.get_many("t", "c", X)
        assert not miss.any()


def test_share_cache_row_blocks_evict_lru():
    cache = VectorShareCache(capacity_bytes=4 * 64 * 4 * 2)  # ~2 blocks
    X = np.arange(64 * 8, dtype=np.float32).reshape(64, 8)
    E = np.ones((64, 4), np.float32)
    for i in range(4):                        # 4 key spaces, LRU evicts
        keys, _, _ = cache.get_many("t", f"c{i}", X)
        cache.put_many("t", f"c{i}", keys, E)
    _, found, miss = cache.get_many("t", "c0", X)
    assert found is None and miss.all()       # oldest block evicted
    _, found3, miss3 = cache.get_many("t", "c3", X)
    assert not miss3.any()                    # newest survives


def test_pipeline_chunked_matches_single_shot():
    rng = np.random.default_rng(0)
    n = 500
    table = {"x": rng.standard_normal((n, 8)).astype(np.float32),
             "v": rng.integers(0, 50, n)}
    W = rng.standard_normal((8, 3)).astype(np.float32)

    def predict(b):
        out = dict(b)
        out["p"] = (b["x"] @ W).sum(axis=1)
        return out

    d = Dag()
    d.add(Node("src", "scan"))
    d.add(Node("f", "filter",
               fn=lambda b: filter_op(b, lambda x: x["v"] > 10)),
          deps=("src",))
    d.add(Node("p", "predict", fn=predict, cost_hint=5), deps=("f",))
    ex = PipelineExecutor(d)
    full = ex.execute({"src": table})["p"]
    chunked = ex.execute_chunked("src", table, chunk_rows=64, sink_id="p")
    np.testing.assert_allclose(np.sort(full["p"]), np.sort(chunked["p"]),
                               rtol=1e-6)


def test_join_duplicate_keys_both_sides_ordering():
    """Vectorized sort-merge join must match hash-join semantics: probe
    rows in order, ties expanded in build-side row order."""
    left = {"k": np.array([2, 1, 2]), "x": np.array([10.0, 20.0, 30.0])}
    right = {"k": np.array([2, 3, 2, 1]),
             "y": np.array([1.0, 2.0, 3.0, 4.0])}
    j = join(left, right, "k")
    np.testing.assert_array_equal(j["k"], [2, 2, 1, 2, 2])
    np.testing.assert_array_equal(j["x"], [10.0, 10.0, 20.0, 30.0, 30.0])
    np.testing.assert_array_equal(j["y"], [1.0, 3.0, 4.0, 1.0, 3.0])


def test_join_string_keys_and_column_suffix():
    left = {"k": np.array(["a", "b", "c"]), "v": np.arange(3.0)}
    right = {"k": np.array(["b", "c", "d"]), "v": np.array([9.0, 8.0, 7.0])}
    j = join(left, right, "k")
    np.testing.assert_array_equal(j["k"], ["b", "c"])
    np.testing.assert_array_equal(j["v"], [1.0, 2.0])
    np.testing.assert_array_equal(j["v_r"], [9.0, 8.0])


def test_join_no_matches_and_empty_sides():
    left = {"k": np.array([1, 2]), "x": np.array([1.0, 2.0])}
    right = {"k": np.array([3, 4]), "y": np.array([5.0, 6.0])}
    j = join(left, right, "k")
    assert len(j["k"]) == 0 and len(j["y"]) == 0
    j2 = join({"k": np.zeros(0, np.int64), "x": np.zeros(0)},
              right, "k")
    assert len(j2["k"]) == 0
    j3 = join(left, {"k": np.zeros(0, np.int64), "y": np.zeros(0)}, "k")
    assert len(j3["k"]) == 0


def test_join_matches_naive_reference():
    rng = np.random.default_rng(0)
    left = {"k": rng.integers(0, 20, 200), "x": rng.standard_normal(200)}
    right = {"k": rng.integers(0, 20, 60), "y": rng.standard_normal(60)}
    j = join(left, right, "k")
    li, ri = [], []
    for i, k in enumerate(left["k"]):
        for jj, kk in enumerate(right["k"]):
            if k == kk:
                li.append(i)
                ri.append(jj)
    np.testing.assert_array_equal(j["k"], left["k"][li])
    np.testing.assert_allclose(j["x"], left["x"][li])
    np.testing.assert_allclose(j["y"], right["y"][ri])
