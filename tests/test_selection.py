"""Model selection: NMF invariants (hypothesis), forest regressor,
end-to-end two-phase selection beats random and approaches the oracle.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ModelSelector, RandomForestRegressor, RidgeRegressor,
                        TaskFeaturizer, build_tasks, build_zoo,
                        linear_probe_accuracy, nmf, reconstruction_error,
                        selection_regret, transfer_matrix)
from repro.core.task import TaskRegistry, TaskSpec


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 12), st.integers(4, 12), st.integers(1, 4))
def test_nmf_invariants(m, n, k):
    """W,H >= 0; loss non-increasing; low-rank matrices recovered."""
    rng = np.random.default_rng(m * 31 + n)
    Wt = rng.uniform(0.1, 1.0, (m, k)).astype(np.float32)
    Ht = rng.uniform(0.1, 1.0, (n, k)).astype(np.float32)
    V = Wt @ Ht.T
    res = nmf(V, k, iters=400)
    W, H = np.asarray(res.W), np.asarray(res.H)
    assert (W >= 0).all() and (H >= 0).all()
    losses = np.asarray(res.loss_curve)
    assert losses[-1] <= losses[5] + 1e-5
    assert reconstruction_error(V, res.W, res.H) < 1e-2


def test_nmf_masked():
    rng = np.random.default_rng(0)
    V = rng.uniform(0.2, 1.0, (10, 12)).astype(np.float32)
    mask = (rng.random((10, 12)) < 0.8).astype(np.float32)
    res = nmf(V, 4, iters=500, mask=mask)
    err = reconstruction_error(V, res.W, res.H, mask)
    assert err < 0.05


def test_forest_fits_nonlinear():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((300, 6)).astype(np.float32)
    Y = np.stack([np.sin(X[:, 0]) + X[:, 1] ** 2,
                  np.abs(X[:, 2])], axis=1).astype(np.float32)
    rf = RandomForestRegressor(n_trees=24, max_depth=8, seed=0).fit(X, Y)
    P = rf.predict(X)
    r2 = 1 - ((P - Y) ** 2).sum() / ((Y - Y.mean(0)) ** 2).sum()
    assert r2 > 0.6, r2
    # forest must beat a linear model on this target
    rr = RidgeRegressor(1e-2).fit(X, Y)
    Pr = rr.predict(X)
    r2_lin = 1 - ((Pr - Y) ** 2).sum() / ((Y - Y.mean(0)) ** 2).sum()
    assert r2 > r2_lin


@pytest.fixture(scope="module")
def selection_world():
    zoo = build_zoo(16, seed=0)
    hist = build_tasks(40, seed=1)
    V = transfer_matrix(zoo, hist)
    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in hist])
    targets = build_tasks(16, seed=99)
    Vt = transfer_matrix(zoo, targets)
    return zoo, hist, V, feats, targets, Vt


def test_two_phase_selection_beats_random(selection_world):
    zoo, hist, V, feats, targets, Vt = selection_world
    sel = ModelSelector(k=6, n_anchors=4).fit_offline(V, feats, zoo=zoo)
    regs, rand = [], []
    rng = np.random.default_rng(5)
    for j, t in enumerate(targets):
        r = selection_regret(sel, Vt[:, j], t.X, t.y)
        regs.append(r["regret"])
        rand.append(Vt[:, j].max() - Vt[rng.integers(len(zoo)), j])
    assert np.mean(regs) < np.mean(rand) * 0.75, (np.mean(regs),
                                                  np.mean(rand))
    assert np.mean(regs) < 0.08


def test_online_selection_is_fast(selection_world):
    zoo, hist, V, feats, targets, Vt = selection_world
    sel = ModelSelector(k=6, n_anchors=2).fit_offline(V, feats, zoo=zoo)
    rep = sel.select(targets[0].X, targets[0].y)
    assert rep.online_ms < 200  # vs seconds for exhaustive evaluation
    assert rep.scores.shape == (len(zoo),)


def test_task_registry_resolution(selection_world):
    zoo, hist, V, feats, targets, Vt = selection_world
    sel = ModelSelector(k=6, n_anchors=2).fit_offline(V, feats, zoo=zoo)
    reg = TaskRegistry(selector=sel, zoo=zoo)
    reg.create_task(TaskSpec("sentiment", "series", ("POS", "NEG")))
    with pytest.raises(ValueError):
        reg.create_task(TaskSpec("sentiment", "series", ("POS", "NEG")))
    t = targets[0]
    idx = reg.resolve("sentiment", t.X, t.y)
    assert 0 <= idx < len(zoo)
    assert reg.resolve("sentiment", t.X, t.y) == idx  # cached
    fn = reg.predict_fn("sentiment")
    out = fn(t.X[:5])
    assert out.shape[0] == 5
    with pytest.raises(KeyError):
        reg.resolve("nope", t.X, t.y)
