"""GPipe pipeline parallelism: forward + grad equivalence vs sequential."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_gpipe_matches_sequential_forward_and_grad():
    print(_run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.pipeline_parallel import (
            gpipe_apply, make_pipelined_fn, pipeline_bubble_fraction)
        from repro.launch.mesh import _make_mesh

        S, L_per, D, M, mb = 4, 2, 16, 8, 4
        # _make_mesh handles the AxisType compat across jax pins
        mesh = _make_mesh((S,), ('pod',))
        rng = jax.random.PRNGKey(0)
        # stage params: [S, L_per, D, D]
        Ws = jax.random.normal(rng, (S, L_per, D, D)) * (0.5 / D ** 0.5)

        def stage_fn(W, x):  # W: [L_per, D, D]
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, W)
            return h

        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))

        # sequential reference: all S*L_per layers in order
        def seq(Ws, x):
            h = x.reshape(M * mb, D)
            for s in range(S):
                h = stage_fn(Ws[s], h)
            return h.reshape(M, mb, D)

        ref = seq(Ws, x)
        piped = jax.jit(make_pipelined_fn(stage_fn, mesh))({'w': Ws}['w'], x) \
            if False else jax.jit(make_pipelined_fn(stage_fn, mesh))(Ws, x)
        err = float(jnp.abs(ref - piped).max())
        assert err < 1e-5, err

        # gradients flow through the ppermute ring
        f = make_pipelined_fn(stage_fn, mesh)
        g_pipe = jax.jit(jax.grad(lambda W: f(W, x).sum()))(Ws)
        g_ref = jax.grad(lambda W: seq(W, x).sum())(Ws)
        gerr = float(jnp.abs(g_pipe - g_ref).max())
        assert gerr < 1e-4, gerr
        assert abs(pipeline_bubble_fraction(8, 4) - 3/11) < 1e-9
        print('gpipe ok', err, gerr)
    """))
