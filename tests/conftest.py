"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; multi-device tests spawn subprocesses with their own flags.
"""
import _hypothesis_compat  # noqa: F401  (shim before test modules import it)
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def nprng():
    return np.random.default_rng(0)
