"""Chunked/naive attention equivalence + SSD/RG-LRU recurrence oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.attention import chunked_attention, naive_attention
from repro.models import mamba2


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("S,qc,kc", [(256, 64, 64), (256, 32, 128),
                                     (192, 48, 96)])
def test_chunked_matches_naive(causal, window, S, qc, kc):
    if window and not causal:
        pytest.skip("window implies causal here")
    rng = jax.random.PRNGKey(0)
    B, Hq, K, D = 2, 4, 2, 16
    q = jax.random.normal(rng, (B, S, Hq, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D))
    a = naive_attention(q, k, v, causal=causal, window=window)
    b = chunked_attention(q, k, v, causal=causal, window=window,
                          q_chunk=qc, kv_chunk=kc)
    assert float(jnp.abs(a - b).max()) < 1e-5


def test_cross_attention_unequal_lengths():
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 192, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 256, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 256, 2, 16))
    a = naive_attention(q, k, v, causal=False)
    b = chunked_attention(q, k, v, causal=False, q_chunk=64, kv_chunk=64)
    assert float(jnp.abs(a - b).max()) < 1e-5


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.integers(1, 2), st.sampled_from([64, 128]),
       st.sampled_from([8, 16]))
def test_chunked_attention_property(b, kheads, s, d):
    """Property: row-stochastic attention — outputs stay in the convex
    hull of V rows (max |o| <= max |v|)."""
    rng = jax.random.PRNGKey(b * 7 + s)
    q = jax.random.normal(rng, (b, s, 2 * kheads, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kheads, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kheads, d))
    o = chunked_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    assert jnp.all(jnp.isfinite(o))
    assert float(jnp.abs(o).max()) <= float(jnp.abs(v).max()) + 1e-4


# ---------------------------------------------------------------------------
# SSD chunked algorithm vs naive recurrence
# ---------------------------------------------------------------------------

def _ssd_naive(x, dt, A, B, C):
    """Step-by-step recurrence oracle: h = h*exp(dt*A) + dt * B (x) x."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bf = np.repeat(np.asarray(B, np.float64), rep, axis=2)
    Cf = np.repeat(np.asarray(C, np.float64), rep, axis=2)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    h = np.zeros((b, H, P, N))
    ys = []
    for t in range(S):
        decay = np.exp(dtf[:, t] * Af[None, :])              # [b,H]
        upd = np.einsum("bhn,bhp->bhpn", Bf[:, t],
                        xf[:, t] * dtf[:, t][..., None])
        h = h * decay[..., None, None] + upd
        ys.append(np.einsum("bhn,bhpn->bhp", Cf[:, t], h))
    return np.stack(ys, axis=1), h


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_recurrence(chunk):
    rng = np.random.default_rng(0)
    b, S, H, P, G, N = 2, 64, 4, 8, 2, 8
    x = jnp.asarray(rng.standard_normal((b, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, S, G, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, S, G, N)), jnp.float32)
    y, h = mamba2.ssd_chunked(x, dt, A, B, C, chunk)
    y_ref, h_ref = _ssd_naive(x, dt, A, B, C)
    assert float(np.abs(np.asarray(y) - y_ref).max()) < 1e-3
    assert float(np.abs(np.asarray(h) - h_ref).max()) < 1e-3


def test_rglru_scan_matches_stepwise():
    """associative_scan recurrence == per-step decode updates."""
    from repro.configs import smoke_config
    from repro.models import rglru
    from repro.models.spec import init_params
    cfg = smoke_config("recurrentgemma-9b")
    p = init_params(rglru.rglru_specs(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    full, (conv_f, h_f) = rglru.rglru_apply(cfg, p, x, return_state=True)
    # stepwise
    k = p["conv_w"].shape[0]
    w = cfg.rglru_width or cfg.d_model
    conv = jnp.zeros((2, k - 1, w))
    h = jnp.zeros((2, w))
    outs = []
    for t in range(16):
        o, (conv, h) = rglru.rglru_decode_step(cfg, p, x[:, t:t + 1], conv, h)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    assert float(jnp.abs(step - full).max()) < 1e-4
    assert float(jnp.abs(h - h_f).max()) < 1e-4
