"""Task-centric query engine: parser, optimizer passes, session
end-to-end parity with a hand-built DAG, chunked in-flight depth, and the
LRU/batcher fixes underneath it."""
import threading
import time

import numpy as np
import pytest

from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import (LogicalPlan, MorphingSession, QueryStmt, parse,
                          insert_embeds, push_down_filters)
from repro.pipeline import (ContinuousBatcher, Dag, Node, OpProfile,
                            PipelineExecutor, Request, VectorShareCache,
                            filter_op, groupby_agg, place_dag)


# -- fixtures --------------------------------------------------------------

@pytest.fixture(scope="module")
def mini_zoo():
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=120, dim=8, classes=3)
    return [pretrain_model(src, width=12, seed=1, name="m0"),
            pretrain_model(src, width=8, seed=2, name="m1")]


@pytest.fixture()
def session(tmp_path, mini_zoo):
    """Session with a forced resolution (no selector needed: the registry
    returns cached resolutions)."""
    sess = MorphingSession(zoo=mini_zoo, root=tmp_path, chunk_rows=64)
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0
    rng = np.random.default_rng(0)
    n = 400
    sess.register_table("reviews", {
        "gender": rng.integers(0, 2, n),
        "len": rng.integers(1, 200, n),
        "emb": rng.standard_normal((n, 8)).astype(np.float32)})
    sess.resolve_task("sent", np.zeros((4, 8), np.float32),
                      np.zeros(4, np.int64))
    return sess


# -- parser ----------------------------------------------------------------

def test_parse_multi_predicate_and_aggs():
    s = parse("SELECT gender, AVG(sent(emb)), COUNT(*), SUM(len) "
              "FROM reviews WHERE len > 20 AND gender != 1 "
              "GROUP BY gender")
    assert isinstance(s, QueryStmt)
    assert s.tasks == ["sent"]
    ops = s.plan.ops()
    assert ops == ["scan", "predict", "filter", "agg"]
    agg = s.plan.nodes[-1]
    assert agg.args["group_by"] == "gender"
    assert ("*", "count", "count") in agg.args["specs"]
    assert ("len", "sum", "sum_len") in agg.args["specs"]
    filt = s.plan.nodes[2]
    assert filt.args["preds"] == [("len", ">", 20), ("gender", "!=", 1)]


def test_parse_predict_using_task():
    s = parse("PREDICT emb USING TASK sent FROM reviews WHERE len > 150")
    assert s.tasks == ["sent"]
    assert s.plan.ops() == ["scan", "predict", "filter"]


def test_parse_errors():
    with pytest.raises(ValueError):
        parse("SELECT gender, AVG(x) FROM t")       # bare col, no GROUP BY
    with pytest.raises(ValueError):
        parse("DELETE FROM t")
    with pytest.raises(ValueError):
        parse("SELECT a FROM t GROUP BY a")         # GROUP BY without agg


# -- optimizer passes ------------------------------------------------------

def test_pushdown_moves_base_column_filter_below_predict():
    plan = (LogicalPlan.scan("t").predict("task", "emb")
            .filter([("len", ">", 5)]).agg("g", [("_score", "mean", "m")]))
    push_down_filters(plan)
    assert plan.ops() == ["scan", "filter", "predict", "agg"]


def test_pushdown_keeps_filter_on_inference_output():
    plan = (LogicalPlan.scan("t").predict("task", "emb")
            .filter([("_score", ">", 0)]))
    push_down_filters(plan)
    assert plan.ops() == ["scan", "predict", "filter"]


def test_embed_insertion_splits_predict():
    plan = LogicalPlan.scan("t").predict("task", "emb")
    insert_embeds(plan)
    assert plan.ops() == ["scan", "embed", "predict"]
    assert plan.nodes[2].args["head_only"]
    assert plan.nodes[1].args["out"] == plan.nodes[2].args["col"]


def test_optimizer_annotates_device_and_batch(session):
    res = session.sql("SELECT gender, AVG(sent(emb)) FROM reviews "
                      "WHERE len > 20 GROUP BY gender")
    rep = res.report
    assert "embed" in rep.device_of and rep.device_of["embed"] in (
        "host", "tpu", "api")
    assert rep.batch_size_of["sent"] >= 1
    # pushdown happened: filter ran before embed in the compiled plan
    assert rep.plan.index("filter") < rep.plan.index("embed")


# -- session end-to-end ----------------------------------------------------

def test_sql_matches_hand_built_dag(session, mini_zoo):
    res = session.sql("SELECT gender, AVG(sent(emb)) FROM reviews "
                      "WHERE len > 20 GROUP BY gender")
    model = session.models["sent"]
    table = session.tables["reviews"]

    def predict_node(b):
        out = dict(b)
        out["_score"] = model.features(b["emb"]).mean(axis=1)
        return out

    dag = Dag()
    dag.add(Node("reviews", "scan"))
    dag.add(Node("where", "filter",
                 fn=lambda b: filter_op(b, lambda x: x["len"] > 20)),
            deps=("reviews",))
    dag.add(Node("pred", "predict", fn=predict_node, cost_hint=5),
            deps=("where",))
    dag.add(Node("agg", "groupby",
                 fn=lambda b: groupby_agg(b, "gender", "_score")),
            deps=("pred",))
    ref = PipelineExecutor(dag).execute({"reviews": table})["agg"]
    np.testing.assert_array_equal(res.rows["gender"], ref["gender"])
    np.testing.assert_allclose(res.rows["mean__score"], ref["mean__score"],
                               rtol=1e-5)


def test_repeated_query_hits_share_cache(session):
    r1 = session.sql("SELECT gender, AVG(sent(emb)) FROM reviews "
                     "GROUP BY gender")
    assert r1.report.share_hits == 0 and r1.report.share_misses > 0
    r2 = session.sql("SELECT gender, AVG(sent(emb)) FROM reviews "
                     "GROUP BY gender")
    assert r2.report.share_hit_rate == 1.0
    np.testing.assert_allclose(r1.rows["mean__score"],
                               r2.rows["mean__score"], rtol=1e-6)


def test_plain_aggregates_no_group_by(session):
    res = session.sql("SELECT COUNT(*), SUM(len), AVG(len) FROM reviews "
                      "WHERE len > 100")
    t = session.tables["reviews"]
    mask = t["len"] > 100
    assert res.rows["count"][0] == mask.sum()
    np.testing.assert_allclose(res.rows["sum_len"][0], t["len"][mask].sum())
    np.testing.assert_allclose(res.rows["mean_len"][0],
                               t["len"][mask].mean())


def test_empty_chunk_keeps_embed_width(tmp_path, mini_zoo):
    """A fully-filtered chunk must emit (0, width) embeddings so
    cross-chunk concatenation doesn't shape-mismatch."""
    sess = MorphingSession(zoo=mini_zoo, root=tmp_path, chunk_rows=64)
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0
    rng = np.random.default_rng(1)
    n = 200
    ln = np.concatenate([np.zeros(64, np.int64),      # chunk 0 all filtered
                         rng.integers(100, 200, n - 64)])
    sess.register_table("reviews", {
        "gender": rng.integers(0, 2, n), "len": ln,
        "emb": rng.standard_normal((n, 8)).astype(np.float32)})
    sess.resolve_task("sent", np.zeros((4, 8), np.float32),
                      np.zeros(4, np.int64))
    res = sess.sql("SELECT gender, AVG(sent(emb)) FROM reviews "
                   "WHERE len > 50 GROUP BY gender")
    assert res.report.rows_out == 2


def test_select_list_projects_columns(session):
    res = session.sql("SELECT gender FROM reviews WHERE len > 20")
    assert list(res.rows) == ["gender"]
    res2 = session.sql("SELECT sent(emb) FROM reviews WHERE len > 190")
    assert list(res2.rows) == ["_score"]


def test_fingerprint_sees_mid_buffer_mutations(session):
    t = session.tables["reviews"]
    before = session.sql("SELECT gender, AVG(sent(emb)) FROM reviews "
                         "GROUP BY gender").rows["mean__score"]
    t["emb"][150:160] += 5.0
    after = session.sql("SELECT gender, AVG(sent(emb)) FROM reviews "
                        "GROUP BY gender").rows["mean__score"]
    assert not np.allclose(before, after)


def test_bare_task_call_with_aggregates_rejected():
    with pytest.raises(ValueError):
        parse("SELECT sent(emb), AVG(len) FROM reviews")


def test_zero_row_table_keeps_schema(tmp_path, mini_zoo):
    sess = MorphingSession(zoo=mini_zoo, root=tmp_path)
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0
    sess.register_table("empty", {
        "gender": np.zeros(0, np.int64), "len": np.zeros(0, np.int64),
        "emb": np.zeros((0, 8), np.float32)})
    sess.resolve_task("sent", np.zeros((4, 8), np.float32),
                      np.zeros(4, np.int64))
    res = sess.sql("SELECT gender, AVG(sent(emb)) FROM empty "
                   "WHERE len > 5 GROUP BY gender")
    assert list(res.rows) == ["gender", "mean__score"]
    assert res.report.rows_out == 0
    res2 = sess.sql("SELECT gender FROM empty")
    assert list(res2.rows) == ["gender"] and len(res2.rows["gender"]) == 0


def test_predict_statement_rows(session):
    res = session.sql("PREDICT emb USING TASK sent FROM reviews "
                      "WHERE len > 150")
    t = session.tables["reviews"]
    assert res.report.rows_out == int((t["len"] > 150).sum())
    assert "_score" in res.rows


def test_model_served_from_blob_store(session, tmp_path):
    """Resolution persists weights via the BLOB store + catalog; the
    served model is reconstructed from storage."""
    info = session.catalog.get_model("m0")
    assert info.storage == "blob"
    assert (session.root / "models" / "m0.blob").exists()


def test_unresolved_task_raises(tmp_path, mini_zoo):
    sess = MorphingSession(zoo=mini_zoo, root=tmp_path)
    sess.create_task(TaskSpec("t2", "series", ("A",)))
    sess.register_table("x", {"emb": np.zeros((4, 8), np.float32)})
    with pytest.raises(RuntimeError):
        sess.sql("SELECT AVG(t2(emb)) FROM x")


# -- chunked execution depth ----------------------------------------------

def _depth_dag(active, max_seen, lock):
    def slow(b):
        with lock:
            active[0] += 1
            max_seen[0] = max(max_seen[0], active[0])
        time.sleep(0.002)
        with lock:
            active[0] -= 1
        return b
    d = Dag()
    d.add(Node("src", "scan"))
    d.add(Node("p", "predict", fn=slow, cost_hint=5), deps=("src",))
    return d


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_execute_chunked_inflight_depth(depth):
    table = {"x": np.arange(512.0)}
    active, max_seen, lock = [0], [0], threading.Lock()
    ex = PipelineExecutor(_depth_dag(active, max_seen, lock))
    out = ex.execute_chunked("src", table, chunk_rows=32, sink_id="p",
                             max_inflight=depth)
    np.testing.assert_array_equal(np.sort(out["x"]), table["x"])
    assert max_seen[0] <= depth


# -- satellite fixes under the engine -------------------------------------

def test_share_cache_lru_recency_and_accounting():
    embeds = {"n": 0}

    def embed(X):
        embeds["n"] += 1
        return X.astype(np.float32)

    a = np.ones((1, 256), np.float32)
    b = np.full((1, 256), 2.0, np.float32)
    c = np.full((1, 256), 3.0, np.float32)
    cache = VectorShareCache(capacity_bytes=2 * a.nbytes)
    cache.get_or_embed("t", "c", a, embed)
    cache.get_or_embed("t", "c", b, embed)
    cache.get_or_embed("t", "c", a, embed)        # hit refreshes recency
    cache.get_or_embed("t", "c", c, embed)        # evicts b, not a
    assert embeds["n"] == 3
    cache.get_or_embed("t", "c", a, embed)        # still cached
    assert embeds["n"] == 3
    cache.get_or_embed("t", "c", b, embed)        # b was evicted
    assert embeds["n"] == 4
    assert cache._used == sum(v.nbytes for v in cache._mem.values())


def test_share_cache_disk_hit_no_duplicate_accounting(tmp_path):
    X = np.ones((4, 16), np.float32)
    c1 = VectorShareCache(tmp_path)
    c1.get_or_embed("t", "c", X, lambda x: x)
    c2 = VectorShareCache(tmp_path)
    c2.get_or_embed("t", "c", X, lambda x: x)     # disk tier
    c2.get_or_embed("t", "c", X, lambda x: x)     # memory tier
    assert len(c2._mem) == 1
    assert c2._used == next(iter(c2._mem.values())).nbytes
    assert c2.stats.hits == 2 and c2.stats.misses == 0


def test_continuous_batcher_blocks_instead_of_spinning():
    prof = OpProfile(flops_per_row=1e3, bytes_per_row=64, model_bytes=1e4)
    cb = ContinuousBatcher(lambda xs: [x * 2 for x in xs], prof,
                           device="host", max_wait_s=0.005,
                           idle_wait_s=0.05)
    t = threading.Thread(target=lambda: [
        time.sleep(0.02),
        [cb.submit(Request(i, i)) for i in range(8)]])
    t0 = time.time()
    t.start()
    res = cb.run(total=8)
    t.join()
    assert res == {i: i * 2 for i in range(8)}
    # empty-queue polls block (idle_wait_s), so the run loop iterates few
    # times rather than busy-spinning thousands of 2ms polls
    assert time.time() - t0 < 2.0
    assert cb._collect() == []                     # times out, no spin


def test_place_dag_annotates_nodes():
    d = Dag()
    d.add(Node("src", "scan"))
    d.add(Node("p", "predict", fn=lambda b: b, cost_hint=5), deps=("src",))
    placement = place_dag(d, {"p": OpProfile(
        flops_per_row=2e9, bytes_per_row=4096, model_bytes=4e9)},
        nrows_hint=4096)
    assert placement["p"] == "tpu" and d.nodes["p"].device == "tpu"
    assert placement["src"] == "host"
