"""Semantic share cache: IVF-flat ANN index, calibrated-radius embedding
reuse (error-bounded vs the exact oracle, hypothesis property tests),
the CacheTier/CacheChain protocol, SIMILARITY query lowering, and the
shared EngineConfig construction surface."""
import tempfile
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import (EngineConfig, LogicalPlan, MorphingServer,
                          MorphingSession, lower_similarity, parse)
from repro.engine.sql import encode_text
from repro.pipeline.share import (AnnConfig, AnnShareTier, CacheChain,
                                  CacheTier, IvfFlatIndex, TierLookup,
                                  VectorShareCache, fingerprint_rows)


# -- fixtures --------------------------------------------------------------

@pytest.fixture(scope="module")
def mini_zoo():
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=120, dim=8, classes=3)
    return [pretrain_model(src, width=12, seed=1, name="m0")]


def _session(tmp_path, zoo, **kw):
    sess = MorphingSession(zoo=zoo, root=tmp_path, chunk_rows=64, **kw)
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0
    return sess


def _resolve(sess):
    sess.resolve_task("sent", np.zeros((4, 8), np.float32),
                      np.zeros(4, np.int64))


def _iso_embed(dim, out, scale, seed=0):
    """Isometry-scaled linear embedder: ||f(a)-f(b)|| == scale*||a-b||
    exactly, so the calibrated Lipschitz estimate equals ``scale`` and
    the tier's error bound is a theorem, not a hope."""
    m = max(dim, out)
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((m, m)))
    M = (scale * q[:dim]).astype(np.float32)   # M @ M.T == scale^2 * I
    return lambda A: np.asarray(A, np.float32).reshape(len(A), -1) @ M


# -- IVF-flat index --------------------------------------------------------

def test_ivf_full_probe_matches_brute_force():
    rng = np.random.default_rng(0)
    V = rng.standard_normal((300, 16)).astype(np.float32)
    idx = IvfFlatIndex(nlist=8, nprobe=8, train_min=32)
    idx.add(V)
    Q = rng.standard_normal((50, 16)).astype(np.float32)
    d, i = idx.search1(Q)
    bd = np.linalg.norm(Q[:, None] - V[None], axis=2)
    np.testing.assert_array_equal(i, bd.argmin(axis=1))
    np.testing.assert_allclose(d, bd.min(axis=1), rtol=1e-4, atol=1e-5)


def test_ivf_below_train_min_is_brute_force():
    rng = np.random.default_rng(1)
    V = rng.standard_normal((10, 4)).astype(np.float32)
    idx = IvfFlatIndex(nlist=4, nprobe=1, train_min=64)
    idx.add(V)
    d, i = idx.search1(V)
    np.testing.assert_array_equal(i, np.arange(10))
    assert (d < 1e-2).all()


def test_ivf_incremental_add_and_retrain():
    rng = np.random.default_rng(2)
    idx = IvfFlatIndex(nlist=8, nprobe=8, train_min=32)
    chunks = [rng.standard_normal((80, 8)).astype(np.float32)
              for _ in range(4)]
    for c in chunks:
        idx.add(c)
    V = np.concatenate(chunks)
    assert len(idx) == len(V)
    # every member row finds itself at distance ~0 (full probe)
    d, i = idx.search1(V[::7])
    np.testing.assert_array_equal(i, np.arange(len(V))[::7])
    assert (d < 1e-2).all()


def test_ivf_recall_floor_on_near_duplicates():
    """Default nprobe on a seeded near-duplicate corpus: >= 0.95 of
    queries must find their true (very close) nearest neighbor."""
    rng = np.random.default_rng(3)
    V = rng.standard_normal((600, 12)).astype(np.float32)
    idx = IvfFlatIndex(nlist=16, nprobe=4, train_min=64)
    idx.add(V)
    Q = V + rng.standard_normal(V.shape).astype(np.float32) * 1e-3
    _, i = idx.search1(Q)
    recall = float((i == np.arange(len(V))).mean())
    assert recall >= 0.95, recall


def test_ivf_empty_and_miss():
    idx = IvfFlatIndex()
    d, i = idx.search1(np.zeros((3, 4), np.float32))
    assert (i == -1).all() and np.isinf(d).all()


# -- CacheTier protocol + chain --------------------------------------------

def test_cache_tier_protocol_isinstance():
    assert isinstance(VectorShareCache(), CacheTier)
    assert isinstance(AnnShareTier(), CacheTier)


def test_exact_tier_lookup_insert_roundtrip():
    cache = VectorShareCache()
    rng = np.random.default_rng(4)
    rows = rng.standard_normal((20, 6)).astype(np.float32)
    embs = rng.standard_normal((20, 3)).astype(np.float32)
    tl = cache.lookup_many("t", "c", rows)
    assert isinstance(tl, TierLookup) and tl.miss.all()
    cache.insert_many("t", "c", tl.keys, rows, embs)
    tl2 = cache.lookup_many("t", "c", rows)
    assert not tl2.miss.any() and tl2.hits == 20
    np.testing.assert_allclose(tl2.found, embs)
    assert len(tl2.approx_idx) == 0      # exact tier never approximates


def test_chain_exact_tier_leads():
    """A row in the exact tier is served byte-exact even when the ANN
    tier could approximate it."""
    exact = VectorShareCache()
    ann = AnnShareTier(AnnConfig(max_dist=10.0, audit_rate=0.0))
    chain = CacheChain([exact, ann])
    rng = np.random.default_rng(5)
    rows = rng.standard_normal((30, 6)).astype(np.float32)
    embs = rng.standard_normal((30, 4)).astype(np.float32)
    keys = fingerprint_rows(rows)
    chain.insert_many("t", "c", keys, rows, embs)
    tl = chain.lookup_many("t", "c", rows)
    assert not tl.miss.any()
    assert len(tl.approx_idx) == 0
    np.testing.assert_allclose(tl.found, embs)
    # a near-duplicate falls through to the ANN tier
    q = rows[:5] + 1e-4
    tq = chain.lookup_many("t", "c", q)
    assert not tq.miss.any() and len(tq.approx_idx) == 5
    np.testing.assert_allclose(tq.found, embs[:5])


def test_ann_cold_tier_never_serves():
    """Until calibration the radius is 0: the tier cannot serve wild
    guesses from an uncalibrated distance threshold."""
    ann = AnnShareTier(AnnConfig())
    rng = np.random.default_rng(6)
    rows = rng.standard_normal((40, 8)).astype(np.float32)
    ann.insert_many("t", "c", fingerprint_rows(rows), rows,
                    rng.standard_normal((40, 4)).astype(np.float32))
    assert ann.radius("t", "c") == 0.0
    tl = ann.lookup_many("t", "c", rows + 1e-6)
    assert tl.miss.all()


def test_ann_calibrates_and_serves_within_radius():
    cfg = AnnConfig(error_bound=0.1, audit_rate=0.0, seed=0)
    ann = AnnShareTier(cfg)
    embed = _iso_embed(8, 4, scale=2.0)
    rng = np.random.default_rng(7)
    base = rng.standard_normal((200, 8)).astype(np.float32)
    ann.insert_many("t", "c", fingerprint_rows(base), base, embed(base))
    near = base + rng.standard_normal(base.shape).astype(np.float32) * 1e-3
    ann.insert_many("t", "c", fingerprint_rows(near), near, embed(near))
    r = ann.radius("t", "c")
    # isometry: lip_hat == 2.0 exactly -> radius == bound/(1.5*2)
    assert r == pytest.approx(cfg.error_bound / (1.5 * 2.0), rel=1e-3)
    probe = base + rng.standard_normal(base.shape).astype(np.float32) \
        * (r * 0.2)
    tl = ann.lookup_many("t", "c", probe)
    assert tl.hits > 0.9 * len(probe)
    # every served embedding is within the error bound of the oracle
    err = np.linalg.norm(tl.found[~tl.miss] - embed(probe)[~tl.miss],
                         axis=1)
    assert err.max() <= cfg.error_bound + 1e-5
    # far rows stay misses
    far = base + 10.0
    assert ann.lookup_many("t", "c", far).miss.all()


def test_record_audit_counts_false_accepts_and_shrinks_radius():
    ann = AnnShareTier(AnnConfig(error_bound=0.1))
    rng = np.random.default_rng(8)
    base = rng.standard_normal((100, 8)).astype(np.float32)
    embed = _iso_embed(8, 4, scale=1.0)
    ann.insert_many("t", "c", fingerprint_rows(base), base, embed(base))
    near = base + 1e-3
    ann.insert_many("t", "c", fingerprint_rows(near), near, embed(near))
    r0 = ann.radius("t", "c")
    assert r0 > 0
    # report an audited hit whose exact recomputation blew the bound
    ann.record_audit("t", "c", "v1", dists=np.array([r0 / 2]),
                     errors=np.array([0.5]))
    assert ann.stats.false_accepts == 1
    assert ann.radius("t", "c") < r0


def test_chain_get_or_embed_single_flight_and_audit():
    calls = {"rows": 0}
    embed = _iso_embed(6, 3, scale=1.0)

    def counting_embed(A):
        calls["rows"] += len(A)
        return embed(A)

    chain = CacheChain([VectorShareCache(),
                        AnnShareTier(AnnConfig(error_bound=0.1,
                                               audit_rate=1.0))])
    rng = np.random.default_rng(9)
    rows = rng.standard_normal((50, 6)).astype(np.float32)
    dup = np.concatenate([rows, rows])      # in-flight duplicates
    E = chain.get_or_embed("t", "c", dup, counting_embed)
    assert calls["rows"] == 50              # single-flight dedup
    np.testing.assert_allclose(E, embed(dup), atol=1e-5)
    # warm: no new computation
    chain.get_or_embed("t", "c", rows, counting_embed)
    assert calls["rows"] == 50
    # near-duplicates calibrate, then serve approximately; with
    # audit_rate=1 every approx hit is recomputed exactly and served
    # exact (keeping the radius honest costs the audit rows only)
    near = rows + 1e-4
    chain.get_or_embed("t", "c", near, counting_embed)
    near2 = rows + 2e-4
    before = calls["rows"]
    E2 = chain.get_or_embed("t", "c", near2, counting_embed)
    ann = chain.ann
    assert ann.stats.approx_hits > 0
    assert ann.stats.audits > 0
    np.testing.assert_allclose(E2, embed(near2), atol=1e-5)  # audited=exact
    assert calls["rows"] > before           # audits did recompute


# -- hypothesis property tests ---------------------------------------------

@settings(max_examples=10, deadline=None)
@given(dim=st.sampled_from([4, 8, 16]),
       out=st.sampled_from([2, 4]),
       scale=st.floats(min_value=0.5, max_value=4.0),
       dtype=st.sampled_from(["float32", "float64"]),
       eps_frac=st.floats(min_value=0.05, max_value=0.9))
def test_property_ann_error_within_bound(dim, out, scale, dtype,
                                         eps_frac):
    """Across dtypes/shapes/scales: every ANN-served embedding is within
    the configured error bound of the exact oracle."""
    cfg = AnnConfig(error_bound=0.2, audit_rate=0.0, seed=1)
    chain = CacheChain([VectorShareCache(), AnnShareTier(cfg)])
    embed = _iso_embed(dim, out, scale=scale, seed=dim)
    rng = np.random.default_rng(dim * 31 + out)
    base = rng.standard_normal((150, dim)).astype(dtype)
    chain.get_or_embed("t", "c", base, embed)
    chain.get_or_embed("t", "c", (base + 1e-3).astype(dtype), embed)
    ann = chain.ann
    r = ann.radius("t", "c")
    assert r == pytest.approx(cfg.error_bound / (1.5 * scale), rel=1e-2)
    probe = (base + rng.standard_normal(base.shape)
             * (r * eps_frac / np.sqrt(dim))).astype(dtype)
    served = chain.get_or_embed("t", "c", probe, embed)
    err = np.linalg.norm(served - embed(probe), axis=1)
    assert err.max() <= cfg.error_bound + 1e-4
    assert ann.stats.approx_hits > 0        # the tier actually served


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_property_recall_floor_seeded_corpus(seed):
    """Recall floor: on a seeded near-duplicate corpus with a calibrated
    radius, >= 95% of in-radius queries are served by the tier."""
    cfg = AnnConfig(error_bound=0.3, audit_rate=0.0, seed=2)
    ann = AnnShareTier(cfg)
    embed = _iso_embed(8, 4, scale=1.0, seed=seed)
    rng = np.random.default_rng(seed)
    base = rng.standard_normal((400, 8)).astype(np.float32)
    ann.insert_many("t", "c", fingerprint_rows(base), base, embed(base))
    near = base + rng.standard_normal(base.shape).astype(np.float32) * 1e-3
    ann.insert_many("t", "c", fingerprint_rows(near), near, embed(near))
    r = ann.radius("t", "c")
    probe = base + rng.standard_normal(base.shape).astype(np.float32) \
        * (r * 0.1)
    tl = ann.lookup_many("t", "c", probe)
    assert tl.hits / len(probe) >= 0.95


# -- deprecated row-level wrappers -----------------------------------------

def test_get_row_put_row_deprecated_but_working():
    cache = VectorShareCache()
    row = np.arange(6, dtype=np.float32)
    emb = np.ones(3, np.float32)
    with pytest.warns(DeprecationWarning):
        assert cache.get_row("t", "c", row) is None
    with pytest.warns(DeprecationWarning):
        cache.put_row("t", "c", row, emb)
    with pytest.warns(DeprecationWarning):
        got = cache.get_row("t", "c", row)
    np.testing.assert_allclose(got, emb)
    assert cache.stats.hits >= 1 and cache.stats.misses >= 1


# -- SIMILARITY parsing + lowering -----------------------------------------

def test_parse_similarity_vector_and_limit():
    s = parse("SELECT id FROM t ORDER BY SIMILARITY(emb, [1.0, -2, 0.5]) "
              "LIMIT 5")
    assert s.plan.ops() == ["scan", "project", "sort", "limit"]
    srt = s.plan.nodes[2]
    np.testing.assert_allclose(srt.args["query"], [1.0, -2.0, 0.5])
    assert srt.args["ascending"] is False
    assert srt.args["drop_col"] == "emb"     # carried only for ordering
    assert s.plan.nodes[3].args["k"] == 5


def test_parse_similarity_text_asc_and_predict():
    s = parse("PREDICT emb USING TASK sent FROM t "
              "ORDER BY SIMILARITY(emb, 'cheap hotel') ASC LIMIT 3")
    srt = next(n for n in s.plan.nodes if n.op == "sort")
    assert srt.args["query"] == "cheap hotel"
    assert srt.args["ascending"] is True


def test_parse_similarity_errors():
    with pytest.raises(ValueError, match="aggregates"):
        parse("SELECT COUNT(*) FROM t ORDER BY SIMILARITY(e, [1]) LIMIT 2")
    with pytest.raises(ValueError, match="LIMIT"):
        parse("SELECT a FROM t LIMIT 0")
    with pytest.raises(ValueError, match="quoted"):
        parse("SELECT a FROM t ORDER BY SIMILARITY(e, bare) LIMIT 2")


def test_encode_text_deterministic_unit_norm():
    a = encode_text("hello world", 16)
    b = encode_text("hello world", 16)
    np.testing.assert_array_equal(a, b)
    assert np.linalg.norm(a) == pytest.approx(1.0)
    assert not np.allclose(a, encode_text("other text", 16))


def test_lower_similarity_pass_conditions():
    q = np.ones(3, np.float32)
    p = LogicalPlan.scan("t").project(["a", "e"]) \
        .order_by_similarity("e", q).limit(4)
    p = lower_similarity(p)
    assert p.ops() == ["index_scan", "project"]
    assert p.nodes[0].args["k"] == 4 and p.nodes[0].args["table"] == "t"
    # a filter blocks the lowering (predicates must see all rows)
    p2 = lower_similarity(LogicalPlan.scan("t").filter([("a", ">", 1)])
                          .order_by_similarity("e", q).limit(4))
    assert p2.nodes[0].op == "scan"
    # ascending (farthest-first) blocks it too
    p3 = lower_similarity(LogicalPlan.scan("t")
                          .order_by_similarity("e", q, ascending=True)
                          .limit(4))
    assert p3.nodes[0].op == "scan"
    # no limit: full sort, nothing to index-scan
    p4 = lower_similarity(LogicalPlan.scan("t")
                          .order_by_similarity("e", q))
    assert p4.nodes[0].op == "scan"


# -- similarity queries end-to-end -----------------------------------------

def test_similarity_topk_warm_cache_no_trunk(tmp_path, mini_zoo):
    sess = _session(tmp_path, mini_zoo,
                    config=EngineConfig(cache_tiers=("exact", "ann"),
                                        ann=AnnConfig(error_bound=0.2)))
    _resolve(sess)
    rng = np.random.default_rng(0)
    n = 200
    T = {"id": np.arange(n),
         "emb": rng.standard_normal((n, 8)).astype(np.float32)}
    sess.register_table("reviews", T)
    sess.sql("PREDICT emb USING TASK sent FROM reviews")     # warm cache
    q = T["emb"][17]
    vec = "[" + ", ".join(f"{x:.6f}" for x in q) + "]"
    res = sess.sql(f"PREDICT emb USING TASK sent FROM reviews "
                   f"ORDER BY SIMILARITY(emb, {vec}) LIMIT 5")
    assert res.report.index_scan
    assert res.report.sim_trunk_rows == 0       # warm: no trunk forward
    assert res.rows["id"][0] == 17              # nearest = the row itself
    assert len(res.rows["id"]) == 5
    assert res.rows["_sim"][0] == pytest.approx(0.0, abs=1e-5)
    assert (np.diff(res.rows["_sim"]) <= 1e-6).all()   # nearest first


def test_similarity_select_drops_order_column(tmp_path, mini_zoo):
    sess = _session(tmp_path, mini_zoo)
    _resolve(sess)
    rng = np.random.default_rng(1)
    T = {"id": np.arange(50),
         "emb": rng.standard_normal((50, 8)).astype(np.float32)}
    sess.register_table("reviews", T)
    vec = "[" + ", ".join(f"{x:.6f}" for x in T["emb"][3]) + "]"
    res = sess.sql(f"SELECT id FROM reviews "
                   f"ORDER BY SIMILARITY(emb, {vec}) LIMIT 3")
    assert list(res.rows) == ["id", "_sim"]     # emb carried then dropped
    assert res.rows["id"][0] == 3
    assert res.report.index_scan                # raw row space lowers too


def test_similarity_with_filter_falls_back(tmp_path, mini_zoo):
    sess = _session(tmp_path, mini_zoo)
    _resolve(sess)
    rng = np.random.default_rng(2)
    n = 80
    T = {"id": np.arange(n), "len": rng.integers(0, 100, n),
         "emb": rng.standard_normal((n, 8)).astype(np.float32)}
    sess.register_table("reviews", T)
    vec = "[" + ", ".join(f"{x:.6f}" for x in T["emb"][5]) + "]"
    res = sess.sql(f"SELECT id FROM reviews WHERE len >= 0 "
                   f"ORDER BY SIMILARITY(emb, {vec}) LIMIT 4")
    assert not res.report.index_scan            # filter blocks lowering
    assert res.rows["id"][0] == 5               # but ordering still holds
    assert len(res.rows["id"]) == 4


def test_similarity_text_query_runs(tmp_path, mini_zoo):
    sess = _session(tmp_path, mini_zoo)
    _resolve(sess)
    rng = np.random.default_rng(3)
    T = {"id": np.arange(30),
         "emb": rng.standard_normal((30, 8)).astype(np.float32)}
    sess.register_table("reviews", T)
    res = sess.sql("SELECT id FROM reviews "
                   "ORDER BY SIMILARITY(emb, 'some query text') LIMIT 2")
    assert len(res.rows["id"]) == 2


def test_session_ann_scores_match_exact_oracle(tmp_path, mini_zoo):
    """End-to-end: ANN-mode predictions on near-duplicate traffic match
    the exact session's scores within the configured error bound."""
    bound = 0.2
    sess = _session(tmp_path, mini_zoo,
                    config=EngineConfig(cache_tiers=("exact", "ann"),
                                        ann=AnnConfig(error_bound=bound,
                                                      audit_rate=0.0)))
    _resolve(sess)
    rng = np.random.default_rng(4)
    n = 200
    base = rng.standard_normal((n, 8)).astype(np.float32)
    sess.register_table("t", {"emb": base})
    sess.sql("PREDICT emb USING TASK sent FROM t")            # fill
    near1 = base + rng.standard_normal((n, 8)).astype(np.float32) * 1e-3
    sess.register_table("t", {"emb": near1})
    sess.sql("PREDICT emb USING TASK sent FROM t")            # calibrate
    near2 = base + rng.standard_normal((n, 8)).astype(np.float32) * 1e-3
    sess.register_table("t", {"emb": near2})
    res = sess.sql("PREDICT emb USING TASK sent FROM t")
    assert res.report.approx_hits > 0
    rm = sess.models["sent"]
    oracle = rm.head(rm.features(near2))
    err = np.abs(np.asarray(res.rows["_score"]) - oracle)
    assert err.max() <= bound + 1e-5


# -- EngineConfig ----------------------------------------------------------

def test_engine_config_and_kwargs_equivalent(tmp_path, mini_zoo):
    a = MorphingSession(zoo=mini_zoo, root=tmp_path / "a",
                        config=EngineConfig(chunk_rows=32, workers=2,
                                            enable_share=False,
                                            model_store="decoupled"))
    b = MorphingSession(zoo=mini_zoo, root=tmp_path / "b", chunk_rows=32,
                        workers=2, enable_share=False,
                        model_store="decoupled")
    for s in (a, b):
        assert (s.chunk_rows, s.workers, s.enable_share, s.model_store) \
            == (32, 2, False, "decoupled")
    assert a.config == b.config


def test_engine_config_kwargs_overlay(tmp_path, mini_zoo):
    sess = MorphingSession(zoo=mini_zoo, root=tmp_path,
                           config=EngineConfig(chunk_rows=32),
                           chunk_rows=16)       # explicit kwarg wins
    assert sess.chunk_rows == 16
    assert sess.config.chunk_rows == 16


def test_engine_config_validation():
    with pytest.raises(ValueError, match="model_store"):
        EngineConfig(model_store="nope").validate()
    with pytest.raises(ValueError, match="cache tier"):
        EngineConfig(cache_tiers=("exact", "bogus")).validate()
    with pytest.raises(ValueError, match="start with 'exact'"):
        EngineConfig(cache_tiers=("ann",)).validate()
    with pytest.raises(ValueError, match="device_count"):
        EngineConfig(device_count=0).validate()


def test_engine_config_ann_tier_wiring(tmp_path, mini_zoo):
    sess = MorphingSession(zoo=mini_zoo, root=tmp_path,
                           cache_tiers=("exact", "ann"),
                           ann=AnnConfig(error_bound=0.42))
    assert sess.ann is not None
    assert sess.ann.cfg.error_bound == 0.42
    assert sess.cache_chain.tiers == [sess.share, sess.ann]
    # default sessions stay exact-only
    sess2 = MorphingSession(zoo=mini_zoo, root=tmp_path / "x")
    assert sess2.ann is None


def test_server_devices_kwarg_deprecated(tmp_path, mini_zoo):
    sess = _session(tmp_path, mini_zoo)
    with pytest.warns(DeprecationWarning, match="device_count"):
        MorphingServer(session=sess, devices=1)
    # conflicting value still raises (after the warning)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="conflicts"):
            MorphingServer(session=sess, devices=3)


def test_server_policy_from_config(tmp_path, mini_zoo):
    from repro.pipeline.admission import AdmissionPolicy
    pol = AdmissionPolicy(max_queue_rows=64)
    sess = _session(tmp_path, mini_zoo,
                    config=EngineConfig(policy=pol))
    srv = MorphingServer(session=sess)
    assert srv.policy is pol


# -- serving with the ANN tier ---------------------------------------------

def test_server_ann_counters(tmp_path, mini_zoo):
    sess = _session(tmp_path, mini_zoo,
                    config=EngineConfig(cache_tiers=("exact", "ann"),
                                        ann=AnnConfig(error_bound=0.2,
                                                      audit_rate=0.2)))
    _resolve(sess)
    rng = np.random.default_rng(5)
    n = 128
    base = rng.standard_normal((n, 8)).astype(np.float32)
    sess.register_table("t0", {"emb": base})
    sess.register_table("t1", {"emb": base + rng.standard_normal(
        (n, 8)).astype(np.float32) * 1e-3})
    sess.register_table("t2", {"emb": base + rng.standard_normal(
        (n, 8)).astype(np.float32) * 1e-3})
    with MorphingServer(session=sess) as srv:
        srv.predict("PREDICT emb USING TASK sent FROM t0")   # fill
        srv.predict("PREDICT emb USING TASK sent FROM t1")   # calibrate
        srv.predict("PREDICT emb USING TASK sent FROM t2")   # ANN hits
        st = srv.stats()
        assert st.approx_hits > 0
        assert st.share_hit_rate > 0
        assert st.false_accepts == 0         # tiny perturbations: exact
        srv.reset_telemetry()
        assert srv.stats().approx_hits == 0
