"""Mvec property tests (hypothesis): lossless roundtrip + slicing."""
import io

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.storage import mvec

shapes = st.lists(st.integers(1, 8), min_size=0, max_size=4)


@settings(max_examples=60, deadline=None)
@given(shapes, st.sampled_from(["float32", "int8", "int32", "float16"]))
def test_roundtrip(shape, dtype):
    rng = np.random.default_rng(sum(shape) + 1)
    arr = (rng.standard_normal(shape) * 10).astype(dtype)
    buf = mvec.encode(arr)
    out = mvec.decode(buf)
    assert out.shape == tuple(shape)
    assert out.dtype == np.dtype(dtype)
    np.testing.assert_array_equal(out, arr)


def test_bfloat16_roundtrip():
    arr = jnp.asarray(np.random.default_rng(0).standard_normal((4, 5)),
                      jnp.bfloat16)
    buf = mvec.encode(arr)
    hdr = mvec.decode_header(buf)
    assert hdr.dtype == "bfloat16" and hdr.shape == (4, 5)
    out = mvec.decode(buf)
    assert np.asarray(jnp.asarray(out.view(np.uint16))
                      ).tobytes() == np.asarray(arr).view(np.uint16).tobytes()


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 20), st.integers(1, 6),
       st.integers(0, 19), st.integers(1, 20))
def test_slice_matches_numpy(rows, cols, start, count):
    rng = np.random.default_rng(rows * 31 + cols)
    arr = rng.standard_normal((rows, cols)).astype(np.float32)
    buf = mvec.encode(arr)
    stop = start + count
    out = mvec.decode_slice(buf, start, stop)
    np.testing.assert_array_equal(out, arr[max(0, start):min(stop, rows)])


def test_file_range_read(tmp_path):
    arr = np.arange(120, dtype=np.float32).reshape(12, 10)
    p = tmp_path / "x.mvec"
    p.write_bytes(mvec.encode(arr))
    with open(p, "rb") as f:
        hdr = mvec.read_header(f)
        assert hdr.shape == (12, 10)
        part = mvec.read_slice(f, 3, 7)
        np.testing.assert_array_equal(part, arr[3:7])
        part2 = mvec.read_slice(f, 0, 2)  # file offset must reset
        np.testing.assert_array_equal(part2, arr[0:2])


def test_rejects_garbage():
    with pytest.raises(ValueError):
        mvec.decode(b"\x00" * 64)
