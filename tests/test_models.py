"""Per-arch smoke tests (reduced configs, CPU): forward/train step shape +
finiteness, prefill+decode == full forward, and a real learning check.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, smoke_config
from repro.configs.base import ShapeConfig
from repro.models import build_model, make_batch
from repro.training import OptimizerConfig, init_state, make_train_step

SMOKE = ShapeConfig("smoke", 64, 2, "train")


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_loss(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg, attn_impl="naive")
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE)
    loss, metrics = m.loss(params, batch)
    assert jnp.isfinite(loss), arch
    assert 3.0 < float(loss) < 9.0  # ~ln(vocab) at init
    if cfg.is_encoder_decoder:
        logits, _ = m.apply(params, batch)
        assert logits.shape == (2, 32, cfg.padded_vocab)
    else:
        logits, _ = m.apply(params, batch["tokens"])
        assert logits.shape == (2, 64, cfg.padded_vocab)


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_updates(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg, attn_impl="naive")
    params = m.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step = make_train_step(m, OptimizerConfig(learning_rate=1e-3))
    batch = make_batch(cfg, SMOKE)
    new_params, new_opt, out = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(out["loss"]) and jnp.isfinite(out["grad_norm"])
    assert int(new_opt.step) == 1
    # parameters must actually move
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_full_forward(arch):
    S, B = 32, 2
    cfg = smoke_config(arch)
    m = build_model(cfg, attn_impl="naive")
    params = m.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    if cfg.is_encoder_decoder:
        batch = {
            "frames": jax.random.normal(rng, (B, S, cfg.d_model)),
            "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
        full, _ = m.apply(params, batch)
        pre = {"frames": batch["frames"], "tokens": batch["tokens"][:, :-1]}
        _, state = m.prefill(params, pre, max_len=S)
        lg, _ = m.decode_step(params, state, batch["tokens"][:, S - 1:S])
    else:
        tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
        full, _ = m.apply(params, tokens)
        _, state = m.prefill(params, tokens[:, :S - 1], max_len=S + 4)
        lg, _ = m.decode_step(params, state, tokens[:, S - 1:S])
    err = float(jnp.abs(lg - full[:, S - 1:S]).max())
    assert err < 2e-4, f"{arch}: decode mismatch {err}"


def test_multi_step_decode_consistency():
    """Greedy decode 4 steps == argmax of the full forward at each pos."""
    cfg = smoke_config("llama3-405b")
    m = build_model(cfg, attn_impl="naive")
    params = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                cfg.vocab_size)
    _, state = m.prefill(params, tokens[:, :20], max_len=28)
    for t in range(20, 24):
        # feed the token at position t; logits must match the full forward
        lg, state = m.decode_step(params, state, tokens[:, t:t + 1])
        full, _ = m.apply(params, tokens[:, :t + 1])
        err = float(jnp.abs(lg[:, 0] - full[:, t]).max())
        assert err < 2e-4, f"step {t}: {err}"


def test_training_learns():
    """A tiny LM must overfit a fixed batch (loss drops substantially)."""
    cfg = smoke_config("gemma-2b").replace(num_layers=2, vocab_size=128)
    m = build_model(cfg, attn_impl="naive")
    params = m.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step = jax.jit(make_train_step(
        m, OptimizerConfig(learning_rate=3e-3, warmup_steps=5,
                           total_steps=60, weight_decay=0.0)))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 32),
                                          0, 128)}
    first = None
    for _ in range(60):
        params, opt, out = step(params, opt, batch)
        first = first if first is not None else float(out["loss"])
    assert float(out["loss"]) < first * 0.5, (first, float(out["loss"]))


def test_grad_accumulation_equivalence():
    """accum_steps=4 must match the single-batch gradient step closely."""
    cfg = smoke_config("granite-3-8b").replace(num_layers=2)
    m = build_model(cfg, attn_impl="naive")
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, ShapeConfig("s", 32, 8, "train"))
    oc = OptimizerConfig(learning_rate=1e-3)
    p1, _, o1 = jax.jit(make_train_step(m, oc, accum_steps=1))(
        params, init_state(params), batch)
    p4, _, o4 = jax.jit(make_train_step(m, oc, accum_steps=4))(
        params, init_state(params), batch)
    assert abs(float(o1["loss"]) - float(o4["loss"])) < 1e-4
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()), p1, p4)
    assert max(jax.tree.leaves(diffs)) < 5e-3
