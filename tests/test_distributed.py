"""Multi-device tests (subprocess with 8 host devices — conftest must NOT
set XLA_FLAGS globally): sharded training equivalence, shard_map MoE EP,
int8 gradient compression, and dry-run lowering on a small mesh.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.configs.base import ShapeConfig
        from repro.models import build_model, make_batch, batch_axes
        from repro.training import (OptimizerConfig, init_state,
                                    make_train_step, state_axes)
        from repro.distributed.sharding import (axis_rules, make_rules,
                                                tree_shardings)
        from repro.launch.mesh import make_host_mesh

        cfg = smoke_config('granite-3-8b').replace(num_layers=2)
        m = build_model(cfg, attn_impl='naive')
        params = m.init(jax.random.PRNGKey(0))
        opt = init_state(params)
        batch = make_batch(cfg, ShapeConfig('s', 32, 8, 'train'))
        oc = OptimizerConfig(learning_rate=1e-3)
        step = make_train_step(m, oc)

        # single device reference
        p1, o1, out1 = jax.jit(step)(params, opt, batch)

        mesh = make_host_mesh(2, 4)
        rules = make_rules(shard_attn_heads=True)
        ps = tree_shardings(mesh, m.param_axes(), rules)
        os_ = tree_shardings(mesh, state_axes(m.param_axes()), rules)
        bs = tree_shardings(mesh, batch_axes(cfg), rules)
        with axis_rules(rules, mesh=mesh):
            jt = jax.jit(step, in_shardings=(ps, os_, bs),
                         out_shardings=(ps, os_, None))
            p2, o2, out2 = jt(params, opt, batch)
        d = abs(float(out1['loss']) - float(out2['loss']))
        assert d < 1e-4, d
        diffs = jax.tree.map(lambda a, b: float(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)).max()), p1, p2)
        md = max(jax.tree.leaves(diffs))
        assert md < 5e-3, md
        print('sharded==single ok', d, md)
    """))


def test_shard_map_moe_ep_matches_dense():
    print(_run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.models.moe import moe_apply, moe_dense, moe_specs
        from repro.models.spec import init_params
        from repro.launch.mesh import make_host_mesh

        cfg = smoke_config('olmoe-1b-7b')
        p = init_params(moe_specs(cfg), jax.random.PRNGKey(3), 'float32')
        x = jax.random.normal(jax.random.PRNGKey(4), (4, 16, cfg.d_model)) * 0.5
        yd, auxd = moe_dense(cfg, p, x)

        mesh = make_host_mesh(2, 4)  # EP over 'model'=4: 8 experts -> 2/rank
        xs = jax.device_put(x, NamedSharding(mesh, P('data', None, None)))
        ps = {'router': jax.device_put(p['router'], NamedSharding(mesh, P(None, None))),
              'wi': jax.device_put(p['wi'], NamedSharding(mesh, P('model', 'data', None))),
              'wg': jax.device_put(p['wg'], NamedSharding(mesh, P('model', 'data', None))),
              'wo': jax.device_put(p['wo'], NamedSharding(mesh, P('model', None, 'data')))}
        ye, auxe = jax.jit(lambda p, x: moe_apply(cfg, p, x, mesh=mesh))(ps, xs)
        err = float(jnp.abs(yd - ye).max())
        assert err < 1e-4, err
        assert abs(float(auxd) - float(auxe)) < 1e-5
        print('EP moe ok', err)
    """))


def test_gradient_compression_psum():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import (compressed_psum,
                                                   init_ef_state)
        from repro.distributed.sharding import shard_map
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(8, 1)
        g = {'w': jax.random.normal(jax.random.PRNGKey(0), (8, 64)),
             'b': jax.random.normal(jax.random.PRNGKey(1), (8, 16)) * 5}

        def make(enabled):
            def f(g):
                gl = {k: v[0] for k, v in g.items()}
                ef = init_ef_state(gl)
                red, ef = compressed_psum(gl, ef, 'data', enabled=enabled)
                resid = {k: v[None] for k, v in ef.residual.items()}
                return red, resid
            return shard_map(
                f, mesh=mesh,
                in_specs=({'w': P('data', None), 'b': P('data', None)},),
                out_specs=({'w': P(), 'b': P()},
                           {'w': P('data', None), 'b': P('data', None)}))

        red, resid = jax.jit(make(True))(g)
        exact = {k: v.mean(axis=0) for k, v in g.items()}
        for k in exact:
            # int8 quantization error relative to the per-shard grad
            # magnitude (mean cancellation makes output-relative noisy)
            err = float(jnp.abs(red[k] - exact[k]).max())
            bound = float(jnp.abs(g[k]).max()) / 127.0
            assert err <= bound * 1.5, (k, err, bound)
            # error-feedback residual bounded by one quantization step
            assert float(jnp.abs(resid[k]).max()) <= bound * 1.5, k

        red2, _ = jax.jit(make(False))(g)
        for k in exact:
            assert float(jnp.abs(red2[k] - exact[k]).max()) < 1e-6
        print('compression ok')
    """))


def test_dryrun_lowering_small_mesh():
    """The dry-run path itself (lower+compile+analyze) on 8 devices."""
    print(_run("""
        import jax
        from repro.launch.dryrun import lower_cell  # noqa: must import late
        # monkeypatch the production mesh to the host size
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_mod
        def small(multi_pod=False):
            # make_host_mesh handles the AxisType compat across jax pins
            return mesh_mod.make_host_mesh(2, 4)
        mesh_mod.make_production_mesh = small
        dr.make_production_mesh = small
        rec, compiled = lower_cell('gemma-2b', 'decode_32k', False)
        assert rec['roofline']['dominant'] in ('compute', 'memory',
                                               'collective')
        assert rec['flops_per_device'] > 0
        print('dryrun small mesh ok', rec['roofline']['dominant'])
    """))
