"""Serving path: ContinuousBatcher lifecycle, MorphingServer coalescing,
and partial-load resolution byte accounting on the DecoupledStore."""
import threading
import time

import numpy as np
import pytest

from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import MorphingServer, MorphingSession
from repro.engine.config import EngineConfig
from repro.pipeline import ContinuousBatcher, OpProfile, Request
from repro.storage import Catalog, DecoupledStore

PROF = OpProfile(flops_per_row=1e5, bytes_per_row=128, model_bytes=1e6)


# -- fixtures --------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_zoo():
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=120, dim=16, classes=3)
    ring = make_task(rng, "ring", n=120, dim=16, classes=3)
    return [pretrain_model(src, width=12, seed=1, name="m0"),
            pretrain_model(ring, width=12, seed=2, name="m1",
                           mode="radial")]


@pytest.fixture(scope="module")
def table():
    rng = np.random.default_rng(0)
    n = 600
    return {"gender": rng.integers(0, 2, n),
            "len": rng.integers(1, 200, n),
            "emb": rng.standard_normal((n, 16)).astype(np.float32)}


@pytest.fixture(scope="module")
def sample():
    return make_task(np.random.default_rng(1), "gauss", n=128, dim=16,
                     classes=3)


def make_session(tmp_path, zoo, table, *, model_store="decoupled",
                 backend="numpy", resolution=0, **kw):
    sess = MorphingSession(zoo=zoo, root=tmp_path, model_store=model_store,
                          backend=backend, **kw)
    sess.register_table("reviews", {k: v.copy() for k, v in table.items()})
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = resolution
    return sess


# -- ContinuousBatcher lifecycle ------------------------------------------

def test_batcher_duplicate_req_id_raises():
    cb = ContinuousBatcher(lambda xs: xs, PROF, device="host")
    cb.submit(Request(1, 1.0))
    with pytest.raises(ValueError, match="duplicate"):
        cb.submit(Request(1, 2.0))


def test_batcher_run_returns_exactly_the_submitted_set():
    """total not a batch multiple: run() must not overcount past total."""
    calls = []

    def step(ps):
        calls.append(len(ps))
        return [p * 2 for p in ps]

    cb = ContinuousBatcher(step, PROF, device="host", max_wait_s=0.001)
    for i in range(10):
        cb.submit(Request(i, float(i)))
    res = cb.run(total=7)
    assert sum(calls) == 7          # exactly 7 served, 3 still queued
    assert len(res) == 7
    rest = cb.run(total=3)
    assert set(rest) == set(range(10))


def test_batcher_service_mode_concurrent_submitters():
    cb = ContinuousBatcher(lambda xs: [x + 1 for x in xs], PROF,
                           device="host", max_wait_s=0.002,
                           idle_wait_s=0.01).start()
    ids = list(range(40))

    def client(lo):
        for i in range(lo, lo + 10):
            cb.submit(Request(i, float(i)))

    threads = [threading.Thread(target=client, args=(lo,))
               for lo in range(0, 40, 10)]
    for t in threads:
        t.start()
    outs = {i: cb.result(i, timeout=5.0) for i in ids}
    for t in threads:
        t.join()
    cb.stop()
    assert outs == {i: i + 1.0 for i in ids}
    assert len(cb.latencies) == 40
    assert max(cb.batch_sizes) > 1      # actually coalesced


def test_batcher_stop_drains_queue():
    served = []

    def slow_step(ps):
        time.sleep(0.01)
        served.extend(ps)
        return ps

    cb = ContinuousBatcher(slow_step, PROF, device="host",
                           max_wait_s=0.001, idle_wait_s=0.01).start()
    for i in range(25):
        cb.submit(Request(i, i))
    cb.stop(drain=True)
    assert sorted(served) == list(range(25))
    with pytest.raises(RuntimeError, match="stopped"):
        cb.submit(Request(99, 1))


def test_batcher_stop_without_drain_fails_pending():
    release = threading.Event()

    def blocked_step(ps):
        release.wait(1.0)
        return ps

    cb = ContinuousBatcher(blocked_step, PROF, device="host",
                           batch_size=1, max_wait_s=0.0,
                           idle_wait_s=0.01).start()
    cb.submit(Request(0, 0))
    time.sleep(0.05)                 # worker is inside step 0
    for i in range(1, 8):
        cb.submit(Request(i, i))
    cb.stop(drain=False)
    release.set()
    dropped = 0
    for i in range(8):
        try:
            cb.result(i, timeout=1.0)
        except RuntimeError:
            dropped += 1
    assert dropped > 0               # queued requests were failed, not lost


def test_batcher_stop_join_timeout_raises_then_retries():
    """A worker wedged inside its step surfaces as TimeoutError from
    stop() instead of hanging the caller; once the step returns, a
    second stop() retries the join and succeeds."""
    release = threading.Event()
    entered = threading.Event()

    def wedged_step(ps):
        entered.set()
        release.wait(5.0)
        return ps

    cb = ContinuousBatcher(wedged_step, PROF, device="host",
                           batch_size=1, max_wait_s=0.0,
                           idle_wait_s=0.01).start()
    cb.submit(Request(0, 0))
    assert entered.wait(2.0)         # worker is inside the wedged step
    with pytest.raises(TimeoutError, match="did not join"):
        cb.stop(drain=False, timeout=0.2)
    release.set()
    cb.stop(drain=False, timeout=5.0)    # retry joins cleanly
    assert cb.result(0, timeout=1.0) == 0


def test_batcher_stop_drains_inline_when_never_started():
    """stop(drain=True) with no worker thread must serve the queue on
    the calling thread rather than orphan admitted requests."""
    cb = ContinuousBatcher(lambda xs: [x * 3 for x in xs], PROF,
                           device="host", max_wait_s=0.001,
                           idle_wait_s=0.01)
    for i in range(5):
        cb.submit(Request(i, float(i)))
    res = cb.stop(drain=True)
    assert res == {i: i * 3.0 for i in range(5)}


def test_batcher_step_error_propagates_to_result():
    def bad_step(ps):
        raise RuntimeError("boom")

    cb = ContinuousBatcher(bad_step, PROF, device="host",
                           idle_wait_s=0.01).start()
    cb.submit(Request(0, 1.0))
    with pytest.raises(RuntimeError, match="boom"):
        cb.result(0, timeout=5.0)
    cb.stop()


def test_batcher_run_raises_step_error():
    """One-shot mode has no result() call: run() must fail loudly, not
    hand back internal failure sentinels as model outputs."""
    cb = ContinuousBatcher(lambda ps: 1 / 0, PROF, device="host",
                           max_wait_s=0.001)
    cb.submit(Request(0, 1.0))
    with pytest.raises(ZeroDivisionError):
        cb.run(total=1)


def test_batcher_result_evicts_by_default():
    """Service mode must stay memory-bounded: a result is retrievable
    once, then its stored state is released."""
    cb = ContinuousBatcher(lambda xs: xs, PROF, device="host",
                           idle_wait_s=0.01).start()
    cb.submit(Request(0, 1.0))
    assert cb.result(0, timeout=5.0) == 1.0
    with pytest.raises(KeyError):
        cb.result(0, timeout=0.1)            # evicted
    cb.submit(Request(0, 2.0))               # req_id slot is reusable
    assert cb.result(0, timeout=5.0) == 2.0
    cb.stop()


def test_batcher_row_aware_sizing():
    """size_of counts payload rows: the row budget, not the request
    count, closes a batch."""
    sizes = []
    cb = ContinuousBatcher(lambda xs: xs, batch_size=100, size_of=len,
                           max_wait_s=0.05, idle_wait_s=0.01)
    for i in range(6):
        cb.submit(Request(i, list(range(40))))    # 40 rows each
    cb.run(total=6)
    # 100-row budget -> 3 requests (120 rows) per batch, not all 6
    assert max(cb.batch_sizes) <= 3


# -- MorphingServer --------------------------------------------------------

def test_server_concurrent_submitters_match_engine(tmp_path, serve_zoo,
                                                   table, sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    ref = {thr: sess.sql(f"PREDICT emb USING TASK sent FROM reviews "
                         f"WHERE len > {thr}").rows["_score"]
           for thr in (20, 60, 100)}
    server = MorphingServer(session=sess, max_wait_s=0.002)
    with server:
        ids = {}

        def client(thr):
            ids[thr] = [server.submit(
                "PREDICT emb USING TASK sent FROM reviews "
                f"WHERE len > {thr}") for _ in range(4)]

        threads = [threading.Thread(target=client, args=(t,))
                   for t in (20, 60, 100)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for thr, rids in ids.items():
            for rid in rids:
                out = server.result(rid, timeout=10.0)
                np.testing.assert_allclose(out.scores, ref[thr],
                                           atol=1e-5)
                assert out.latency_s >= 0.0


def test_server_coalesces_same_task_requests(tmp_path, serve_zoo, table,
                                             sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess, max_wait_s=0.05)
    with server:
        rids = [server.submit("PREDICT emb USING TASK sent FROM reviews "
                              "WHERE len > 150") for _ in range(12)]
        for rid in rids:
            server.result(rid, timeout=10.0)
    st = server.stats()
    assert st.requests == 12
    assert st.batches < 12                   # requests shared batches
    assert st.mean_coalesced > 1.0
    assert st.rows > 0 and st.infer_seconds > 0.0


def test_server_stats_latency_percentiles(tmp_path, serve_zoo, table,
                                          sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess)
    with server:
        for rid in [server.submit("PREDICT emb USING TASK sent "
                                  "FROM reviews") for _ in range(6)]:
            server.result(rid, timeout=10.0)
    st = server.stats()
    assert 0.0 < st.p50_latency_s <= st.p95_latency_s <= st.max_latency_s
    assert st.requests_by_task == {"sent": 6}
    assert st.stored_bytes > 0


def test_server_stop_drains_submitted_requests(tmp_path, serve_zoo, table,
                                               sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess, max_wait_s=0.001).start()
    rids = [server.submit("PREDICT emb USING TASK sent FROM reviews")
            for _ in range(10)]
    server.stop(drain=True)                  # no result() calls yet
    for rid in rids:
        out = server.result(rid, timeout=0.1)   # already served
        assert out.rows == 600


def test_server_rejects_analytics_sql(tmp_path, serve_zoo, table, sample):
    sess = make_session(tmp_path, serve_zoo, table)
    server = MorphingServer(session=sess)
    with pytest.raises(ValueError, match="PREDICT"):
        server.submit("SELECT gender, AVG(sent(emb)) FROM reviews "
                      "GROUP BY gender")


def test_server_submit_before_start_raises(tmp_path, serve_zoo, table,
                                           sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess)
    with pytest.raises(RuntimeError, match="not started"):
        server.submit("PREDICT emb USING TASK sent FROM reviews")


def test_server_resolves_on_first_request(tmp_path, serve_zoo, table,
                                          sample):
    sess = make_session(tmp_path, serve_zoo, table)
    server = MorphingServer(session=sess)
    with server:
        out = server.predict("PREDICT emb USING TASK sent FROM reviews",
                             sample=(sample.X, sample.y), timeout=10.0)
    assert out.rows == 600
    assert "sent" in sess.models


def test_server_jax_backend_parity(tmp_path, serve_zoo, table, sample):
    ref_sess = make_session(tmp_path / "np", serve_zoo, table)
    ref_sess.resolve_task("sent", sample.X, sample.y)
    ref = ref_sess.sql("PREDICT emb USING TASK sent FROM reviews "
                       "WHERE len > 50").rows["_score"]
    sess = make_session(tmp_path / "jax", serve_zoo, table, backend="jax")
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess)
    with server:
        out = server.predict("PREDICT emb USING TASK sent FROM reviews "
                             "WHERE len > 50", timeout=30.0)
    np.testing.assert_allclose(out.scores, ref, atol=1e-5)


# -- share-aware serving: trunk lanes, dedup, head stages ------------------

def _count_features(backend):
    """Instrument a backend instance: record rows per _features call."""
    calls = []
    orig = backend._features

    def counting(spec, X, _o=orig):
        calls.append(len(X))
        return _o(spec, X)

    backend._features = counting
    return calls


def test_concurrent_identical_requests_embed_once(tmp_path, serve_zoo,
                                                  table, sample):
    """N threads submitting identical PREDICT rows must produce exactly
    one embed computation: in-flight duplicates fold in-batch, and
    later batches hit the cache written back by earlier ones."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    calls = _count_features(sess.backends["host"])
    n_rows = int((table["len"] > 100).sum())
    n_clients = 8
    # generous coalescing window: the dedup assertion needs at least one
    # batch to carry two identical requests even on a loaded scheduler
    server = MorphingServer(session=sess, max_wait_s=0.2)
    with server:
        rids = []
        lock = threading.Lock()

        def client():
            rid = server.submit("PREDICT emb USING TASK sent FROM "
                                "reviews WHERE len > 100")
            with lock:
                rids.append(rid)

        threads = [threading.Thread(target=client)
                   for _ in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        outs = [server.result(r, timeout=10.0) for r in rids]
    assert all(o.rows == n_rows for o in outs)
    assert sum(calls) == n_rows              # the one and only trunk pass
    st = server.stats()
    assert st.embed_rows == n_rows
    assert st.head_rows == n_clients * n_rows
    assert st.dedup_rows + st.share_hits == (n_clients - 1) * n_rows
    assert st.dedup_rows > 0                 # in-flight dedup exercised
    assert st.dedup_rate > 0.0


def test_tasks_sharing_trunk_share_one_lane(tmp_path, serve_zoo, table,
                                            sample):
    """Two tasks resolving to the same stored model feed one embed lane
    and reuse each other's cached rows (cross-task trunk sharing)."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    sess.create_task(TaskSpec("sent2", "series", ("P", "N")))
    sess.registry._resolution["sent2"] = 0
    sess.resolve_task("sent2", sample.X, sample.y)
    assert (sess.models["sent"].trunk_fp
            == sess.models["sent2"].trunk_fp != "")
    ref = sess.sql("PREDICT emb USING TASK sent FROM reviews "
                   "WHERE len > 50").rows["_score"]
    server = MorphingServer(session=sess, max_wait_s=0.001)
    with server:
        out1 = server.predict("PREDICT emb USING TASK sent FROM reviews "
                              "WHERE len > 50", timeout=10.0)
        out2 = server.predict("PREDICT emb USING TASK sent2 FROM reviews "
                              "WHERE len > 50", timeout=10.0)
    np.testing.assert_allclose(out1.scores, ref, atol=1e-5)
    np.testing.assert_allclose(out2.scores, ref, atol=1e-5)
    assert len(server._lanes) == 1
    st = server.stats()
    assert st.requests_by_task == {"sent": 1, "sent2": 1}
    # the second task's rows were embedded by the first task's traffic
    assert st.share_hits >= out2.rows
    lane_key = sess.models["sent"].trunk_fp
    assert st.share_hit_rate_by_lane[lane_key] > 0.0


def test_distinct_trunks_get_distinct_lanes(tmp_path, serve_zoo, table,
                                            sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    sess.create_task(TaskSpec("ring", "series", ("P", "N")))
    sess.registry._resolution["ring"] = 1     # the radial model
    sess.resolve_task("ring", sample.X, sample.y)
    server = MorphingServer(session=sess)
    with server:
        server.predict("PREDICT emb USING TASK sent FROM reviews",
                       timeout=10.0)
        server.predict("PREDICT emb USING TASK ring FROM reviews",
                       timeout=10.0)
    assert len(server._lanes) == 2


def test_share_lanes_match_legacy_task_lanes(tmp_path, serve_zoo, table,
                                             sample):
    """The embed/head split must be invisible in the scores."""
    outs = {}
    for mode in (True, False):
        sess = make_session(tmp_path / str(mode), serve_zoo, table)
        sess.resolve_task("sent", sample.X, sample.y)
        server = MorphingServer(session=sess, share_lanes=mode)
        with server:
            outs[mode] = server.predict(
                "PREDICT emb USING TASK sent FROM reviews WHERE len > 30",
                timeout=10.0).scores
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-5)


def test_legacy_lanes_report_no_share_counters(tmp_path, serve_zoo,
                                               table, sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess, share_lanes=False)
    with server:
        for _ in range(2):
            server.predict("PREDICT emb USING TASK sent FROM reviews",
                           timeout=10.0)
    st = server.stats()
    assert st.share_hits == st.share_misses == st.dedup_rows == 0
    assert st.embed_rows == 0 and st.head_rows == 0
    assert st.rows == 1200 and st.share_hit_rate == 0.0


def test_embed_head_budgets_split(tmp_path, serve_zoo, table, sample):
    """Eq. 11 sizes the head stage independently of the embed lane: the
    head profile is orders cheaper per row, so its budget must be at
    least as large."""
    from repro.pipeline.cost import split_profile

    sess = make_session(tmp_path, serve_zoo, table)
    rm = sess.resolve_task("sent", sample.X, sample.y)
    embed_p, head_p = split_profile(rm.profile, rm.head_dim)
    assert head_p.flops_per_row < embed_p.flops_per_row
    assert head_p.model_bytes < embed_p.model_bytes
    server = MorphingServer(session=sess)
    with server:
        server.predict("PREDICT emb USING TASK sent FROM reviews",
                       timeout=10.0)
    (lane,) = server._lanes.values()
    assert lane.heads["sent"].batch_rows >= lane.batch_rows


def test_server_reset_telemetry_rebases_window(tmp_path, serve_zoo,
                                               table, sample):
    """Percentiles/counters must be computable over a consistent window:
    after reset, stats reflect only post-reset traffic (the warmup
    samples no longer skew p50/p95)."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess)
    with server:
        for _ in range(4):                   # warmup traffic
            server.predict("PREDICT emb USING TASK sent FROM reviews",
                           timeout=10.0)
        assert server.stats().requests == 4
        server.reset_telemetry()
        st0 = server.stats()
        assert st0.requests == 0 and st0.rows == 0
        assert st0.p95_latency_s == 0.0 and st0.share_hits == 0
        server.predict("PREDICT emb USING TASK sent FROM reviews",
                       timeout=10.0)
        st = server.stats()
    assert st.requests == 1 and st.rows == 600
    assert st.batches == 1
    assert 0.0 < st.p50_latency_s <= st.p95_latency_s
    assert st.share_hits == 600              # warm rows survive the reset


def test_write_back_races_lane_shutdown(tmp_path, serve_zoo, table,
                                        sample):
    """stop(drain=True) racing concurrent submits: every admitted
    request is served, its scores correct, and the drained batches'
    cache write-backs land (a fresh server over the same session starts
    warm)."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    ref = sess.sql("PREDICT emb USING TASK sent FROM reviews "
                   "WHERE len > 50").rows["_score"]
    server = MorphingServer(session=sess, max_wait_s=0.005).start()
    admitted, rejected = [], []
    lock = threading.Lock()

    def client():
        for _ in range(5):
            try:
                rid = server.submit("PREDICT emb USING TASK sent FROM "
                                    "reviews WHERE len > 50")
                with lock:
                    admitted.append(rid)
            except RuntimeError:             # raced the stop: rejected
                with lock:
                    rejected.append(1)

    threads = [threading.Thread(target=client) for _ in range(4)]
    for t in threads:
        t.start()
    server.stop(drain=True)                  # race the submitters
    for t in threads:
        t.join()
    for rid in admitted:
        out = server.result(rid, timeout=5.0)    # drained, never lost
        np.testing.assert_allclose(out.scores, ref, atol=1e-5)
    if admitted:                             # write-backs survived stop
        server2 = MorphingServer(session=sess, max_wait_s=0.001)
        with server2:
            server2.predict("PREDICT emb USING TASK sent FROM reviews "
                            "WHERE len > 50", timeout=10.0)
        st2 = server2.stats()
        assert st2.share_hits == len(ref) and st2.embed_rows == 0


def test_stop_without_drain_fails_pending_cleanly(tmp_path, serve_zoo,
                                                  table, sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess, max_wait_s=0.05,
                            idle_wait_s=0.2).start()
    rids = [server.submit("PREDICT emb USING TASK sent FROM reviews")
            for _ in range(6)]
    server.stop(drain=False)
    outcomes = {"served": 0, "failed": 0}
    for rid in rids:
        try:
            server.result(rid, timeout=1.0)
            outcomes["served"] += 1
        except RuntimeError:
            outcomes["failed"] += 1
    assert outcomes["served"] + outcomes["failed"] == 6


def test_server_stop_surfaces_stuck_lane(tmp_path, serve_zoo, table,
                                         sample):
    """A lane worker wedged in a backend call must not hang stop():
    the join times out and the server raises RuntimeError naming the
    stuck lane, with pending results marked undeliverable."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess, max_wait_s=0.01,
                            idle_wait_s=0.05).start()
    release = threading.Event()
    entered = threading.Event()
    backend = sess.backends["host"]        # numpy pool: every annotation
    orig = backend.run_infer               # shares this instance

    def wedged_run_infer(spec, batch):
        entered.set()
        release.wait(10.0)
        return orig(spec, batch)

    backend.run_infer = wedged_run_infer
    try:
        server.submit("PREDICT emb USING TASK sent FROM reviews")
        assert entered.wait(5.0)           # worker is inside the backend
        t0 = time.perf_counter()
        with pytest.raises(RuntimeError, match="did not join") as ei:
            server.stop(drain=False, timeout=0.2)
        assert time.perf_counter() - t0 < 5.0   # bounded, not hung
        # the error names which lane is wedged
        assert any(k in str(ei.value) for k in server._lanes)
    finally:
        release.set()
        backend.run_infer = orig


def test_head_mode_task_served_warm_keeps_trunk_on_disk(tmp_path,
                                                        serve_zoo, table,
                                                        sample):
    """The server-side embed split preserves the partial-load story: a
    head-mode task whose rows are already cached never materializes its
    trunk."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    sess.create_task(TaskSpec("sent2", "series", ("P", "N")))
    sess.registry._resolution["sent2"] = 0
    rm2 = sess.resolve_task("sent2", sample.X, sample.y, mode="head")
    ref = sess.sql("PREDICT emb USING TASK sent FROM reviews").rows["_score"]
    server = MorphingServer(session=sess)
    with server:
        server.predict("PREDICT emb USING TASK sent FROM reviews",
                       timeout=10.0)         # warms the lane's row cache
        out = server.predict("PREDICT emb USING TASK sent2 FROM reviews",
                             timeout=10.0)
    np.testing.assert_allclose(out.scores, ref, atol=1e-5)
    assert not rm2.zoo_model.materialized    # share hits: trunk on disk


# -- partial-load resolution ----------------------------------------------

def test_decoupled_loaded_bytes_accounting(tmp_path):
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat, cache_layers=False)
    W = np.arange(64, dtype=np.float32).reshape(16, 4)
    ds.save("m", {"v": 1}, {"trunk/W": W,
                            "head/w": np.ones(4, np.float32)})
    _, flat = ds.load("m")
    full_bytes = ds.stats.loaded_bytes
    assert full_bytes >= W.nbytes + 16      # payload + headers
    assert ds.stats.loads == 1 and ds.stats.partial_loads == 0

    _, head = ds.load("m", layer_filter=lambda n: n.startswith("head/"))
    head_bytes = ds.stats.loaded_bytes - full_bytes
    assert set(head) == {"head/w"}
    assert 0 < head_bytes < W.nbytes
    assert ds.stats.partial_loads == 1


def test_decoupled_load_layer_rows_counts_slice_bytes(tmp_path):
    ds = DecoupledStore(tmp_path / "dec", cache_layers=False)
    W = np.arange(128, dtype=np.float32).reshape(16, 8)
    ds.save("m", {"v": 1}, {"trunk/W": W})
    part = ds.load_layer_rows("m", "trunk/W", 0, 4)
    np.testing.assert_array_equal(part, W[:4])
    assert ds.stats.loaded_bytes == part.nbytes       # only the slice
    assert ds.stats.partial_loads == 1


def test_decoupled_layer_cache_shares_across_loads(tmp_path):
    ds = DecoupledStore(tmp_path / "dec")
    W = np.ones((8, 4), np.float32)
    ds.save("m", {"v": 1}, {"trunk/W": W})
    ds.load("m")
    first = ds.stats.loaded_bytes
    ds.load("m")                             # second load: cache tier
    assert ds.stats.loaded_bytes == first
    assert ds.stats.cache_hits == 1
    assert ds.stats.cache_hit_bytes == W.nbytes


def test_layer_cache_save_keeps_prefix_sibling_models(tmp_path):
    """Saving 'm1' must not evict cached layers of 'm10'."""
    ds = DecoupledStore(tmp_path / "dec")
    W = np.ones((8, 4), np.float32)
    ds.save("m10", {"v": 1}, {"trunk/W": W})
    ds.load("m10")
    ds.save("m1", {"v": 1}, {"trunk/W": 2 * W})
    ds.load("m10")                           # still cache-served
    assert ds.stats.cache_hits == 1


def test_partial_resolution_slices_trunk_width(tmp_path, serve_zoo):
    """A narrow table only pulls the trunk rows its width touches."""
    rng = np.random.default_rng(0)
    table8 = {"len": rng.integers(1, 200, 200),
              "emb": rng.standard_normal((200, 8)).astype(np.float32)}
    sess = make_session(tmp_path, serve_zoo, table8)
    sample8 = make_task(np.random.default_rng(2), "gauss", n=96, dim=8,
                        classes=3)
    rm = sess.resolve_task("sent", sample8.X, sample8.y, mode="partial")
    assert rm.loaded_bytes < rm.stored_bytes
    assert "+w8" in rm.version               # slice-tagged embedder
    res = sess.sql("PREDICT emb USING TASK sent FROM reviews "
                   "WHERE len > 50")
    assert res.report.loaded_bytes < res.report.stored_bytes
    # parity: zero-padded inputs through the full trunk give the same
    # scores as the sliced trunk
    full = make_session(tmp_path / "full", serve_zoo, table8)
    full.resolve_task("sent", sample8.X, sample8.y, mode="full")
    ref = full.sql("PREDICT emb USING TASK sent FROM reviews "
                   "WHERE len > 50")
    np.testing.assert_allclose(res.rows["_score"], ref.rows["_score"],
                               atol=1e-5)


def test_head_only_resolution_skips_trunk_on_share_hit(tmp_path,
                                                       serve_zoo, table,
                                                       sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y, mode="full")
    sess.sql("PREDICT emb USING TASK sent FROM reviews")  # warm share
    sess.dstore.cache_layers = False         # count true disk bytes
    sess.create_task(TaskSpec("sent2", "series", ("P", "N")))
    sess.registry._resolution["sent2"] = 0
    rm2 = sess.resolve_task("sent2", sample.X, sample.y, mode="head")
    res = sess.sql("PREDICT emb USING TASK sent2 FROM reviews")
    assert not rm2.zoo_model.materialized    # share hits: trunk on disk
    assert 0 < rm2.loaded_bytes < rm2.stored_bytes
    ref = sess.sql("PREDICT emb USING TASK sent FROM reviews")
    np.testing.assert_allclose(res.rows["_score"], ref.rows["_score"],
                               atol=1e-5)


def test_resolve_mode_conflict_with_cached_resolution(tmp_path,
                                                      serve_zoo, table,
                                                      sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)       # default: full
    with pytest.raises(ValueError, match="force=True"):
        sess.resolve_task("sent", sample.X, sample.y, mode="head")

    class _Pinned:                       # force re-runs selection
        def select(self, X, y):
            return type("R", (), {"chosen": 0})()

    sess.registry.selector = _Pinned()
    rm = sess.resolve_task("sent", sample.X, sample.y, mode="head",
                           force=True)
    assert rm.load_mode == "head"


def test_head_mode_lazily_loads_trunk_on_cold_embed(tmp_path, serve_zoo,
                                                    table, sample):
    sess = make_session(tmp_path, serve_zoo, table, enable_share=False)
    rm = sess.resolve_task("sent", sample.X, sample.y, mode="head")
    head_bytes = rm.loaded_bytes
    assert not rm.zoo_model.materialized
    sess.sql("PREDICT emb USING TASK sent FROM reviews")  # cold: needs it
    assert rm.zoo_model.materialized
    assert rm.loaded_bytes > head_bytes


def test_radial_partial_skips_projection(tmp_path, serve_zoo, table,
                                         sample):
    sess = make_session(tmp_path, serve_zoo, table, resolution=1)
    rm = sess.resolve_task("sent", sample.X, sample.y, mode="partial")
    assert rm.loaded_bytes < rm.stored_bytes     # identity W never read
    res = sess.sql("PREDICT emb USING TASK sent FROM reviews "
                   "WHERE len > 50")
    blob = make_session(tmp_path / "blob", serve_zoo, table,
                        model_store="blob", resolution=1)
    blob.resolve_task("sent", sample.X, sample.y)
    ref = blob.sql("PREDICT emb USING TASK sent FROM reviews "
                   "WHERE len > 50")
    np.testing.assert_allclose(res.rows["_score"], ref.rows["_score"],
                               atol=1e-5)


def test_auto_calibrate_populates_measured_hw(tmp_path, serve_zoo):
    sess = MorphingSession(zoo=serve_zoo, root=tmp_path, backend="numpy")
    assert sess.hw and all(p.measured for p in sess.hw.values())
    off = MorphingSession(zoo=serve_zoo, root=tmp_path / "off",
                          backend="numpy", auto_calibrate=False)
    assert off.hw is None


# -- fine-tune delta resolution & serving ---------------------------------

def _register_fleet(sess, sample, k, seed=11):
    """K head-delta fine-tunes of the already-resolved base model m0,
    each bound to task sent_ft{i}. Returns {task: head weights}."""
    rng = np.random.default_rng(seed)
    dim = sess.models["sent"].head_dim
    heads = {}
    for i in range(k):
        w = np.abs(rng.standard_normal(dim)).astype(np.float32)
        w /= w.sum()
        name, mid = f"sent_ft{i}", f"m0-ft{i}"
        sess.register_finetune(mid, "m0", {"head/w": w})
        sess.create_task(TaskSpec(name, "series", ("P", "N")))
        sess.resolve_task(name, sample.X, sample.y, model_id=mid)
        heads[name] = w
    return heads


def test_finetune_parity_vs_materialized_full_model(tmp_path, serve_zoo,
                                                    table, sample):
    """save(base_model=) -> resolve(model_id=) -> serve must match an
    eagerly-materialized full model stored without delta encoding."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    heads = _register_fleet(sess, sample, 1)
    # eagerly-materialized twin: same weights, no base_model lineage
    arch, flat = sess.dstore.load("m0")
    flat = dict(flat, **{"head/w": heads["sent_ft0"]})
    sess.dstore.save("m0-eager", arch, flat)
    sess.create_task(TaskSpec("sent_eager", "series", ("P", "N")))
    rme = sess.resolve_task("sent_eager", sample.X, sample.y,
                            model_id="m0-eager")
    assert not rme.is_delta and sess.models["sent_ft0"].is_delta
    got = sess.sql("PREDICT emb USING TASK sent_ft0 FROM reviews "
                   "WHERE len > 40").rows["_score"]
    ref = sess.sql("PREDICT emb USING TASK sent_eager FROM reviews "
                   "WHERE len > 40").rows["_score"]
    np.testing.assert_allclose(got, ref, atol=1e-5)
    # and against the raw math on the in-memory zoo weights
    X = table["emb"][table["len"] > 40]
    np.testing.assert_allclose(
        got, serve_zoo[0].features(X) @ heads["sent_ft0"], atol=1e-5)


def test_finetune_loaded_bytes_only_delta_on_warm_base(tmp_path,
                                                       serve_zoo, table,
                                                       sample):
    """A fine-tune resolved after its base reads only delta bytes: the
    base trunk is warm in the cross-model layer cache."""
    sess = make_session(tmp_path, serve_zoo, table)
    base = sess.resolve_task("sent", sample.X, sample.y)
    b0 = sess.dstore.stats.loaded_bytes
    _register_fleet(sess, sample, 1)
    rm = sess.models["sent_ft0"]
    read = sess.dstore.stats.loaded_bytes - b0
    assert rm.is_delta and rm.base_model_id == "m0"
    assert rm.base_fp == base.trunk_fp == rm.trunk_fp != ""
    assert rm.loaded_bytes == rm.delta_bytes == read > 0
    assert rm.loaded_bytes < base.loaded_bytes
    assert rm.stored_bytes == rm.delta_bytes   # only deltas on disk
    # warm-trunk staging: Eq. 7 charges only the delta bytes
    assert rm.profile.model_bytes == float(rm.delta_bytes)
    assert base.profile.model_bytes > rm.profile.model_bytes


def test_delta_fleet_shares_one_embed_lane(tmp_path, serve_zoo, table,
                                           sample):
    """K fine-tunes + their base ride ONE embed lane; each keeps its own
    head stage and ServerStats reports the delta counters."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    heads = _register_fleet(sess, sample, 3)
    server = MorphingServer(session=sess, max_wait_s=0.001)
    X = table["emb"][table["len"] > 50]
    F = serve_zoo[0].features(X)
    with server:
        for task in ["sent"] + sorted(heads):
            out = server.predict(f"PREDICT emb USING TASK {task} "
                                 "FROM reviews WHERE len > 50",
                                 timeout=10.0)
            want = (F.mean(axis=1) if task == "sent"
                    else F @ heads[task])
            np.testing.assert_allclose(np.asarray(out.scores), want,
                                       atol=1e-5)
    assert len(server._lanes) == 1
    st = server.stats()
    assert st.lanes == 1
    assert st.tasks_by_lane == {sess.models["sent"].trunk_fp: 4}
    assert st.delta_tasks == 3
    assert st.delta_stored_bytes == sum(
        sess.models[t].delta_bytes for t in heads)
    assert st.delta_loaded_bytes == sum(
        sess.models[t].loaded_bytes for t in heads)
    # after the base's first request every fine-tune row is a share hit
    assert st.share_hits >= 3 * len(X)


def test_compressed_fleet_serving_parity_and_bytes(tmp_path, serve_zoo,
                                                   table, sample):
    """K=8 head-delta fleet served through MorphingServer with delta
    compression ON vs OFF: row-level score parity within the declared
    quantization bound, strictly fewer delta bytes read from disk, and
    the compression gauges surfaced on ServerStats/QueryReport."""
    K = 8

    def run_fleet(root, compress):
        sess = make_session(root, serve_zoo, table,
                            config=EngineConfig(compress_deltas=compress))
        sess.resolve_task("sent", sample.X, sample.y)
        heads = _register_fleet(sess, sample, K)
        scores = {}
        with MorphingServer(session=sess, max_wait_s=0.001) as server:
            for task in sorted(heads):
                out = server.predict(f"PREDICT emb USING TASK {task} "
                                     "FROM reviews WHERE len > 50",
                                     timeout=10.0)
                scores[task] = np.asarray(out.scores)
            st = server.stats()
        return sess, heads, scores, st

    sess_c, heads, got, st_c = run_fleet(tmp_path / "on", True)
    sess_u, _, ref, st_u = run_fleet(tmp_path / "off", False)
    assert sorted(got) == sorted(ref) and len(got) == K
    # parity: per-weight quant error <= declared bound, so a score row
    # F_i . w is off by at most bound * ||F_i||_1
    bound = st_c.quant_error_bound
    assert bound > 0.0
    X = table["emb"][table["len"] > 50]
    F = serve_zoo[0].features(X)
    atol = bound * float(np.abs(F).sum(axis=1).max()) + 1e-6
    for task in got:
        np.testing.assert_allclose(got[task], ref[task], atol=atol)
        # exact weights differ: parity must come from the bound, not
        # from compression silently being a no-op
    assert sess_c.dstore.stats.compressed_delta_bytes > 0
    # compressed fleet reads strictly fewer delta bytes off disk
    assert 0 < st_c.delta_loaded_bytes < st_u.delta_loaded_bytes
    assert sum(sess_c.dstore.delta_bytes(f"m0-ft{i}") for i in range(K)) \
        < sum(sess_u.dstore.delta_bytes(f"m0-ft{i}") for i in range(K))
    # gauges ride ServerStats and QueryReport; OFF run declares no bound
    assert st_u.quant_error_bound == 0.0 == st_u.compressed_delta_bytes
    rep = sess_c.sql("PREDICT emb USING TASK sent_ft0 FROM reviews "
                     "WHERE len > 50").report
    assert rep.quant_error_bound == bound
    assert rep.compressed_delta_bytes == \
        sess_c.dstore.stats.compressed_delta_bytes


def test_trunk_delta_variant_gets_own_lane(tmp_path, serve_zoo, table,
                                           sample):
    """A fine-tune whose TRUNK carries deltas is a different embedder:
    distinct fingerprint, own lane, scores from the composed trunk."""
    sess = make_session(tmp_path, serve_zoo, table)
    base = sess.resolve_task("sent", sample.X, sample.y)
    Wd = (serve_zoo[0].W + 0.01).astype(np.float32)
    sess.register_finetune("m0-tft", "m0", {"trunk/W": Wd})
    sess.create_task(TaskSpec("sent_t", "series", ("P", "N")))
    rm = sess.resolve_task("sent_t", sample.X, sample.y,
                           model_id="m0-tft")
    assert rm.trunk_fp != base.trunk_fp
    assert rm.base_fp == base.trunk_fp        # lineage still recorded
    server = MorphingServer(session=sess, max_wait_s=0.001)
    with server:
        out = server.predict("PREDICT emb USING TASK sent_t FROM reviews "
                             "WHERE len > 50", timeout=10.0)
        server.predict("PREDICT emb USING TASK sent FROM reviews "
                       "WHERE len > 50", timeout=10.0)
    assert len(server._lanes) == 2
    X = table["emb"][table["len"] > 50]
    from repro.core.zoo import ZooModel
    twin = ZooModel(name="twin", source_family="gauss", W=Wd,
                    mode="linear")
    np.testing.assert_allclose(np.asarray(out.scores),
                               twin.features(X).mean(axis=1), atol=1e-4)


def test_finetune_head_mode_keeps_trunk_on_disk(tmp_path, serve_zoo,
                                                table, sample):
    """head-mode fine-tune resolution: share hits from the base's
    traffic keep the (shared) trunk lazy — never materialized."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    sess.sql("PREDICT emb USING TASK sent FROM reviews")  # warm share
    rng = np.random.default_rng(5)
    w = np.abs(rng.standard_normal(12)).astype(np.float32)
    w /= w.sum()
    sess.register_finetune("m0-hft", "m0", {"head/w": w})
    sess.create_task(TaskSpec("sent_h", "series", ("P", "N")))
    rm = sess.resolve_task("sent_h", sample.X, sample.y, mode="head",
                           model_id="m0-hft")
    res = sess.sql("PREDICT emb USING TASK sent_h FROM reviews")
    assert not rm.zoo_model.materialized      # share hits: trunk on disk
    assert res.report.share_hit_rate == 1.0
    X = np.asarray(table["emb"])
    np.testing.assert_allclose(res.rows["_score"],
                               serve_zoo[0].features(X) @ w, atol=1e-5)


def test_finetune_partial_mode_slices_delta_rows(tmp_path, serve_zoo):
    """partial-mode fine-tune with a trunk delta: base and delta rows
    are width-sliced consistently and match the full-trunk scores."""
    rng = np.random.default_rng(0)
    table8 = {"len": rng.integers(1, 200, 200),
              "emb": rng.standard_normal((200, 8)).astype(np.float32)}
    sample8 = make_task(np.random.default_rng(2), "gauss", n=96, dim=8,
                        classes=3)
    Wd = (serve_zoo[0].W * 1.02).astype(np.float32)
    outs = {}
    for mode in ("partial", "full"):
        sess = make_session(tmp_path / mode, serve_zoo, table8)
        sess.resolve_task("sent", sample8.X, sample8.y)
        sess.register_finetune("m0-pft", "m0", {"trunk/W": Wd})
        sess.create_task(TaskSpec("sent_p", "series", ("P", "N")))
        rm = sess.resolve_task("sent_p", sample8.X, sample8.y,
                               mode=mode, model_id="m0-pft")
        if mode == "partial":
            assert "+w8" in rm.version and "+w8" in rm.trunk_fp
            assert rm.loaded_bytes < rm.stored_bytes + rm.delta_bytes
        outs[mode] = sess.sql("PREDICT emb USING TASK sent_p "
                              "FROM reviews WHERE len > 50")
    np.testing.assert_allclose(outs["partial"].rows["_score"],
                               outs["full"].rows["_score"], atol=1e-5)


def test_warm_trunk_discount_requires_resident_trunk(tmp_path,
                                                     serve_zoo, table,
                                                     sample):
    """The Eq. 7 delta-staging discount only applies when a sharing
    model's trunk is actually loaded/staged — a lazy head-mode
    resolution that never materialized must not understate TransCost."""
    rng = np.random.default_rng(5)
    w = np.abs(rng.standard_normal(12)).astype(np.float32)
    w /= w.sum()
    # base resolved head-mode with NO traffic: trunk never materializes
    sess = make_session(tmp_path, serve_zoo, table)
    base = sess.resolve_task("sent", sample.X, sample.y, mode="head")
    assert not base.zoo_model.materialized
    sess.register_finetune("m0-ft0", "m0", {"head/w": w})
    sess.create_task(TaskSpec("ft", "series", ("P", "N")))
    rm = sess.resolve_task("ft", sample.X, sample.y, model_id="m0-ft0")
    assert rm.profile.model_bytes > rm.delta_bytes   # full staging cost
    # with a materialized base the discount applies
    warm = make_session(tmp_path / "warm", serve_zoo, table)
    warm.resolve_task("sent", sample.X, sample.y)    # full: staged
    warm.register_finetune("m0-ft0", "m0", {"head/w": w})
    warm.create_task(TaskSpec("ft", "series", ("P", "N")))
    rmw = warm.resolve_task("ft", sample.X, sample.y, model_id="m0-ft0")
    assert rmw.profile.model_bytes == float(rmw.delta_bytes)


def test_finetune_resolution_conflicts(tmp_path, serve_zoo, table,
                                       sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    _register_fleet(sess, sample, 1)
    # rebinding a resolved task to another model requires force
    with pytest.raises(ValueError, match="force=True"):
        sess.resolve_task("sent_ft0", sample.X, sample.y, model_id="m0")
    # unknown model ids fail with a actionable message
    with pytest.raises(KeyError, match="register_finetune"):
        sess.resolve_task("sent", sample.X, sample.y, model_id="nope",
                          force=True)
    # update validation: unknown layers and shape mismatches
    with pytest.raises(KeyError, match="head/extra"):
        sess.register_finetune("m0-bad", "m0",
                               {"head/extra": np.ones(3, np.float32)})
    with pytest.raises(ValueError, match="shape"):
        sess.register_finetune("m0-bad", "m0",
                               {"head/w": np.ones(3, np.float32)})
    # fine-tunes need the decoupled store
    blob = make_session(tmp_path / "blob", serve_zoo, table,
                       model_store="blob")
    with pytest.raises(ValueError, match="decoupled"):
        blob.register_finetune("x", "m0", {})
    blob.resolve_task("sent", sample.X, sample.y)
    with pytest.raises(ValueError, match="decoupled"):
        blob.resolve_task("sent", sample.X, sample.y, model_id="m0",
                          force=True)
