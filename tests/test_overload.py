"""Admission control, backpressure, fault-tolerant lanes, and the
serving-side FaultInjector (ISSUE 7 robustness layer).

Covers: typed Rejected/CircuitOpen/RequestError outcomes, priority-class
caps and weighted draining, block-mode backpressure, the deadline-aware
DynamicBudget, retry-with-backoff, the lane circuit breaker + supervisor
reset, chaos injection through BackendPool, and the stop-timeout path a
wedged lane takes through MorphingServer.stop().
"""
import threading
import time

import numpy as np
import pytest

from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import MorphingServer, MorphingSession
from repro.pipeline import (AdmissionPolicy, CircuitOpen, ContinuousBatcher,
                            DynamicBudget, OpProfile, Rejected, Request,
                            RequestError)
from repro.training.fault import FaultInjector, InjectedFault

PROF = OpProfile(flops_per_row=1e5, bytes_per_row=128, model_bytes=1e6)


def make_batcher(step, *, batch_size=4, policy=None, name="lane0", **kw):
    kw.setdefault("max_wait_s", 0.001)
    kw.setdefault("idle_wait_s", 0.01)
    return ContinuousBatcher(step, batch_size=batch_size, name=name,
                             policy=policy, **kw)


# -- policy validation -----------------------------------------------------

def test_policy_rejects_unknown_mode_and_priorities():
    with pytest.raises(ValueError, match="mode"):
        AdmissionPolicy(mode="drop")
    with pytest.raises(ValueError, match="priority"):
        AdmissionPolicy(per_priority_rows={"vip": 10})
    with pytest.raises(ValueError, match="priority"):
        AdmissionPolicy(weights={"urgent": 4})


def test_unknown_priority_rejected_at_submit():
    cb = make_batcher(lambda xs: xs, policy=AdmissionPolicy())
    with pytest.raises(ValueError, match="priority"):
        cb.submit(Request(0, 1.0, priority="vip"))


def test_policy_backoff_is_capped_exponential():
    pol = AdmissionPolicy(retry_backoff_s=0.01, retry_backoff_cap_s=0.03)
    assert pol.backoff_s(1) == pytest.approx(0.01)
    assert pol.backoff_s(2) == pytest.approx(0.02)
    assert pol.backoff_s(3) == pytest.approx(0.03)     # capped
    assert pol.backoff_s(10) == pytest.approx(0.03)


# -- queue caps + backpressure ---------------------------------------------

def test_reject_mode_pushes_back_at_queue_cap():
    pol = AdmissionPolicy(max_queue_rows=2, mode="reject")
    cb = make_batcher(lambda xs: xs, policy=pol)   # no worker: queue holds
    cb.submit(Request(0, 1.0))
    cb.submit(Request(1, 2.0))
    with pytest.raises(Rejected) as ei:
        cb.submit(Request(2, 3.0))
    assert ei.value.reason == "queue_full"
    assert ei.value.lane == "lane0"
    assert ei.value.queued_units == 2
    assert cb.rejected == 1
    # the rejected request left no state: its req_id is still free
    cb.run(total=2)
    cb.submit(Request(2, 3.0))


def test_per_priority_cap_sheds_one_class_only():
    pol = AdmissionPolicy(max_queue_rows=100,
                          per_priority_rows={"best_effort": 1})
    cb = make_batcher(lambda xs: xs, policy=pol)
    cb.submit(Request(0, 1.0, priority="best_effort"))
    with pytest.raises(Rejected):
        cb.submit(Request(1, 2.0, priority="best_effort"))
    # other classes keep admitting past the best-effort cap
    cb.submit(Request(2, 3.0, priority="interactive"))
    cb.submit(Request(3, 4.0, priority="batch"))
    assert cb.rejected_by_priority["best_effort"] == 1
    assert cb.rejected_by_priority["interactive"] == 0


def test_block_mode_waits_for_drain_then_admits():
    pol = AdmissionPolicy(max_queue_rows=1, mode="block",
                          block_timeout_s=5.0)
    cb = make_batcher(lambda xs: [x * 2 for x in xs], batch_size=1,
                      policy=pol).start()
    for i in range(6):                 # every submit past a full queue
        cb.submit(Request(i, float(i)))  # blocks until the worker drains
    outs = {i: cb.result(i, timeout=5.0) for i in range(6)}
    cb.stop()
    assert outs == {i: i * 2.0 for i in range(6)}


def test_block_mode_times_out_to_rejected():
    pol = AdmissionPolicy(max_queue_rows=1, mode="block",
                          block_timeout_s=0.05)
    cb = make_batcher(lambda xs: xs, policy=pol)   # no worker: never drains
    cb.submit(Request(0, 1.0))
    t0 = time.time()
    with pytest.raises(Rejected) as ei:
        cb.submit(Request(1, 2.0))
    assert ei.value.reason == "block_timeout"
    assert time.time() - t0 >= 0.04                # actually waited


# -- weighted priority draining --------------------------------------------

def test_weighted_drain_serves_interactive_first():
    order = []

    def step(ps):
        order.extend(ps)
        return ps

    cb = make_batcher(step, batch_size=1, max_wait_s=0.0,
                      policy=AdmissionPolicy())
    for i in range(6):
        cb.submit(Request(i, "be", priority="best_effort"))
    for i in range(6, 12):
        cb.submit(Request(i, "ia", priority="interactive"))
    cb.run(total=12)
    # interactive weight (8) covers all six queued: they all drain first
    assert order[:6] == ["ia"] * 6
    assert order[6:] == ["be"] * 6


def test_weighted_drain_does_not_starve_best_effort():
    order = []

    def step(ps):
        order.extend(ps)
        return ps

    pol = AdmissionPolicy(weights={"interactive": 2, "batch": 1,
                                   "best_effort": 1})
    cb = make_batcher(step, batch_size=1, max_wait_s=0.0, policy=pol)
    for i in range(8):
        cb.submit(Request(i, "ia", priority="interactive"))
    for i in range(8, 12):
        cb.submit(Request(i, "be", priority="best_effort"))
    cb.run(total=12)
    # weight 2:1 -> best-effort work interleaves instead of waiting for
    # the whole interactive backlog
    assert "be" in order[:4]


# -- satellite (a): submit after stop --------------------------------------

def test_submit_after_stop_raises_lane_stopped():
    cb = make_batcher(lambda xs: xs, name="trunk-a").start()
    cb.submit(Request(0, 1.0))
    cb.stop()
    with pytest.raises(RuntimeError, match="lane 'trunk-a' stopped"):
        cb.submit(Request(1, 2.0))
    # the legacy unnamed batcher keeps a clear message too
    cb2 = ContinuousBatcher(lambda xs: xs, PROF, device="host")
    cb2.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        cb2.submit(Request(0, 1.0))


# -- fault-tolerant lanes --------------------------------------------------

def test_request_error_scoped_to_failed_batch_lane_survives():
    def step(ps):
        if "bad" in ps:
            raise ValueError("poison payload")
        return ps

    pol = AdmissionPolicy(retry_limit=0, breaker_threshold=0)
    cb = make_batcher(step, batch_size=1, max_wait_s=0.0,
                      policy=pol, name="L").start()
    cb.submit(Request(0, "ok-1"))
    assert cb.result(0, timeout=5.0) == "ok-1"
    cb.submit(Request(1, "bad"))
    with pytest.raises(RequestError) as ei:
        cb.result(1, timeout=5.0)
    assert ei.value.req_ids == (1,)
    assert ei.value.lane == "L"
    assert isinstance(ei.value.__cause__, ValueError)
    assert "poison payload" in str(ei.value)
    # the lane worker survived the failed batch and keeps serving
    cb.submit(Request(2, "ok-2"))
    assert cb.result(2, timeout=5.0) == "ok-2"
    assert cb.failed_batches == 1
    cb.stop()


def test_transient_failure_retries_then_succeeds():
    attempts = []

    def flaky(ps):
        attempts.append(1)
        if len(attempts) == 1:
            raise OSError("transient device hiccup")
        return ps

    pol = AdmissionPolicy(retry_limit=2, retry_backoff_s=0.001)
    cb = make_batcher(flaky, batch_size=4, policy=pol).start()
    cb.submit(Request(0, 7.0))
    assert cb.result(0, timeout=5.0) == 7.0        # recovered, not failed
    assert cb.retries == 1
    assert cb.failed_batches == 0
    cb.stop()


def test_retry_budget_exhausted_reports_attempts():
    def always_bad(ps):
        raise OSError("still down")

    pol = AdmissionPolicy(retry_limit=2, retry_backoff_s=0.001,
                          breaker_threshold=0)
    cb = make_batcher(always_bad, batch_size=1, policy=pol).start()
    cb.submit(Request(0, 1.0))
    with pytest.raises(RequestError) as ei:
        cb.result(0, timeout=5.0)
    assert ei.value.attempts == 3                  # 1 try + 2 retries
    assert cb.retries == 2
    cb.stop()


def test_breaker_trips_sheds_and_supervisor_resets():
    healthy = threading.Event()

    def step(ps):
        if not healthy.is_set():
            raise OSError("backend down")
        return ps

    pol = AdmissionPolicy(retry_limit=0, breaker_threshold=2,
                          breaker_cooldown_s=0.05)
    cb = make_batcher(step, batch_size=1, max_wait_s=0.0,
                      policy=pol, name="B").start()
    for i in range(5):
        cb.submit(Request(i, float(i)))
    outcomes = {}
    for i in range(5):
        try:
            cb.result(i, timeout=5.0)
            outcomes[i] = "ok"
        except CircuitOpen:
            outcomes[i] = "shed"
        except RequestError:
            outcomes[i] = "failed"
    # exactly threshold batches failed; the rest were shed by the trip
    assert list(outcomes.values()).count("failed") == 2
    assert list(outcomes.values()).count("shed") == 3
    assert cb.breaker.open and cb.breaker.trips == 1
    # open breaker sheds new submits with the typed error
    with pytest.raises(CircuitOpen):
        cb.submit(Request(10, 1.0))
    # supervisor path: reset only succeeds after the cooldown
    healthy.set()
    deadline = time.time() + 2.0
    while not cb.reset_breaker() and time.time() < deadline:
        time.sleep(0.01)
    assert not cb.breaker.open and cb.breaker_resets == 1
    cb.submit(Request(11, 42.0))
    assert cb.result(11, timeout=5.0) == 42.0      # lane restarted
    cb.stop()


# -- deadline-aware dynamic budget -----------------------------------------

def test_dynamic_budget_shrinks_and_regrows():
    b = DynamicBudget(base_rows=64, min_rows=8)
    assert b.current == 64
    b.update(0.9, 1.0, queued_units=10)            # p95/deadline = 0.9
    assert b.current == 32 and b.shrinks == 1
    b.update(0.9, 1.0, queued_units=10)
    b.update(0.9, 1.0, queued_units=10)
    b.update(0.9, 1.0, queued_units=10)
    assert b.current == 8                          # floored at min_rows
    b.update(0.1, 1.0, queued_units=10)            # comfortably under SLO
    assert b.current == 16 and b.grows >= 1
    b.update(None, None, queued_units=0)           # idle: regrow
    b.update(None, None, queued_units=0)
    assert b.current == 64                         # capped at base


def test_lane_shrinks_batches_under_tight_deadlines():
    def slow(ps):
        time.sleep(0.02)
        return ps

    pol = AdmissionPolicy(min_batch_rows=1, breaker_threshold=0)
    cb = make_batcher(slow, batch_size=32, policy=pol)
    n = 60
    for i in range(n):          # standing backlog: every post-batch
        cb.submit(Request(i, float(i), deadline_s=0.02))  # update sees
    cb.start()                  # queued work + p95 >= the 20ms deadline
    for i in range(n):
        cb.result(i, timeout=30.0)
    cb.stop()
    assert cb.budget.shrinks > 0
    assert cb.budget.current < 32
    assert cb.deadline_misses > 0                  # every serve ran late
    assert cb.deadlines_admitted == n


# -- FaultInjector ---------------------------------------------------------

def test_fault_injector_scripted_call_indices():
    from repro.pipeline.backend import InferSpec, NumpyBackend

    class M:
        def features(self, X):
            return np.asarray(X, np.float32) * 2

        def head(self, F):
            return F.mean(axis=1)

    be = NumpyBackend()
    fi = FaultInjector(scripted_errors={0})
    be.fault_injector = fi
    spec = InferSpec(kind="embed", task="t", col="x", out="f",
                     table="tab", version="v", model=M())
    X = np.ones((4, 3), np.float32)
    with pytest.raises(InjectedFault, match="call 0"):
        be.run_infer(spec, {"x": X})
    out = be.run_infer(spec, {"x": X})             # retry = fresh call
    np.testing.assert_allclose(out["f"], X * 2)
    assert fi.calls == 2 and fi.injected_errors == 1
    assert fi.error_calls == [0]


def test_fault_injector_disarm_and_rate():
    from repro.pipeline.backend import InferSpec, NumpyBackend

    class M:
        def features(self, X):
            return np.asarray(X, np.float32)

    be = NumpyBackend()
    fi = FaultInjector(error_rate=1.0)
    be.fault_injector = fi
    spec = InferSpec(kind="embed", task="t", col="x", out="f",
                     table="tab", version="v", model=M())
    with pytest.raises(InjectedFault):
        be.run_infer(spec, {"x": np.ones((2, 2), np.float32)})
    fi.disarm()
    be.run_infer(spec, {"x": np.ones((2, 2), np.float32)})
    assert fi.injected_errors == 1                 # disarmed calls free


# -- MorphingServer integration --------------------------------------------

@pytest.fixture(scope="module")
def serve_zoo():
    rng = np.random.default_rng(3)
    src = make_task(rng, "gauss", n=120, dim=16, classes=3)
    return [pretrain_model(src, width=12, seed=1, name="m0")]


@pytest.fixture(scope="module")
def table():
    rng = np.random.default_rng(0)
    n = 200
    return {"len": rng.integers(1, 200, n),
            "emb": rng.standard_normal((n, 16)).astype(np.float32)}


@pytest.fixture(scope="module")
def sample():
    return make_task(np.random.default_rng(1), "gauss", n=128, dim=16,
                     classes=3)


def make_session(tmp_path, zoo, table, **kw):
    sess = MorphingSession(zoo=zoo, root=tmp_path, model_store="decoupled",
                           backend="numpy", **kw)
    sess.register_table("reviews",
                        {k: v.copy() for k, v in table.items()})
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0
    return sess


def test_server_priorities_deadlines_in_stats(tmp_path, serve_zoo, table,
                                              sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess, policy=AdmissionPolicy())
    with server:
        r_ia = server.submit("PREDICT emb USING TASK sent FROM reviews",
                             priority="interactive", deadline_ms=60000)
        r_be = server.submit("PREDICT emb USING TASK sent FROM reviews",
                             priority="best_effort")
        server.result(r_ia, timeout=10.0)
        server.result(r_be, timeout=10.0)
        st = server.stats()
        assert st.deadlines_admitted == 1
        assert st.deadline_misses == 0             # 60s deadline held
        assert "interactive" in st.p95_latency_s_by_priority
        assert "best_effort" in st.p95_latency_s_by_priority
        assert st.rejected == 0
        assert st.batch_rows_by_lane               # dynamic budget visible
        health = server.health()
        assert len(health) == 1
        (h,) = health.values()
        assert h["breaker_open"] is False
        with pytest.raises(ValueError, match="priority"):
            server.submit("PREDICT emb USING TASK sent FROM reviews",
                          priority="vip")


def test_server_backpressure_rejects_when_lane_saturated(
        tmp_path, serve_zoo, table, sample):
    nrows = len(table["len"])
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    fi = FaultInjector(slow_rate=1.0, slow_s=0.2)
    sess.backends.set_fault_injector(fi)
    # cap: one queued request's rows fit, two don't
    pol = AdmissionPolicy(max_queue_rows=int(nrows * 1.5))
    server = MorphingServer(session=sess, policy=pol)
    with server:
        r0 = server.submit("PREDICT emb USING TASK sent FROM reviews")
        time.sleep(0.1)           # worker popped r0, is inside slow step
        r1 = server.submit("PREDICT emb USING TASK sent FROM reviews")
        with pytest.raises(Rejected) as ei:
            server.submit("PREDICT emb USING TASK sent FROM reviews",
                          priority="best_effort")
        assert ei.value.reason == "queue_full"
        server.result(r0, timeout=10.0)
        server.result(r1, timeout=10.0)            # queued one still served
        st = server.stats()
        assert st.rejected == 1
        assert st.rejected_by_priority == {"best_effort": 1}


def test_server_fault_injection_parity_without_restart(
        tmp_path, serve_zoo, table, sample):
    """Killed batches surface as RequestError on exactly their requests;
    every non-injected request matches the fault-free engine answer and
    the server never restarts."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    thrs = [20, 40, 60, 80, 100, 120]
    ref = {t: sess.sql("PREDICT emb USING TASK sent FROM reviews "
                       f"WHERE len < {t}").rows["_score"] for t in thrs}
    pol = AdmissionPolicy(retry_limit=0, breaker_threshold=3,
                          breaker_cooldown_s=0.01)
    server = MorphingServer(session=sess, policy=pol)
    with server:
        # attach chaos only after warmup so resolution/staging calls
        # don't consume scripted indices
        warm = server.submit("PREDICT emb USING TASK sent FROM reviews "
                             f"WHERE len < {thrs[0]}")
        server.result(warm, timeout=10.0)
        fi = FaultInjector(scripted_errors={1, 3})
        sess.backends.set_fault_injector(fi)
        failed, ok = [], []
        # len < t grows with t: every query has fresh cache-miss rows,
        # so each serve is one injector-visible trunk call
        for t in thrs[1:]:
            rid = server.submit("PREDICT emb USING TASK sent FROM "
                                f"reviews WHERE len < {t}")
            try:
                out = server.result(rid, timeout=10.0)
                ok.append((t, out))
            except RequestError as e:
                assert isinstance(e.__cause__, InjectedFault)
                failed.append(t)
        assert len(failed) == 2                    # exactly the scripted
        assert fi.injected_errors == 2
        for t, out in ok:                          # parity on survivors
            np.testing.assert_allclose(out.scores, ref[t], rtol=1e-5)
        # server survived without a restart: same worker set serves on
        rid = server.submit("PREDICT emb USING TASK sent FROM reviews "
                            f"WHERE len < {thrs[0]}")
        server.result(rid, timeout=10.0)
        st = server.stats()
        assert st.failed_batches == 2
        assert not st.breaker_open_lanes           # 2 < threshold 3
        sess.backends.set_fault_injector(None)


def test_server_breaker_trip_and_supervisor_restart(
        tmp_path, serve_zoo, table, sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    pol = AdmissionPolicy(retry_limit=0, breaker_threshold=2,
                          breaker_cooldown_s=0.3)
    server = MorphingServer(session=sess, policy=pol)
    with server:
        warm = server.submit("PREDICT emb USING TASK sent FROM reviews "
                             "WHERE len < 20")
        server.result(warm, timeout=10.0)
        fi = FaultInjector(error_rate=1.0)         # kill every batch
        sess.backends.set_fault_injector(fi)
        for t in (40, 60):                         # two failed batches
            rid = server.submit("PREDICT emb USING TASK sent FROM "
                                f"reviews WHERE len < {t}")
            with pytest.raises(RequestError):
                server.result(rid, timeout=10.0)
        st = server.stats()
        assert st.breaker_trips == 1
        assert st.breaker_open_lanes               # lane is shedding
        with pytest.raises(CircuitOpen):
            server.submit("PREDICT emb USING TASK sent FROM reviews "
                          "WHERE len < 80")
        # heal the backend; the supervisor resets on the next submit
        # after the cooldown and the lane serves again
        fi.disarm()
        time.sleep(0.35)
        rid = server.submit("PREDICT emb USING TASK sent FROM reviews "
                            "WHERE len < 80")
        server.result(rid, timeout=10.0)
        st = server.stats()
        assert st.breaker_resets == 1
        assert not st.breaker_open_lanes
        sess.backends.set_fault_injector(None)


# -- satellite (c): PR 6 stop-timeout path through the server --------------

def test_server_stop_timeout_names_lane_then_retry_succeeds(
        tmp_path, serve_zoo, table, sample):
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess)
    server.start()
    warm = server.submit("PREDICT emb USING TASK sent FROM reviews "
                         "WHERE len < 20")
    server.result(warm, timeout=10.0)
    lane = server._lane_of_task["sent"]
    entered, release = threading.Event(), threading.Event()
    orig_step = lane.batcher.step_fn

    def wedged(ps):
        entered.set()
        release.wait(10.0)
        return orig_step(ps)

    lane.batcher.step_fn = wedged
    server.submit("PREDICT emb USING TASK sent FROM reviews "
                  "WHERE len < 40")
    assert entered.wait(5.0)                       # worker is wedged
    with pytest.raises(RuntimeError, match="did not join") as ei:
        server.stop(timeout=0.2)
    assert lane.key in str(ei.value)               # names the stuck lane
    release.set()                                  # backend un-wedges
    server.stop(timeout=10.0)                      # retry joins cleanly
    assert lane.batcher._thread is None


def test_server_stop_clean_after_prior_timed_out_attempt(
        tmp_path, serve_zoo, table, sample):
    """A healthy server shuts down cleanly even when an earlier stop()
    attempt (on another, wedged server) timed out — per-server state,
    no cross-contamination — and repeated stop() is idempotent."""
    sess = make_session(tmp_path, serve_zoo, table)
    sess.resolve_task("sent", sample.X, sample.y)
    server = MorphingServer(session=sess)
    with server:
        rid = server.submit("PREDICT emb USING TASK sent FROM reviews")
        server.result(rid, timeout=10.0)
    server.stop()                                  # idempotent second stop
    for lane in server._lanes.values():
        assert lane.batcher._thread is None
