"""Execution-backend layer: jitted forward parity against the numpy
oracle (all four ZooModel modes, ragged + empty chunks), shape-bucketed
compile counts, one-time weight staging, registry dispatch through the
executor, and cost-model calibration from the live backend."""
import threading
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import make_task, pretrain_model
from repro.core.task import TaskSpec
from repro.engine import MorphingSession
from repro.pipeline import (Dag, HardwareProfile, InferSpec, JaxBackend,
                            Node, NumpyBackend, OpProfile, PipelineExecutor,
                            calibrate, choose_device)
from repro.pipeline.backend import _next_pow2
from repro.pipeline.batcher import BatcherStats

_FAMILY_FOR_MODE = {"linear": "gauss", "radial": "ring", "relu": "sparse",
                    "proj1d": "stripe"}


def _model_for_mode(mode, dim=8, seed=0):
    rng = np.random.default_rng(seed)
    src = make_task(rng, _FAMILY_FOR_MODE[mode], n=120, dim=dim, classes=3)
    zm = pretrain_model(src, width=12, seed=seed, name=f"zm-{mode}",
                        mode=mode)
    assert zm.mode == mode
    return zm


def _spec_for(zm, version, **kw):
    model = SimpleNamespace(zoo_model=zm, features=zm.features,
                            head=lambda F: np.asarray(F).mean(axis=1))
    defaults = dict(kind="embed", task="t", col="x", out="f", table="tab",
                    version=version, model=model, batch_size=16,
                    share=None, stats=BatcherStats())
    defaults.update(kw)
    return InferSpec(**defaults)


# -- jitted forward parity -------------------------------------------------

@pytest.mark.parametrize("mode", ["linear", "radial", "relu", "proj1d"])
@pytest.mark.parametrize("n", [133, 1, 0])
def test_jax_forward_matches_numpy_oracle(mode, n):
    zm = _model_for_mode(mode)
    jb = JaxBackend()
    spec = _spec_for(zm, f"{mode}@parity")
    rng = np.random.default_rng(1)
    X = rng.standard_normal((n, 8)).astype(np.float32)
    got = jb.run_infer(spec, {"x": X})["f"]
    want = zm.features(X)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("ncols", [4, 8, 12])
def test_jax_forward_pads_or_slices_feature_dim(ncols):
    """ZooModel.features slices wide inputs / zero-pads narrow ones; the
    staged path must replicate that host-side."""
    zm = _model_for_mode("linear")
    jb = JaxBackend()
    spec = _spec_for(zm, f"linear@dim{ncols}")
    X = np.random.default_rng(2).standard_normal((37, ncols)) \
        .astype(np.float32)
    np.testing.assert_allclose(jb.run_infer(spec, {"x": X})["f"],
                               zm.features(X), atol=1e-5)


def test_jax_predict_fuses_score_head():
    zm = _model_for_mode("relu")
    jb = JaxBackend()
    spec = _spec_for(zm, "relu@pred", kind="predict")
    X = np.random.default_rng(3).standard_normal((77, 8)).astype(np.float32)
    got = jb.run_infer(spec, {"x": X})["f"]
    np.testing.assert_allclose(got, zm.features(X).mean(axis=1), atol=1e-5)


# -- shape bucketing -------------------------------------------------------

def test_bucketing_compile_count_is_log_n():
    """Many distinct ragged chunk lengths must share O(log n) compiled
    shapes (pad to next power of two, slice on return)."""
    zm = _model_for_mode("linear")
    jb = JaxBackend(min_bucket=32)
    spec = _spec_for(zm, "linear@buckets")
    compiled = []
    jb.on_compile = lambda version, key: compiled.append(key)
    rng = np.random.default_rng(4)
    sizes = [3, 7, 17, 33, 65, 100, 129, 200, 257, 400, 511, 600]
    for n in sizes:
        X = rng.standard_normal((n, 8)).astype(np.float32)
        out = jb.run_infer(spec, {"x": X})["f"]
        assert out.shape == (n, 12)
    # buckets: 32, 64, 128, 256, 512, 1024 -> <= 6 despite 12 ragged sizes
    assert jb.compile_count <= 6
    assert len(compiled) == jb.compile_count
    assert all(b >= 32 and b == _next_pow2(b) for _, b in compiled)


def test_query_compile_count_and_single_staging():
    """Acceptance: a 6k-row / 256-row-chunk query stays <= 6 compiles and
    stages weights exactly once per resolved task."""
    rng = np.random.default_rng(5)
    src = make_task(rng, "gauss", n=120, dim=8, classes=3)
    zoo = [pretrain_model(src, width=12, seed=1, name="m0")]
    sess = MorphingSession(zoo=zoo, backend="jax", chunk_rows=256,
                           enable_share=False)
    sess.create_task(TaskSpec("sent", "series", ("P", "N")))
    sess.registry._resolution["sent"] = 0
    n = 6000
    sess.register_table("reviews", {
        "gender": rng.integers(0, 2, n),
        "len": rng.integers(1, 200, n),
        "emb": rng.standard_normal((n, 8)).astype(np.float32)})
    sess.resolve_task("sent", np.zeros((4, 8), np.float32),
                      np.zeros(4, np.int64))
    jb = next(iter({id(b): b for b in sess.backends.values()}.values()))
    assert isinstance(jb, JaxBackend)
    assert jb.stage_count == 1            # staged at resolve, before queries
    res = sess.sql("SELECT gender, AVG(sent(emb)) FROM reviews "
                   "WHERE len > 20 GROUP BY gender")
    assert res.report.compile_count <= 6
    assert set(res.report.backend_of.values()) == {"jax"}
    assert jb.stage_count == 1            # still once: no per-chunk staging
    res2 = sess.sql("SELECT gender, AVG(sent(emb)) FROM reviews "
                    "WHERE len > 20 GROUP BY gender")
    assert res2.report.compile_count == 0  # warm: every bucket reused
    assert jb.stage_count == 1


def test_stage_is_idempotent_per_version():
    zm = _model_for_mode("linear")
    jb = JaxBackend()
    s1 = jb.stage("m@1.0", zm)
    s2 = jb.stage("m@1.0", zm)
    assert s1 is s2 and jb.stage_count == 1
    jb.stage("m@2.0", zm)
    assert jb.stage_count == 2


# -- registry dispatch + session parity ------------------------------------

def test_session_backend_parity_end_to_end():
    rng = np.random.default_rng(6)
    src = make_task(rng, "ring", n=120, dim=8, classes=3)
    zoo = [pretrain_model(src, width=12, seed=2, name="m0")]
    n = 500
    table = {"gender": rng.integers(0, 2, n),
             "len": rng.integers(1, 200, n),
             "emb": rng.standard_normal((n, 8)).astype(np.float32)}
    scores = {}
    for backend in ("numpy", "jax"):
        sess = MorphingSession(zoo=zoo, backend=backend, chunk_rows=64)
        sess.create_task(TaskSpec("sent", "series", ("P", "N")))
        sess.registry._resolution["sent"] = 0
        sess.register_table("reviews",
                            {k: v.copy() for k, v in table.items()})
        sess.resolve_task("sent", np.zeros((4, 8), np.float32),
                          np.zeros(4, np.int64))
        res = sess.sql("SELECT gender, AVG(sent(emb)) FROM reviews "
                       "WHERE len > 20 GROUP BY gender")
        scores[backend] = res.rows["mean__score"]
    np.testing.assert_allclose(scores["numpy"], scores["jax"], atol=1e-5)


def test_executor_without_registry_uses_host_fallback():
    """Nodes lowered with an InferSpec still run through node.fn (the
    singleton numpy backend) when no registry is supplied."""
    zm = _model_for_mode("linear")
    spec = _spec_for(zm, "linear@fallback")
    from repro.pipeline.backend import default_host_backend
    node = Node("embed", "embed",
                fn=lambda b: default_host_backend().run_infer(spec, b),
                device="tpu")
    node.meta["infer"] = spec
    d = Dag()
    d.add(Node("src", "scan"))
    d.add(node, deps=("src",))
    X = np.random.default_rng(7).standard_normal((40, 8)).astype(np.float32)
    ex = PipelineExecutor(d)                     # no backends
    out = ex.execute({"src": {"x": X}})["embed"]
    np.testing.assert_allclose(out["f"], zm.features(X), atol=1e-6)
    assert ex.stats.backend_of["embed"] == "fn"


def test_exec_stats_accumulate_under_concurrency():
    """op_seconds/calls_of are read-modify-written from pool threads; the
    lock must not lose increments."""
    d = Dag()
    d.add(Node("src", "scan"))
    node = Node("op", "predict", fn=lambda b: b)
    d.add(node, deps=("src",))
    ex = PipelineExecutor(d)
    n_threads, n_calls = 8, 50

    def hammer():
        for _ in range(n_calls):
            ex._run_node(node, [{}])

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ex.stats.calls_of["op"] == n_threads * n_calls
    assert ex.stats.op_seconds["op"] >= 0.0


# -- calibration -----------------------------------------------------------

def test_calibrate_measures_numpy_backend():
    hwp = calibrate(NumpyBackend(), "host", rows=(64, 512), repeats=1)
    assert hwp.measured and hwp.name == "host"
    assert hwp.flops_per_s > 0 and np.isfinite(hwp.flops_per_s)
    assert hwp.mem_bw > 0
    assert hwp.launch_latency_s >= 0.0


def test_calibrate_measures_jax_backend_and_link():
    jb = JaxBackend()
    hwp = calibrate(jb, "tpu", rows=(64, 256), repeats=1)
    assert hwp.measured
    assert hwp.flops_per_s > 0
    assert np.isfinite(hwp.link_bw) and hwp.link_bw > 0


def test_calibrated_profiles_drive_placement():
    p = OpProfile(flops_per_row=2e6, bytes_per_row=4096, model_bytes=4e6)
    fast_tpu = {"tpu": HardwareProfile("tpu", 1e15, 1e12, link_bw=1e12,
                                       launch_latency_s=1e-7,
                                       measured=True)}
    slow_tpu = {"tpu": HardwareProfile("tpu", 1e3, 1e3, link_bw=1e3,
                                       launch_latency_s=1.0,
                                       measured=True)}
    assert choose_device(p, 65536, hw=fast_tpu) == "tpu"
    assert choose_device(p, 65536, hw=slow_tpu) == "host"


def test_session_calibrate_populates_hw():
    rng = np.random.default_rng(8)
    src = make_task(rng, "gauss", n=120, dim=8, classes=3)
    zoo = [pretrain_model(src, width=12, seed=1, name="m0")]
    sess = MorphingSession(zoo=zoo, backend="numpy")
    hw = sess.calibrate(rows=(64, 256), repeats=1)
    assert set(hw) == set(sess.backends)
    assert all(p.measured for p in hw.values())
    assert sess.hw is hw


def test_jax_predict_respects_custom_head():
    """A non-mean head must not be silently replaced by the fused mean
    head: features run on device, the custom head on host."""
    zm = _model_for_mode("linear")
    jb = JaxBackend()
    spec = _spec_for(zm, "linear@customhead", kind="predict")
    spec.model.head = lambda F: np.asarray(F).max(axis=1)
    spec.model.head_kind = "max"
    X = np.random.default_rng(9).standard_normal((50, 8)).astype(np.float32)
    got = jb.run_infer(spec, {"x": X})["f"]
    np.testing.assert_allclose(got, zm.features(X).max(axis=1), atol=1e-5)


def test_session_calibrate_dedupes_shared_backend(monkeypatch):
    """backend='jax' maps host+tpu to one instance: measure it once."""
    import repro.engine.session as sess_mod
    rng = np.random.default_rng(10)
    src = make_task(rng, "gauss", n=120, dim=8, classes=3)
    zoo = [pretrain_model(src, width=12, seed=1, name="m0")]
    sess = MorphingSession(zoo=zoo, backend="numpy")
    calls = []

    def fake_calibrate(b, dev, **kw):
        calls.append(dev)
        return HardwareProfile(dev, 1e9, 1e8, measured=True)

    monkeypatch.setattr(sess_mod, "calibrate", fake_calibrate)
    hw = sess.calibrate()
    assert len(calls) == 1                 # one shared instance: one pass
    assert set(hw) == set(sess.backends)
    assert {p.name for p in hw.values()} == set(sess.backends)
