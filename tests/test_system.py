"""End-to-end behaviour: the task-centric flow of the paper —
CREATE TASK -> select model -> store/load via Mvec -> DAG query with
batched inference + vector sharing -> results; plus a train->checkpoint->
serve round trip on a reduced arch.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.configs.base import ShapeConfig
from repro.core import (ModelSelector, TaskFeaturizer, TaskRegistry,
                        TaskSpec, build_tasks, build_zoo, transfer_matrix)
from repro.models import build_model, make_batch
from repro.pipeline import (Dag, Node, PipelineExecutor, VectorShareCache,
                            filter_op, groupby_agg)
from repro.storage import (BlobStore, Catalog, CheckpointManager,
                           DecoupledStore)
from repro.training import OptimizerConfig, init_state, make_train_step


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    td = tmp_path_factory.mktemp("world")
    zoo = build_zoo(12, seed=0)
    hist = build_tasks(24, seed=1)
    V = transfer_matrix(zoo, hist)
    fz = TaskFeaturizer()
    feats = np.stack([fz.features(t.X, t.y) for t in hist])
    sel = ModelSelector(k=5, n_anchors=2, nmf_iters=200).fit_offline(
        V, feats, zoo=zoo)
    return td, zoo, sel


def test_task_centric_query_end_to_end(world):
    """The paper's Table-1 task-centric query, mechanically:
    SELECT gender, AVG(sentiment(comment)) ... GROUP BY gender."""
    td, zoo, sel = world
    reg = TaskRegistry(selector=sel, zoo=zoo)
    reg.create_task(TaskSpec("sentiment", "series", ("POS", "NEG")))

    rng = np.random.default_rng(0)
    n = 400
    from repro.core.zoo import make_task
    sample = make_task(rng, "gauss", n=64, dim=16)
    reg.resolve("sentiment", sample.X, sample.y)
    predict = reg.predict_fn("sentiment")

    reviews = {"uid": rng.integers(0, 40, n),
               "gender": rng.integers(0, 2, n),
               "len": rng.integers(1, 200, n),
               "emb": rng.standard_normal((n, 16)).astype(np.float32)}

    cache = VectorShareCache(td / "cache")

    def embed_node(b):
        out = dict(b)
        out["feat"] = cache.get_or_embed("reviews", "emb", b["emb"],
                                         predict)
        return out

    def score_node(b):
        out = dict(b)
        out["sentiment"] = b["feat"].mean(axis=1)
        return out

    dag = Dag()
    dag.add(Node("reviews", "scan"))
    dag.add(Node("flt", "filter",
                 fn=lambda b: filter_op(b, lambda x: x["len"] > 10)),
            deps=("reviews",))
    dag.add(Node("emb", "embed", fn=embed_node, cost_hint=5), deps=("flt",))
    dag.add(Node("pred", "predict", fn=score_node, cost_hint=2),
            deps=("emb",))
    dag.add(Node("agg", "groupby",
                 fn=lambda b: groupby_agg(b, "gender", "sentiment")),
            deps=("pred",))
    ex = PipelineExecutor(dag)
    res = ex.execute({"reviews": reviews})
    assert set(res["agg"]["gender"]) <= {0, 1}
    assert np.all(np.isfinite(res["agg"]["mean_sentiment"]))
    # re-running the query reuses the shared embedding
    ex.execute({"reviews": reviews})
    assert cache.stats.hits >= 1


def test_zoo_model_roundtrip_through_stores(world):
    td, zoo, sel = world
    cat = Catalog(td / "cat")
    blob = BlobStore(td / "blob", cat)
    dec = DecoupledStore(td / "dec", cat)
    m = zoo[0]
    params = {"W": m.W}
    blob.save(m.name, {"mode": m.mode}, params)
    arch, loaded = blob.load(m.name, template=params)
    np.testing.assert_array_equal(loaded["W"], m.W)
    dec.save(m.name + "-dec", {"mode": m.mode}, params)
    _, loaded2 = dec.load(m.name + "-dec", template=params)
    np.testing.assert_array_equal(loaded2["W"], m.W)
    kinds = {i.storage for i in cat.list_models()}
    assert {"blob", "decoupled"} <= kinds


def test_train_checkpoint_serve_roundtrip(tmp_path):
    """Reduced LM: train 8 steps, checkpoint, restore, decode greedily."""
    cfg = smoke_config("h2o-danube-1.8b").replace(num_layers=2)
    m = build_model(cfg, attn_impl="naive")
    params = m.init(jax.random.PRNGKey(0))
    opt = init_state(params)
    step = jax.jit(make_train_step(m, OptimizerConfig(learning_rate=1e-3)))
    batch = make_batch(cfg, ShapeConfig("s", 32, 4, "train"))
    for _ in range(8):
        params, opt, out = step(params, opt, batch)
    cm = CheckpointManager(tmp_path)
    cm.save(8, {"params": params})
    got, s = cm.restore({"params": params})
    restored = jax.tree.map(jnp.asarray, got["params"])
    tokens = batch["tokens"][:, :16]
    _, state = m.prefill(params, tokens, max_len=20)
    l1, _ = m.decode_step(params, state, tokens[:, -1:])
    _, state2 = m.prefill(restored, tokens, max_len=20)
    l2, _ = m.decode_step(restored, state2, tokens[:, -1:])
    assert float(jnp.abs(l1 - l2).max()) < 2e-6
