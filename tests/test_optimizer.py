"""AdamW from scratch: reference equivalence, schedule, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.optimizer import (AdamWState, OptimizerConfig,
                                      apply_updates, clip_by_global_norm,
                                      init_state, lr_schedule)


def _adamw_ref(p, g, m, v, step, cfg):
    """Textbook AdamW single-tensor reference."""
    m = cfg.beta1 * m + (1 - cfg.beta1) * g
    v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
    mh = m / (1 - cfg.beta1 ** step)
    vh = v / (1 - cfg.beta2 ** step)
    lr = float(lr_schedule(cfg, jnp.asarray(step)))
    upd = mh / (np.sqrt(vh) + cfg.eps)
    if p.ndim >= 2:
        upd = upd + cfg.weight_decay * p
    return p - lr * upd, m, v


def test_matches_reference():
    cfg = OptimizerConfig(learning_rate=1e-2, warmup_steps=0, grad_clip=1e9)
    rng = np.random.default_rng(0)
    p = {"w": rng.standard_normal((4, 3)).astype(np.float32),
         "b": rng.standard_normal(3).astype(np.float32)}
    g = {"w": rng.standard_normal((4, 3)).astype(np.float32) * 0.1,
         "b": rng.standard_normal(3).astype(np.float32) * 0.1}
    params = jax.tree.map(jnp.asarray, p)
    state = init_state(params)
    new_p, new_s, _ = apply_updates(cfg, params, jax.tree.map(jnp.asarray, g),
                                    state)
    ref_w, _, _ = _adamw_ref(p["w"], g["w"], np.zeros_like(p["w"]),
                             np.zeros_like(p["w"]), 1, cfg)
    ref_b, _, _ = _adamw_ref(p["b"], g["b"], np.zeros_like(p["b"]),
                             np.zeros_like(p["b"]), 1, cfg)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref_w, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_p["b"]), ref_b, rtol=1e-5)
    assert int(new_s.step) == 1


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                 rel=1e-4)


def test_schedule_shape():
    cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=100,
                          total_steps=1000, min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s)))
           for s in (0, 50, 100, 500, 1000)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3, rel=1e-2)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_bf16_moments_halve_memory():
    p = {"w": jnp.zeros((128, 128))}
    s32 = init_state(p, "float32")
    s16 = init_state(p, "bfloat16")
    assert s16.m["w"].dtype == jnp.bfloat16
    assert s16.m["w"].nbytes * 2 == s32.m["w"].nbytes
