"""BLOB / decoupled / delta / API model stores + catalog."""
import numpy as np
import pytest

from repro.storage import (ApiModelRegistry, BlobStore, Catalog,
                           DecoupledStore, flatten_params, unflatten_like)


@pytest.fixture
def params():
    rng = np.random.default_rng(0)
    return {"embed": rng.standard_normal((16, 8)).astype(np.float32),
            "layers": {"w1": rng.standard_normal((8, 8)).astype(np.float32),
                       "b1": np.zeros(8, np.float32)}}


def test_flatten_roundtrip(params):
    flat = flatten_params(params)
    assert set(flat) == {"embed", "layers/w1", "layers/b1"}
    back = unflatten_like(params, flat)
    np.testing.assert_array_equal(back["layers"]["w1"],
                                  params["layers"]["w1"])


def test_blob_store(tmp_path, params):
    cat = Catalog(tmp_path / "cat")
    bs = BlobStore(tmp_path / "blob", cat)
    bs.save("m1", {"arch": "mlp", "layers": 1}, params,
            task_types=["classification"])
    arch, loaded = bs.load("m1", template=params)
    assert arch["arch"] == "mlp"
    np.testing.assert_array_equal(loaded["embed"], params["embed"])
    assert cat.get_model("m1").storage == "blob"
    assert cat.get_model("m1").param_count == 16 * 8 + 64 + 8


def test_decoupled_partial_and_delta(tmp_path, params):
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat)
    ds.save("base", {"arch": "mlp"}, params)
    base_bytes = ds.stored_bytes("base")

    ft = {"embed": params["embed"],
          "layers": {"w1": params["layers"]["w1"] + 1.0,
                     "b1": params["layers"]["b1"]}}
    ds.save("ft", {"arch": "mlp"}, ft, base_model="base")
    assert ds.stored_bytes("ft") < base_bytes / 2  # only w1 rewritten

    _, loaded = ds.load("ft", template=ft)
    np.testing.assert_array_equal(loaded["layers"]["w1"],
                                  ft["layers"]["w1"])
    np.testing.assert_array_equal(loaded["embed"], params["embed"])

    # partial load: just the embedding layer
    _, some = ds.load("ft", layer_filter=lambda n: n == "embed")
    assert list(some) == ["embed"]

    # range read within a layer
    rows = ds.load_layer_rows("ft", "embed", 4, 9)
    np.testing.assert_array_equal(rows, params["embed"][4:9])


def test_api_registry_retry_cache_quota():
    reg = ApiModelRegistry()
    calls = {"n": 0}

    def fn(x):
        calls["n"] += 1
        return np.asarray(x) * 2

    reg.register("gpt-sim", fn, latency_s=0.001, failure_rate=0.5,
                 max_retries=10, quota=50)
    rng = np.random.default_rng(0)
    out = reg.invoke("gpt-sim", np.ones(3), rng)
    np.testing.assert_array_equal(out, 2 * np.ones(3))
    # cache hit: second identical call doesn't re-invoke
    n = calls["n"]
    reg.invoke("gpt-sim", np.ones(3), rng)
    assert calls["n"] == n
    assert reg.stats["gpt-sim"]["cache_hits"] == 1

    reg.register("tiny", fn, latency_s=0.001, quota=2, cache=False)
    reg.invoke("tiny", np.ones(1), rng)
    reg.invoke("tiny", np.ones(2), rng)
    with pytest.raises(RuntimeError, match="quota"):
        reg.invoke("tiny", np.ones(4), rng)
