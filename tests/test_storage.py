"""BLOB / decoupled / delta / API model stores + catalog."""
import numpy as np
import pytest

from repro.storage import (ApiModelRegistry, BlobStore, Catalog,
                           DecoupledStore, flatten_params, unflatten_like)
from repro.storage import mvec


@pytest.fixture
def params():
    rng = np.random.default_rng(0)
    return {"embed": rng.standard_normal((16, 8)).astype(np.float32),
            "layers": {"w1": rng.standard_normal((8, 8)).astype(np.float32),
                       "b1": np.zeros(8, np.float32)}}


def test_flatten_roundtrip(params):
    flat = flatten_params(params)
    assert set(flat) == {"embed", "layers/w1", "layers/b1"}
    back = unflatten_like(params, flat)
    np.testing.assert_array_equal(back["layers"]["w1"],
                                  params["layers"]["w1"])


def test_blob_store(tmp_path, params):
    cat = Catalog(tmp_path / "cat")
    bs = BlobStore(tmp_path / "blob", cat)
    bs.save("m1", {"arch": "mlp", "layers": 1}, params,
            task_types=["classification"])
    arch, loaded = bs.load("m1", template=params)
    assert arch["arch"] == "mlp"
    np.testing.assert_array_equal(loaded["embed"], params["embed"])
    assert cat.get_model("m1").storage == "blob"
    assert cat.get_model("m1").param_count == 16 * 8 + 64 + 8


def test_decoupled_partial_and_delta(tmp_path, params):
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat)
    ds.save("base", {"arch": "mlp"}, params)
    base_bytes = ds.stored_bytes("base")

    ft = {"embed": params["embed"],
          "layers": {"w1": params["layers"]["w1"] + 1.0,
                     "b1": params["layers"]["b1"]}}
    ds.save("ft", {"arch": "mlp"}, ft, base_model="base")
    assert ds.stored_bytes("ft") < base_bytes / 2  # only w1 rewritten

    _, loaded = ds.load("ft", template=ft)
    np.testing.assert_array_equal(loaded["layers"]["w1"],
                                  ft["layers"]["w1"])
    np.testing.assert_array_equal(loaded["embed"], params["embed"])

    # partial load: just the embedding layer
    _, some = ds.load("ft", layer_filter=lambda n: n == "embed")
    assert list(some) == ["embed"]

    # range read within a layer
    rows = ds.load_layer_rows("ft", "embed", 4, 9)
    np.testing.assert_array_equal(rows, params["embed"][4:9])


def test_delta_file_composition_and_flags(tmp_path, params):
    """A changed same-geometry layer lands as a FLAG_DELTA-tagged delta
    file, not a full rewrite, and reads compose base + delta."""
    ds = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"))
    ds.save("base", {"arch": "mlp"}, params)
    ft = {"embed": params["embed"],
          "layers": {"w1": params["layers"]["w1"] + 0.125,
                     "b1": params["layers"]["b1"]}}
    ds.save("ft", {"arch": "mlp"}, ft, base_model="base")
    w1_li = next(li for li in ds.catalog.get_layers("ft")
                 if li.layer_name == "layers/w1")
    assert w1_li.delta_of == "base" and not w1_li.file.startswith("@")
    assert w1_li.file.endswith(".delta.mvec")
    head = mvec.decode_header(
        (tmp_path / "dec" / "ft" / w1_li.file).read_bytes())
    assert head.is_delta and head.flags & mvec.FLAG_DELTA
    # on-disk payload is the delta, not the weights (to float rounding:
    # (w1 + 0.125) - w1 differs from 0.125 by ~1 ulp of w1)
    delta = mvec.decode(
        (tmp_path / "dec" / "ft" / w1_li.file).read_bytes())
    np.testing.assert_allclose(delta, np.full_like(delta, 0.125),
                               atol=1e-6)
    _, loaded = ds.load("ft", template=ft)
    np.testing.assert_allclose(loaded["layers"]["w1"],
                               ft["layers"]["w1"], atol=1e-6)
    assert ds.stats.delta_composes >= 1
    assert ds.delta_bytes("ft") > 0 and ds.delta_bytes("base") == 0
    # non-delta full files are untagged
    base_li = next(li for li in ds.catalog.get_layers("base")
                   if li.layer_name == "layers/w1")
    assert not mvec.decode_header(
        (tmp_path / "dec" / "base" / base_li.file).read_bytes()).is_delta


def test_delta_integer_layers_roundtrip_exactly(tmp_path):
    """Integer deltas compose exactly via wraparound arithmetic."""
    ds = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"))
    rng = np.random.default_rng(0)
    base = {"ids": rng.integers(0, 255, 32).astype(np.uint8),
            "steps": rng.integers(-1000, 1000, 16).astype(np.int32)}
    ds.save("base", {"arch": "emb"}, base)
    ft = {"ids": (base["ids"] + 200).astype(np.uint8),   # wraps
          "steps": base["steps"] - 5}
    ds.save("ft", {"arch": "emb"}, ft, base_model="base")
    assert any(li.file.endswith(".delta.mvec")
               for li in ds.catalog.get_layers("ft"))
    _, loaded = ds.load("ft")
    np.testing.assert_array_equal(loaded["ids"], ft["ids"])
    np.testing.assert_array_equal(loaded["steps"], ft["steps"])


def test_delta_row_slice_composes(tmp_path, params):
    """load_layer_rows on a delta layer slices base and delta rows
    consistently (the width-sliced partial-load path for fine-tunes)."""
    ds = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"))
    ds.save("base", {"arch": "mlp"}, params)
    bump = np.zeros_like(params["embed"])
    bump[3:7] = 1.5
    ft = dict(params, embed=params["embed"] + bump)
    ds.save("ft", {"arch": "mlp"}, ft, base_model="base")
    rows = ds.load_layer_rows("ft", "embed", 2, 9)
    np.testing.assert_allclose(rows, ft["embed"][2:9], atol=1e-6)


def test_delta_loaded_bytes_count_only_delta_for_warm_base(tmp_path,
                                                           params):
    """With the base layer warm in the cross-model cache, loading a
    fine-tune reads only its delta bytes from disk."""
    ds = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"))
    ds.save("base", {"arch": "mlp"}, params)
    ft = dict(params, embed=params["embed"] * 1.01)
    ds.save("ft", {"arch": "mlp"}, ft, base_model="base")
    ds.load("base")                          # warm every base layer
    b0, d0 = ds.stats.loaded_bytes, ds.stats.delta_bytes
    ds.load("ft")
    read = ds.stats.loaded_bytes - b0
    assert read == ds.stats.delta_bytes - d0 == ds.delta_bytes("ft")
    assert 0 < read < ds.stored_bytes("base")


def test_no_double_count_on_cached_composed_delta_reads(tmp_path, params):
    """Byte-accounting audit (regression): a cache-hit read of a
    *composed* delta tensor must not re-count ``loaded_bytes`` or
    ``delta_bytes`` — disk counters move only when disk is read, and
    ``delta_bytes`` stays an exact subset of ``loaded_bytes``:

      loaded_bytes == (plain file bytes read) + (delta file bytes read)

    A composed tensor served from cache lands entirely in
    ``cache_hit_bytes`` (at the composed tensor's logical size, which is
    NOT a disk read and must never be attributed as one)."""
    ds = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"))
    ds.save("base", {"arch": "mlp"}, params)
    ft = dict(params, embed=params["embed"] * 1.01)
    ds.save("ft", {"arch": "mlp"}, ft, base_model="base")
    # fresh store: no save-time reads polluting the ledger
    cold = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"))
    cold.load("ft")
    disk_files = cold.cold_resolve_bytes("ft")  # base + delta files
    s = cold.stats
    assert s.loaded_bytes == disk_files
    assert s.delta_bytes == cold.delta_bytes("ft")
    assert s.delta_bytes < s.loaded_bytes
    snap = (s.loaded_bytes, s.delta_bytes, s.delta_composes)
    hit_b0 = s.cache_hit_bytes
    _, flat = cold.load("ft")                   # fully warm repeat
    assert (s.loaded_bytes, s.delta_bytes, s.delta_composes) == snap
    # the repeat is served at the composed tensors' logical size
    assert s.cache_hit_bytes - hit_b0 == sum(
        np.asarray(v).nbytes for v in flat.values())
    # warm-base, cold-variant: a second fine-tune pays exactly its own
    # delta file (base already cached) — no re-count of base bytes
    ft2 = dict(params, embed=params["embed"] * 1.02)
    cold.save("ft2", {"arch": "mlp"}, ft2, base_model="base")
    l0, d0 = s.loaded_bytes, s.delta_bytes
    cold2 = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"))
    cold2.load("base")
    l0, d0 = cold2.stats.loaded_bytes, cold2.stats.delta_bytes
    cold2.load("ft2")
    assert cold2.stats.loaded_bytes - l0 \
        == cold2.stats.delta_bytes - d0 == cold2.delta_bytes("ft2")


def test_resave_base_invalidates_composed_cache(tmp_path, params):
    """Re-saving a base must evict dependents' composed tensors — a
    stale composition would serve old base + new nothing."""
    ds = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"))
    ds.save("base", {"arch": "mlp"}, params)
    ft = dict(params, embed=params["embed"] + 1.0)
    ds.save("ft", {"arch": "mlp"}, ft, base_model="base")
    _, first = ds.load("ft")
    base2 = dict(params, embed=params["embed"] * 2.0)
    ds.save("base", {"arch": "mlp"}, base2)
    _, second = ds.load("ft")
    # the delta file still holds (old_ft - old_base); composed against
    # the NEW base it must reflect the rewrite, not the cached tensor
    np.testing.assert_allclose(
        second["embed"],
        base2["embed"] + (ft["embed"] - params["embed"]), atol=1e-6)
    assert not np.allclose(second["embed"], first["embed"])


def test_chained_finetune_composes_on_cold_cache(tmp_path, params):
    """ft2 -> ft1 -> base: references resolve through the catalog and
    deltas compose per hop, even with a cold layer cache (a raw delta
    must never be served as weights)."""
    def build(root):
        ds = DecoupledStore(root / "dec", Catalog(root / "cat"))
        ds.save("base", {"arch": "mlp"}, params)
        ft1 = dict(params, embed=params["embed"] + 0.5)   # delta layer
        ds.save("ft1", {"arch": "mlp"}, ft1, base_model="base")
        # ft2 changes a layer ft1 inherited (ref->ref) and inherits the
        # layer ft1 stored as a delta (ref->delta)
        ft2 = dict(ft1)
        ft2["layers"] = {"w1": params["layers"]["w1"] * 2.0,
                         "b1": params["layers"]["b1"]}
        ds.save("ft2", {"arch": "mlp"}, ft2, base_model="ft1")
        return ds, ft2
    ds, ft2 = build(tmp_path)
    # cold cache: a fresh store over the same files (new process)
    cold = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"),
                          cache_layers=False)
    for store in (ds, cold):
        _, loaded = store.load("ft2")
        np.testing.assert_allclose(loaded["embed"], ft2["embed"],
                                   atol=1e-6)     # ref -> ft1's delta
        np.testing.assert_allclose(loaded["layers/w1"],
                                   ft2["layers"]["w1"], atol=1e-6)
        np.testing.assert_array_equal(loaded["layers/b1"],
                                      ft2["layers"]["b1"])  # ref -> ref
    # row slices follow the chain too
    rows = cold.load_layer_rows("ft2", "embed", 2, 6)
    np.testing.assert_allclose(rows, ft2["embed"][2:6], atol=1e-6)
    # an inherited-from-ft1 trunk-less fingerprint: ft2's unchanged
    # 'embed' resolves to ft1's delta file, shared by both variants
    li2 = next(li for li in cold.catalog.get_layers("ft2")
               if li.layer_name == "embed")
    li1 = next(li for li in cold.catalog.get_layers("ft1")
               if li.layer_name == "embed")
    assert cold._resolve_layer_path("ft2", li2) \
        == cold._resolve_layer_path("ft1", li1)


def test_resave_changes_trunk_fingerprint(tmp_path, params):
    """Rewriting a model's tensors at the same paths must change every
    identity derived from them: the fingerprint keys share-cache
    entries and staged device weights, which would otherwise serve the
    old tensors after a re-save."""
    ds = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"))
    base = {"trunk/W": params["embed"], "head/w": params["layers"]["b1"]}
    ds.save("base", {"arch": "mlp"}, base)
    fp0 = ds.trunk_fingerprint("base")
    ft = dict(base, **{"trunk/W": base["trunk/W"] * 1.1})
    ds.save("ft", {"arch": "mlp"}, ft, base_model="base")
    ft_fp0 = ds.trunk_fingerprint("ft")
    assert ft_fp0 != fp0                    # trunk delta: own identity
    # re-save the fine-tune with a different trunk delta (same paths)
    ds.save("ft", {"arch": "mlp"},
            dict(base, **{"trunk/W": base["trunk/W"] * 1.2}),
            base_model="base")
    assert ds.trunk_fingerprint("ft") != ft_fp0
    # re-save the base: its fingerprint AND every dependent's change —
    # including the trunk-DELTA variant, whose composed tensor is
    # new_base + old_delta even though its delta file is untouched
    ft_fp1 = ds.trunk_fingerprint("ft")
    base2 = dict(base, **{"trunk/W": base["trunk/W"] + 1.0})
    ds.save("base", {"arch": "mlp"}, base2)
    fp2 = ds.trunk_fingerprint("base")
    assert fp2 != fp0
    assert ds.trunk_fingerprint("ft") != ft_fp1
    # a variant inheriting the rewritten trunk shares the NEW identity
    ds.save("ref", {"arch": "mlp"}, dict(base2), base_model="base")
    assert ds.trunk_fingerprint("ref") == fp2 != fp0


def test_plain_read_rejects_delta_payload(tmp_path, params):
    """Defense in depth: a FLAG_DELTA file catalogued as plain weights
    raises instead of serving the delta tensor."""
    ds = DecoupledStore(tmp_path / "dec", Catalog(tmp_path / "cat"))
    ds.save("base", {"arch": "mlp"}, params)
    li = next(li for li in ds.catalog.get_layers("base")
              if li.layer_name == "embed")
    path = tmp_path / "dec" / "base" / li.file
    path.write_bytes(mvec.encode(params["embed"], flags=mvec.FLAG_DELTA))
    with pytest.raises(ValueError, match="FLAG_DELTA"):
        ds.load("base")


def test_api_registry_retry_cache_quota():
    reg = ApiModelRegistry()
    calls = {"n": 0}

    def fn(x):
        calls["n"] += 1
        return np.asarray(x) * 2

    reg.register("gpt-sim", fn, latency_s=0.001, failure_rate=0.5,
                 max_retries=10, quota=50)
    rng = np.random.default_rng(0)
    out = reg.invoke("gpt-sim", np.ones(3), rng)
    np.testing.assert_array_equal(out, 2 * np.ones(3))
    # cache hit: second identical call doesn't re-invoke
    n = calls["n"]
    reg.invoke("gpt-sim", np.ones(3), rng)
    assert calls["n"] == n
    assert reg.stats["gpt-sim"]["cache_hits"] == 1

    reg.register("tiny", fn, latency_s=0.001, quota=2, cache=False)
    reg.invoke("tiny", np.ones(1), rng)
    reg.invoke("tiny", np.ones(2), rng)
    with pytest.raises(RuntimeError, match="quota"):
        reg.invoke("tiny", np.ones(4), rng)


# -- bounded layer-tensor cache (LRU over a byte capacity) -----------------

def test_layer_cache_lru_eviction_and_counters(tmp_path):
    """The cross-model tensor cache evicts least-recently-used entries
    once over its byte capacity, and StoreStats accounts for it."""
    rng = np.random.default_rng(0)
    layer = rng.standard_normal((64, 64)).astype(np.float32)  # 16 KiB
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat,
                        cache_capacity_bytes=3 * layer.nbytes + 1024)
    for i in range(5):
        ds.save(f"m{i}", {"arch": "mlp"}, {"trunk": {"W": layer + i}})
        ds.load(f"m{i}")
    assert ds.stats.cache_bytes <= ds.cache_capacity_bytes
    assert ds.stats.cache_evictions >= 2
    assert ds.stats.cache_evicted_bytes >= 2 * layer.nbytes
    # m0/m1 were evicted (LRU): reloading them is a disk read, not a hit
    h0 = ds.stats.cache_hits
    ds.load("m0")
    assert ds.stats.cache_hits == h0
    # the freshest entry is still resident
    ds.load("m4")
    assert ds.stats.cache_hits == h0 + 1


def test_layer_cache_lru_recency_refresh(tmp_path):
    """A cache hit freshens the entry: the hit survivor outlives an
    older untouched entry when capacity pressure evicts."""
    rng = np.random.default_rng(1)
    layer = rng.standard_normal((32, 32)).astype(np.float32)
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat,
                        cache_capacity_bytes=2 * layer.nbytes + 512)
    ds.save("a", {"arch": "m"}, {"w": layer})
    ds.save("b", {"arch": "m"}, {"w": layer + 1})
    ds.load("a")
    ds.load("b")
    ds.load("a")                     # freshen a: b is now the LRU victim
    ds.save("c", {"arch": "m"}, {"w": layer + 2})
    ds.load("c")                     # evicts b, not a
    h0 = ds.stats.cache_hits
    ds.load("a")
    assert ds.stats.cache_hits == h0 + 1
    ds.load("b")                     # miss: was evicted
    assert ds.stats.cache_hits == h0 + 1


def test_delta_fleet_cache_stays_under_cap(tmp_path):
    """K=16 fine-tune fleet: composing every variant against one base
    keeps the tensor cache bounded by the configured capacity."""
    rng = np.random.default_rng(2)
    K = 16
    base_trunk = rng.standard_normal((128, 64)).astype(np.float32)  # 32 KiB
    head = rng.standard_normal(64).astype(np.float32)
    cap = 6 * base_trunk.nbytes
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat, cache_capacity_bytes=cap)
    ds.save("base", {"arch": "m"}, {"trunk": {"W": base_trunk},
                                    "head": {"w": head}})
    for k in range(K):
        ds.save(f"ft{k}", {"arch": "m"},
                {"trunk": {"W": base_trunk + 0.01 * (k + 1)},
                 "head": {"w": head}}, base_model="base")
    for k in range(K):               # resolve the whole fleet
        ds.load(f"ft{k}")
    assert ds.stats.cache_bytes <= cap
    assert ds.stats.cache_evictions > 0
    # accounting identity: resident + evicted == everything ever admitted
    assert ds.stats.cache_bytes + ds.stats.cache_evicted_bytes > 0
    # correctness under eviction: a composed variant re-reads exactly
    _, flat = ds.load("ft3")
    np.testing.assert_allclose(flat["trunk/W"], base_trunk + 0.04,
                               rtol=0, atol=1e-6)


def test_cache_capacity_zero_disables_caching(tmp_path):
    rng = np.random.default_rng(3)
    layer = rng.standard_normal((16, 16)).astype(np.float32)
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat, cache_capacity_bytes=0)
    ds.save("m", {"arch": "m"}, {"w": layer})
    ds.load("m")
    ds.load("m")
    assert ds.stats.cache_hits == 0
    assert ds.stats.cache_bytes == 0


# -- trunk pinning + delta-aware chain eviction ----------------------------

def _trunk(rng, shift=0.0):
    return {"trunk": {"W": rng.standard_normal((64, 64))
                      .astype(np.float32) + shift}}


def test_pin_model_protects_trunk_from_eviction(tmp_path):
    """A pinned trunk survives LRU pressure that evicts its peers."""
    rng = np.random.default_rng(0)
    layer = rng.standard_normal((64, 64)).astype(np.float32)
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat,
                        cache_capacity_bytes=2 * layer.nbytes + 512)
    for i in range(4):
        ds.save(f"m{i}", {"arch": "m"}, {"trunk": {"W": layer + i}})
    ds.pin_model("m0")
    ds.load("m0")
    for i in range(1, 4):            # pressure: evicts m1/m2, never m0
        ds.load(f"m{i}")
    h0 = ds.stats.cache_hits
    ds.load("m0")
    assert ds.stats.cache_hits == h0 + 1     # pinned entry still resident
    ds.unpin_model("m0")
    assert not ds._pin_count
    for i in range(1, 4):            # unpinned: m0 now evictable
        ds.load(f"m{i}")
    h1 = ds.stats.cache_hits
    ds.load("m0")
    assert ds.stats.cache_hits == h1         # miss: evicted after unpin


def test_pin_model_refcounted_and_unknown_raises(tmp_path):
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat)
    with pytest.raises(KeyError):
        ds.pin_model("ghost")
    rng = np.random.default_rng(1)
    ds.save("m", {"arch": "m"}, _trunk(rng))
    ds.pin_model("m")
    ds.pin_model("m")
    ds.unpin_model("m")
    assert ds._pin_count["m"] == 1           # one reference still held
    ds.unpin_model("m")
    assert not ds._pin_count and not ds._pinned_paths
    ds.unpin_model("m")                      # extra release is a no-op


def test_pin_finetune_pins_base_files_it_reads(tmp_path):
    """Pinning a delta fine-tune pins the base layer files composition
    re-reads, so serving the variant keeps the whole read set warm."""
    rng = np.random.default_rng(2)
    base = _trunk(rng)
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat,
                        cache_capacity_bytes=3 * base["trunk"]["W"].nbytes)
    ds.save("base", {"arch": "m"}, base)
    ds.save("ft", {"arch": "m"},
            {"trunk": {"W": base["trunk"]["W"] + 1.0}}, base_model="base")
    ds.pin_model("ft")
    paths = ds._pin_paths["ft"]
    assert any("/base/" in p for p in paths)      # base file pinned too
    assert any("/ft/" in p for p in paths)        # delta file pinned
    ds.load("ft")                                 # caches base + composed
    for i in range(4):                            # heavy pressure
        ds.save(f"x{i}", {"arch": "m"}, _trunk(rng, float(i)))
        ds.load(f"x{i}")
    h0 = ds.stats.cache_hits
    ds.load("ft")
    assert ds.stats.cache_hits > h0               # still warm under pin


def test_delta_chain_evicts_together(tmp_path):
    """Evicting a base layer takes its dependents' composed tensors in
    the same step: a fine-tune fragment without its base must be
    re-composed anyway, so keeping it only splits chain residency."""
    rng = np.random.default_rng(3)
    base = _trunk(rng)
    nb = base["trunk"]["W"].nbytes
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat,
                        cache_capacity_bytes=3 * nb + 512)
    ds.save("base", {"arch": "m"}, base)
    ds.save("ft", {"arch": "m"},
            {"trunk": {"W": base["trunk"]["W"] + 1.0}}, base_model="base")
    ds.load("ft")                    # resident: base layer + composed ft
    assert len(ds._layer_cache) == 2
    ds.save("m2", {"arch": "m"}, _trunk(rng, 9.0))
    ds.save("m3", {"arch": "m"}, _trunk(rng, 7.0))
    ds.load("m2")
    ds.load("m3")                    # over cap: LRU victim is base's file
    assert all("/base/" not in k[0] and "/ft/" not in k[0]
               for k in ds._layer_cache)  # chain left together
    h0 = ds.stats.cache_hits
    ds.load("ft")                    # cold: both members re-read
    assert ds.stats.cache_hits == h0


def test_all_pinned_cache_stays_over_cap(tmp_path):
    """When every resident tensor is pinned the LRU has no victim: the
    cache rides over capacity rather than evicting an active trunk."""
    rng = np.random.default_rng(4)
    a, b = _trunk(rng), _trunk(rng, 1.0)
    nb = a["trunk"]["W"].nbytes
    cat = Catalog(tmp_path / "cat")
    ds = DecoupledStore(tmp_path / "dec", cat,
                        cache_capacity_bytes=nb + nb // 2)
    ds.save("a", {"arch": "m"}, a)
    ds.save("b", {"arch": "m"}, b)
    ds.pin_model("a")
    ds.pin_model("b")
    ds.load("a")
    ds.load("b")
    assert ds.stats.cache_bytes > ds.cache_capacity_bytes
    h0 = ds.stats.cache_hits
    ds.load("a")
    ds.load("b")
    assert ds.stats.cache_hits == h0 + 2
