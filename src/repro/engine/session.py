"""MorphingSession: the task-centric query engine facade.

One object owns the whole paper pipeline: registered tables, CREATE TASK
specs, model resolution through the transferability-subspace selector
*and* the storage catalog (the chosen model's weights round-trip through
the BLOB store rather than living in Python memory), a shared
pre-embedding cache, and compiled plan execution on the chunked pipeline
runtime. Every query returns its rows plus a :class:`QueryReport` that
merges `ExecStats` / `ShareStats` / `BatcherStats` into one telemetry
view.

    sess = MorphingSession(selector=sel, zoo=zoo)
    sess.register_table("reviews", {...})
    sess.sql("CREATE TASK sentiment (INPUT=Series, OUTPUT IN ('P','N'), "
             "TYPE='Classification')")
    sess.resolve_task("sentiment", X_sample, y_sample)
    res = sess.sql("SELECT gender, AVG(sentiment(emb)) FROM reviews "
                   "WHERE len > 20 GROUP BY gender")
    res.rows, res.report.share_hit_rate, res.report.device_of
"""
from __future__ import annotations

import tempfile
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.task import TaskRegistry, TaskSpec
from repro.core.zoo import ZooModel
from repro.engine.plan import (CompileContext, LogicalPlan, compile_plan,
                               optimize)
from repro.engine.sql import CreateTaskStmt, QueryStmt, parse
from repro.pipeline.backend import ExecutionBackend, make_backends
from repro.pipeline.batcher import BatcherStats
from repro.pipeline.cost import (HardwareProfile, OpProfile, calibrate,
                                 profile_for_model)
from repro.pipeline.operators import (Batch, aggregate, batch_len,
                                      groupby_aggs)
from repro.pipeline.scheduler import PipelineExecutor
from repro.pipeline.share import VectorShareCache
from repro.storage.catalog import Catalog
from repro.storage.stores import BlobStore


@dataclass
class ResolvedModel:
    """A task's model, loaded back through the BLOB store."""
    task: str
    model_id: str
    version: str
    features: Callable[[np.ndarray], np.ndarray]   # expensive extractor
    head: Callable[[np.ndarray], np.ndarray]       # cheap score head
    profile: OpProfile
    zoo_model: Optional[ZooModel] = None           # raw weights (staging)
    head_kind: str = "mean"          # 'mean' lets device backends fuse the
    #                                # head; anything else runs head on host


@dataclass
class QueryReport:
    """Per-query telemetry: executor + share cache + batcher, merged."""
    sql: str = ""
    plan: str = ""
    resolution: Dict[str, str] = field(default_factory=dict)
    wall_seconds: float = 0.0
    rows_in: int = 0
    rows_out: int = 0
    op_seconds: Dict[str, float] = field(default_factory=dict)
    device_of: Dict[str, str] = field(default_factory=dict)
    backend_of: Dict[str, str] = field(default_factory=dict)
    batch_size_of: Dict[str, int] = field(default_factory=dict)
    compile_count: int = 0          # jit compiles triggered by this query
    share_hits: int = 0
    share_misses: int = 0
    batch_batches: int = 0
    batch_rows: int = 0
    batch_infer_seconds: float = 0.0

    @property
    def share_hit_rate(self) -> float:
        t = self.share_hits + self.share_misses
        return self.share_hits / t if t else 0.0


@dataclass
class QueryResult:
    rows: Batch
    report: QueryReport


class MorphingSession:
    """Register tables -> create tasks -> resolve models -> run SQL."""

    def __init__(self, selector=None, zoo: Optional[List[ZooModel]] = None,
                 root: Optional[Path] = None, *,
                 devices: Tuple[str, ...] = ("host", "tpu"),
                 backend: str = "auto", enable_share: bool = True,
                 chunk_rows: int = 256, max_inflight: int = 3,
                 workers: int = 4, optimize_plans: bool = True,
                 share_capacity_bytes: int = 1 << 30):
        self.root = Path(root) if root else Path(
            tempfile.mkdtemp(prefix="morphingdb-"))
        self.catalog = Catalog(self.root / "catalog")
        self.blobs = BlobStore(self.root / "models", self.catalog)
        self.share = VectorShareCache(self.root / "share",
                                      capacity_bytes=share_capacity_bytes)
        self.registry = TaskRegistry(selector=selector, zoo=zoo)
        self.zoo = zoo or []
        self.devices = devices
        self.backends: Dict[str, ExecutionBackend] = make_backends(
            backend, devices=devices)
        self.enable_share = enable_share
        self.hw: Optional[Dict[str, HardwareProfile]] = None
        self.chunk_rows = chunk_rows
        self.max_inflight = max_inflight
        self.workers = workers
        self.optimize_plans = optimize_plans
        self.tables: Dict[str, Batch] = {}
        self.models: Dict[str, ResolvedModel] = {}

    # -- catalog-facing API ----------------------------------------------
    def register_table(self, name: str, table: Batch) -> None:
        self.tables[name] = table

    def create_task(self, spec: TaskSpec) -> None:
        self.registry.create_task(spec)

    def resolve_task(self, name: str, X: np.ndarray, y: np.ndarray,
                     force: bool = False) -> ResolvedModel:
        """Select a model for the task from sample data, persist it via
        the BLOB store + catalog, and load the weights back from storage
        (the served model is the stored one, not the in-memory zoo
        object)."""
        if not force and name in self.models:
            return self.models[name]
        idx = self.registry.resolve(name, X, y, force=force)
        zm = self.zoo[idx]
        spec = self.registry.get(name)
        params: Dict[str, np.ndarray] = {"W": zm.W}
        if zm.centers is not None:
            params["centers"] = zm.centers
        arch = {"name": zm.name, "mode": zm.mode, "sigma": float(zm.sigma),
                "source_family": zm.source_family}
        self.blobs.save(zm.name, arch, params,
                        task_types=[spec.kind], modality=spec.input_type)
        arch2, flat = self.blobs.load(zm.name)
        stored = ZooModel(name=arch2["name"],
                          source_family=arch2["source_family"],
                          W=np.asarray(flat["W"]), mode=arch2["mode"],
                          centers=(np.asarray(flat["centers"])
                                   if "centers" in flat else None),
                          sigma=arch2["sigma"])
        dim = stored.W.shape[0]
        rm = ResolvedModel(
            task=name, model_id=zm.name, version=f"{zm.name}@1.0",
            features=stored.features,
            head=lambda F: np.asarray(F, np.float32).mean(axis=1),
            profile=profile_for_model(n_params=float(stored.W.size),
                                      bytes_per_row=dim * 4),
            zoo_model=stored)
        # one-time weight staging: each distinct backend moves the stored
        # weights to its device now, not per chunk (TransCost, Eq. 7)
        for b in {id(b): b for b in self.backends.values()}.values():
            b.stage(rm.version, stored)
        self.models[name] = rm
        return rm

    def calibrate(self, rows=(256, 2048),
                  repeats: int = 3) -> Dict[str, HardwareProfile]:
        """Measure per-row throughput + launch latency from each live
        backend (cost.calibrate) and use the measured profiles for all
        subsequent Eq. 10/11 planning decisions. A backend shared by
        several device names is measured once and the profile reused."""
        import dataclasses
        measured: Dict[int, HardwareProfile] = {}
        self.hw = {}
        for dev, b in self.backends.items():
            if id(b) not in measured:
                measured[id(b)] = calibrate(b, dev, rows=rows,
                                            repeats=repeats)
            self.hw[dev] = dataclasses.replace(measured[id(b)], name=dev)
        return self.hw

    # -- query execution -------------------------------------------------
    def compile(self, plan: LogicalPlan,
                nrows_hint: Optional[int] = None) -> LogicalPlan:
        """Run the optimizer passes against this session's resolutions."""
        if not self.optimize_plans:
            return plan
        profiles = {t: m.profile for t, m in self.models.items()}
        hint = nrows_hint or batch_len(self.tables.get(plan.table, {})) or 1024
        return optimize(plan, profiles, nrows_hint=hint,
                        devices=self.devices, hw=self.hw)

    def execute_plan(self, plan: LogicalPlan, sql_text: str = "",
                     chunk_rows: Optional[int] = None,
                     max_inflight: Optional[int] = None) -> QueryResult:
        table = self.tables[plan.table]
        for node in plan.nodes:
            if node.op == "predict" and node.args["task"] not in self.models:
                raise RuntimeError(
                    f"task {node.args['task']!r} not resolved; call "
                    "resolve_task(name, X_sample, y_sample) first")
        plan = self.compile(plan, nrows_hint=batch_len(table))
        ctx = CompileContext(
            models=self.models,
            share=self.share if self.enable_share else None,
            share_version_of={t: m.version for t, m in self.models.items()})
        dag, source_id, sink_id, agg_node = compile_plan(plan, ctx)
        h0, m0 = self.share.stats.hits, self.share.stats.misses
        distinct_backends = {id(b): b for b in self.backends.values()}
        c0 = sum(getattr(b, "compile_count", 0)
                 for b in distinct_backends.values())
        ex = PipelineExecutor(dag, workers=self.workers,
                              backends=self.backends)
        if sink_id == source_id:                    # pure scan
            rows = table
        else:
            rows = ex.execute_chunked(
                source_id, table, chunk_rows=chunk_rows or self.chunk_rows,
                sink_id=sink_id, max_inflight=max_inflight
                or self.max_inflight)
        # final aggregation over the concatenated stream (exact groups)
        if agg_node is not None:
            g = agg_node.args.get("group_by")
            specs = agg_node.args["specs"]
            rows = (groupby_aggs(rows, g, specs) if g
                    else aggregate(rows, specs))
        report = QueryReport(
            sql=sql_text, plan=plan.describe(),
            resolution={t: m.model_id for t, m in self.models.items()
                        if any(n.op in ("predict", "embed")
                               and n.args.get("task") == t
                               for n in plan.nodes)},
            wall_seconds=ex.stats.wall_seconds,
            rows_in=batch_len(table), rows_out=batch_len(rows),
            op_seconds=dict(ex.stats.op_seconds),
            device_of=dict(ex.stats.device_of),
            backend_of=dict(ex.stats.backend_of),
            compile_count=sum(getattr(b, "compile_count", 0)
                              for b in distinct_backends.values()) - c0,
            batch_size_of={n.args["task"]: int(n.args["batch_size"])
                           for n in plan.nodes
                           if n.op == "embed" and "batch_size" in n.args},
            share_hits=self.share.stats.hits - h0,
            share_misses=self.share.stats.misses - m0)
        for st in ctx.batcher_stats.values():
            report.batch_batches += st.batches
            report.batch_rows += st.rows
            report.batch_infer_seconds += st.infer_seconds
        return QueryResult(rows=rows, report=report)

    def sql(self, statement: str, sample: Optional[Tuple] = None):
        """Execute one SQL statement. ``sample=(X, y)`` supplies the
        resolution sample for any not-yet-resolved task references."""
        stmt = parse(statement)
        if isinstance(stmt, CreateTaskStmt):
            self.create_task(stmt.spec)
            return f"TASK {stmt.spec.name} CREATED"
        assert isinstance(stmt, QueryStmt)
        for t in stmt.tasks:
            if t not in self.registry._tasks:
                raise ValueError(f"unknown task {t}; CREATE TASK first")
            if t not in self.models:
                if sample is None:
                    raise RuntimeError(
                        f"task {t} unresolved and no sample given")
                self.resolve_task(t, *sample)
        return self.execute_plan(stmt.plan, sql_text=statement)
