"""MorphingSession: the task-centric query engine facade.

One object owns the whole paper pipeline: registered tables, CREATE TASK
specs, model resolution through the transferability-subspace selector
*and* the storage catalog (the chosen model's weights round-trip through
the BLOB store rather than living in Python memory), a shared
pre-embedding cache, and compiled plan execution on the chunked pipeline
runtime. Every query returns its rows plus a :class:`QueryReport` that
merges `ExecStats` / `ShareStats` / `BatcherStats` into one telemetry
view.

    sess = MorphingSession(selector=sel, zoo=zoo)
    sess.register_table("reviews", {...})
    sess.sql("CREATE TASK sentiment (INPUT=Series, OUTPUT IN ('P','N'), "
             "TYPE='Classification')")
    sess.resolve_task("sentiment", X_sample, y_sample)
    res = sess.sql("SELECT gender, AVG(sentiment(emb)) FROM reviews "
                   "WHERE len > 20 GROUP BY gender")
    res.rows, res.report.share_hit_rate, res.report.device_of
"""
from __future__ import annotations

import dataclasses
import tempfile
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.task import TaskRegistry, TaskSpec
from repro.core.zoo import ZooModel, adapt_input_width
from repro.engine.config import UNSET, EngineConfig
from repro.engine.plan import (CompileContext, LogicalPlan, PlanNode,
                               compile_plan, optimize)
from repro.engine.sql import CreateTaskStmt, QueryStmt, encode_text, parse
from repro.pipeline.backend import (ExecutionBackend, JaxBackend,
                                    MeshJaxBackend, NumpyBackend,
                                    make_backends)
from repro.pipeline.batcher import BatcherStats
from repro.pipeline.cost import (HardwareProfile, OpProfile, calibrate,
                                 delta_staged_profile, load_profile_memo,
                                 profile_for_model, profile_memo_fingerprint,
                                 store_profile_memo)
from repro.pipeline.operators import (Batch, aggregate, batch_len,
                                      groupby_aggs)
from repro.pipeline.scheduler import PipelineExecutor
from repro.pipeline.share import (AnnConfig, AnnShareTier, CacheChain,
                                  VectorShareCache)
from repro.storage.catalog import Catalog
from repro.storage.stores import BlobStore, DecoupledStore


@dataclass
class ResolvedModel:
    """A task's model, loaded back through a model store (BLOB or
    decoupled layer tables with partial loading / fine-tune deltas)."""
    task: str
    model_id: str
    version: str
    features: Callable[[np.ndarray], np.ndarray]   # expensive extractor
    head: Callable[[np.ndarray], np.ndarray]       # cheap score head
    profile: OpProfile
    zoo_model: Optional[ZooModel] = None           # raw weights (staging)
    head_kind: str = "mean"          # 'mean' lets device backends fuse the
    #                                # head; anything else runs head on host
    store: str = "blob"              # which store served the weights
    load_mode: str = "full"          # full | partial | head
    loaded_bytes: int = 0            # disk bytes this resolution read
    stored_bytes: int = 0            # bytes the store holds for the model
    in_dim: int = 0                  # input width the trunk consumes
    head_dim: int = 0                # embedding width the head consumes
    trunk_fp: str = ""               # trunk identity: tasks sharing it can
    #                                # share one serving embed lane
    base_model_id: str = ""          # fine-tune lineage ("" = not a delta)
    base_fp: str = ""                # the base model's trunk fingerprint;
    #                                # == trunk_fp when the trunk is fully
    #                                # inherited (shared embed lane)
    delta_bytes: int = 0             # disk bytes of this model's delta
    #                                # layers (marginal cost over the base)

    @property
    def is_delta(self) -> bool:
        """True for a fine-tune variant served by delta composition."""
        return bool(self.base_model_id)


class _LazyZooModel:
    """Defers a trunk load until the first attribute access — a head-only
    resolution never pays for trunk weights unless an embed actually
    needs them (share-cache hits keep the trunk on disk)."""

    def __init__(self, loader: Callable[[], ZooModel]):
        self._loader = loader
        self._zm: Optional[ZooModel] = None
        self._force_lock = threading.Lock()

    @property
    def materialized(self) -> bool:
        return self._zm is not None

    def _force(self) -> ZooModel:
        with self._force_lock:
            if self._zm is None:
                self._zm = self._loader()
            return self._zm

    def __getattr__(self, name: str) -> Any:
        return getattr(self._force(), name)


@dataclass
class QueryReport:
    """Per-query telemetry: executor + share cache + batcher, merged."""
    sql: str = ""
    plan: str = ""
    resolution: Dict[str, str] = field(default_factory=dict)
    wall_seconds: float = 0.0
    rows_in: int = 0
    rows_out: int = 0
    op_seconds: Dict[str, float] = field(default_factory=dict)
    device_of: Dict[str, str] = field(default_factory=dict)
    backend_of: Dict[str, str] = field(default_factory=dict)
    batch_size_of: Dict[str, int] = field(default_factory=dict)
    compile_count: int = 0          # jit compiles triggered by this query
    share_hits: int = 0
    share_misses: int = 0
    approx_hits: int = 0            # rows served by the ANN tier (within
    #                               # the calibrated distance of a cached
    #                               # row, not byte-identical)
    false_accepts: int = 0          # audited approx hits whose exact
    #                               # recomputation exceeded the bound
    sim_trunk_rows: int = 0         # rows the similarity path had to run
    #                               # through the trunk (0 = warm cache)
    index_scan: bool = False        # ORDER BY SIMILARITY lowered to the
    #                               # ANN index-scan fast path
    batch_batches: int = 0
    batch_rows: int = 0
    batch_infer_seconds: float = 0.0
    loaded_bytes: int = 0           # model bytes read from disk (resolution)
    stored_bytes: int = 0           # model bytes the store holds
    delta_bytes: int = 0            # fine-tune delta bytes among the
    #                               # resolutions this query touched
    # storage-compression gauges (session-lifetime DecoupledStore stats,
    # docs/architecture.md "Compressed deltas & tensor-page dedup"):
    dedup_pages: int = 0            # page writes elided by content dedup
    dedup_bytes_saved: int = 0      # bytes those elided writes would cost
    compressed_delta_bytes: int = 0  # on-disk bytes of compressed deltas
    quant_error_bound: float = 0.0  # max declared quant bound in play

    @property
    def share_hit_rate(self) -> float:
        t = self.share_hits + self.share_misses
        return self.share_hits / t if t else 0.0


@dataclass
class QueryResult:
    rows: Batch
    report: QueryReport


# Heads must be picklable (ResolvedModel crosses the dispatch tier's
# process boundary), so the standard readouts are module-level callables
# rather than closures.
class _MeanHead:
    """Mean readout over feature columns (the zoo's default head)."""

    def __call__(self, F):
        return np.asarray(F, np.float32).mean(axis=1)


class _LinearHead:
    """Stored linear readout ``F @ w`` (decoupled-store heads)."""

    def __init__(self, w):
        self.w = np.asarray(w, np.float32)

    def __call__(self, F):
        return np.asarray(F, np.float32) @ self.w


# Process-wide fast-calibration cache. Calibration measures the *machine*
# (per-row throughput, launch latency, link BW of a backend class), not a
# session, so one measurement per backend flavour serves every session in
# the process — tier-1 tests constructing dozens of sessions pay once.
# ``memo_path`` (EngineConfig.calib_memo_path) extends the memo across
# processes: dispatch workers and repeated CI legs read the first
# process's probe from disk instead of re-measuring.
_FAST_CALIB_CACHE: Dict[Tuple[str, Any], HardwareProfile] = {}
_FAST_CALIB_LOCK = threading.Lock()
_FAST_CALIB_ROWS = (64, 512)


def _fast_profile(backend: ExecutionBackend, device: str,
                  memo_path: Optional[str] = None
                  ) -> Optional[HardwareProfile]:
    """Measured HardwareProfile for a backend's *class* (memoized). A
    fresh probe instance of the same flavour is calibrated so the live
    backend's stage/compile counters stay untouched."""
    if isinstance(backend, MeshJaxBackend):
        # a mesh profile is per-(flavour, mesh size): the aggregate rate
        # the serving lanes size against depends on how many devices the
        # mesh spans. The probe shares the live mesh — building a second
        # mesh over the same devices would be pure overhead.
        key = ("jax-mesh", backend.interpret, backend.device_count)
        probe_fn = lambda: MeshJaxBackend(  # noqa: E731
            mesh=backend.mesh, interpret=backend.interpret)
    elif isinstance(backend, JaxBackend):
        key = ("jax", backend.interpret)
        probe_fn = lambda: JaxBackend(interpret=backend.interpret)  # noqa: E731
    elif isinstance(backend, NumpyBackend):
        key = ("numpy", None)
        probe_fn = NumpyBackend
    else:
        return None                  # unknown backend: keep spec defaults
    with _FAST_CALIB_LOCK:
        prof = _FAST_CALIB_CACHE.get(key)
        if prof is None and memo_path:
            # disk memo: the fingerprint embeds jax version/device count
            # (cpu count for host backends), so stale entries just miss
            prof = load_profile_memo(memo_path).get(
                profile_memo_fingerprint(key))
            if prof is not None:
                _FAST_CALIB_CACHE[key] = prof
        if prof is None:
            prof = calibrate(probe_fn(), device, rows=_FAST_CALIB_ROWS,
                             repeats=1)
            _FAST_CALIB_CACHE[key] = prof
            if memo_path:
                try:
                    store_profile_memo(
                        memo_path, profile_memo_fingerprint(key), prof)
                except OSError:      # memo is best-effort, never fatal
                    pass
    return dataclasses.replace(prof, name=device)


class MorphingSession:
    """Register tables -> create tasks -> resolve models -> run SQL."""

    def __init__(self, selector=None, zoo: Optional[List[ZooModel]] = None,
                 root: Optional[Path] = None, *,
                 config: Optional[EngineConfig] = None,
                 devices: Tuple[str, ...] = UNSET,
                 device_count: int = UNSET,
                 backend: str = UNSET, enable_share: bool = UNSET,
                 chunk_rows: int = UNSET, max_inflight: int = UNSET,
                 workers: int = UNSET, optimize_plans: bool = UNSET,
                 share_capacity_bytes: int = UNSET,
                 model_store: str = UNSET,
                 auto_calibrate: bool = UNSET,
                 cache_tiers: Tuple[str, ...] = UNSET,
                 ann: Optional[AnnConfig] = UNSET):
        # every legacy kwarg is a deprecation shim overlaying the shared
        # EngineConfig; passing only kwargs builds a config from them
        cfg = (config or EngineConfig()).overlaid({
            "devices": devices, "device_count": device_count,
            "backend": backend, "enable_share": enable_share,
            "chunk_rows": chunk_rows, "max_inflight": max_inflight,
            "workers": workers, "optimize_plans": optimize_plans,
            "share_capacity_bytes": share_capacity_bytes,
            "model_store": model_store, "auto_calibrate": auto_calibrate,
            "cache_tiers": cache_tiers, "ann": ann}).validate()
        self.config = cfg
        self.root = Path(root) if root else Path(
            tempfile.mkdtemp(prefix="morphingdb-"))
        self.catalog = Catalog(self.root / "catalog")
        self.blobs = BlobStore(self.root / "models", self.catalog)
        self.dstore = DecoupledStore(
            self.root / "layers", self.catalog,
            compress_deltas=cfg.compress_deltas,
            quant_dtype=cfg.quant_dtype,
            sparse_eps=cfg.sparse_eps,
            dedup_pages=cfg.dedup_pages,
            page_bytes=cfg.page_bytes)
        self.model_store = cfg.model_store
        self.share = VectorShareCache(
            self.root / "share", capacity_bytes=cfg.share_capacity_bytes)
        # the share surface is a CacheTier chain: the exact fingerprint
        # tier always leads; the opt-in ANN tier serves residual misses
        # with calibrated nearest-neighbor reuse
        tiers = [self.share]
        self.ann: Optional[AnnShareTier] = None
        if "ann" in cfg.cache_tiers:
            self.ann = AnnShareTier(cfg.ann or AnnConfig(),
                                    capacity_bytes=cfg.share_capacity_bytes)
            tiers.append(self.ann)
        self.cache_chain = CacheChain(tiers)
        self.registry = TaskRegistry(selector=selector, zoo=zoo)
        self.zoo = zoo or []
        self.devices = cfg.devices
        # the pool is dict-compatible with the old registry; with
        # device_count > 1 its jax annotation spans a mesh (clamped to
        # the devices jax actually exposes — a clamp to 1 falls back to
        # the parity-exact single-device backends)
        self.backends = make_backends(
            cfg.backend, devices=cfg.devices,
            device_count=cfg.device_count)
        self.device_count = getattr(self.backends, "device_count", 1)
        self.enable_share = cfg.enable_share
        self.hw: Optional[Dict[str, HardwareProfile]] = None
        self.chunk_rows = cfg.chunk_rows
        self.max_inflight = cfg.max_inflight
        self.workers = cfg.workers
        self.optimize_plans = cfg.optimize_plans
        self.tables: Dict[str, Batch] = {}
        self.models: Dict[str, ResolvedModel] = {}
        if cfg.auto_calibrate:
            self._auto_calibrate()

    def _auto_calibrate(self) -> None:
        """Fast calibration at construction (ROADMAP open item): use the
        process-wide memoized profiles so Eq. 10/11 planning starts from
        measured numbers without each session paying a measurement. Full
        per-session measurement stays available via :meth:`calibrate`."""
        try:
            hw = {}
            for dev, b in self.backends.items():
                prof = _fast_profile(b, dev,
                                     memo_path=self.config.calib_memo_path)
                if prof is not None:
                    hw[dev] = prof
            self.hw = hw or None
        except Exception:            # calibration must never block startup
            self.hw = None

    # -- catalog-facing API ----------------------------------------------
    def register_table(self, name: str, table: Batch) -> None:
        self.tables[name] = table

    def create_task(self, spec: TaskSpec) -> None:
        self.registry.create_task(spec)

    def resolve_task(self, name: str, X: np.ndarray, y: np.ndarray,
                     force: bool = False,
                     mode: Optional[str] = None,
                     model_id: Optional[str] = None) -> ResolvedModel:
        """Select a model for the task from sample data, persist it via
        the session's model store + catalog, and load the weights back
        from storage (the served model is the stored one, not the
        in-memory zoo object).

        ``model_id`` pins the task to an explicitly named model already
        in the decoupled catalog — e.g. a fine-tune registered with
        :meth:`register_finetune` — bypassing the selector. Fine-tune
        variants resolve by *delta composition*: unchanged layers come
        from the base model's files (warm via the cross-model layer
        cache, so a fleet of K fine-tunes loads the base trunk once),
        and only their delta bytes hit the disk.

        ``mode`` controls the decoupled store's load shape (ignored for
        the BLOB store, which is all-or-nothing):

        - ``'full'``    — every layer eagerly (the default);
        - ``'partial'`` — the head eagerly plus a *width-sliced* trunk:
          only the first ``X.shape[1]`` rows of the projection leave the
          disk (``load_layer_rows``), since width-adapted inputs zero the
          rest; radial trunks load centers and skip the projection.
          Explicit opt-in: the slice is keyed to the resolution sample's
          width, so the sample must match the serving schema (queries
          over *wider* columns would be truncated to the slice). Delta
          trunks slice base and delta rows consistently;
        - ``'head'``    — only the head eagerly; the trunk stays on disk
          until an embed actually needs it (share-cache hits never pay).
        """
        if not force and name in self.models:
            cached = self.models[name]
            if (mode is not None and cached.store == "decoupled"
                    and cached.load_mode != mode):
                raise ValueError(
                    f"task {name!r} already resolved with load mode "
                    f"{cached.load_mode!r}; pass force=True to "
                    f"re-resolve as {mode!r}")
            if model_id is not None and cached.model_id != model_id:
                raise ValueError(
                    f"task {name!r} already resolved to "
                    f"{cached.model_id!r}; pass force=True to re-bind "
                    f"to {model_id!r}")
            return cached
        if model_id is not None:
            if self.model_store != "decoupled":
                raise ValueError(
                    "model_id resolution requires model_store='decoupled'")
            self.registry.get(name)          # the task must exist
            rm = self._resolve_from_store(name, model_id, X,
                                          mode=mode or "full")
        else:
            idx = self.registry.resolve(name, X, y, force=force)
            zm = self.zoo[idx]
            spec = self.registry.get(name)
            if self.model_store == "decoupled":
                rm = self._resolve_decoupled(name, zm, spec, X,
                                             mode=mode or "full")
            else:
                rm = self._resolve_blob(name, zm, spec)
        self.models[name] = rm
        return rm

    def register_finetune(self, model_id: str, base_model_id: str,
                          updates: Dict[str, np.ndarray], *,
                          task_types: Optional[List[str]] = None,
                          modality: Optional[str] = None) -> Path:
        """Store a fine-tuned variant of a decoupled base model at its
        marginal cost: unchanged layers become references into the base
        (zero new bytes), changed layers land as per-layer *delta* files
        composed back at load time (``DecoupledStore.save(base_model=)``).

        ``updates`` maps layer names (e.g. ``"head/w"``, ``"trunk/W"``)
        to replacement tensors of the base layer's shape; every other
        layer is inherited. A head-only fine-tune keeps the base trunk
        fingerprint, so serving routes it into the base trunk's embed
        lane. Resolve a task against the variant with
        ``resolve_task(name, X, y, model_id=model_id)``.
        """
        if self.model_store != "decoupled":
            raise ValueError(
                "fine-tune deltas require model_store='decoupled'")
        info = self.catalog.get_model(base_model_id)  # KeyError if unsaved
        if info.storage != "decoupled":
            raise ValueError(
                f"base {base_model_id!r} is stored as {info.storage!r}, "
                "not decoupled layer tables")
        arch, flat = self.dstore.load(base_model_id)
        unknown = sorted(set(updates) - set(flat))
        if unknown:
            raise KeyError(
                f"updates for layers the base lacks: {unknown}")
        for lname, arr in updates.items():
            arr = np.asarray(arr, dtype=flat[lname].dtype)
            if arr.shape != flat[lname].shape:
                raise ValueError(
                    f"layer {lname!r} shape {arr.shape} != base shape "
                    f"{flat[lname].shape}")
            flat[lname] = arr
        return self.dstore.save(
            model_id, arch, flat, base_model=base_model_id,
            task_types=task_types or list(info.task_types),
            modality=modality or info.modality)

    def _stage_all(self, rm: ResolvedModel, stored: ZooModel) -> None:
        # one-time weight staging under the *trunk identity*: each
        # distinct backend moves the weights to its device now, not per
        # chunk (TransCost, Eq. 7), and fine-tunes whose trunk is fully
        # inherited stage nothing new — the base trunk is already
        # resident under the shared fingerprint (delta-aware Eq. 7)
        for b in {id(b): b for b in self.backends.values()}.values():
            b.stage(rm.trunk_fp or rm.version, stored)

    def _resolve_blob(self, name: str, zm: ZooModel,
                      spec: TaskSpec) -> ResolvedModel:
        params: Dict[str, np.ndarray] = {"W": zm.W}
        if zm.centers is not None:
            params["centers"] = zm.centers
        arch = {"name": zm.name, "mode": zm.mode, "sigma": float(zm.sigma),
                "source_family": zm.source_family}
        path = self.blobs.save(zm.name, arch, params,
                               task_types=[spec.kind],
                               modality=spec.input_type)
        arch2, flat = self.blobs.load(zm.name)
        stored = ZooModel(name=arch2["name"],
                          source_family=arch2["source_family"],
                          W=np.asarray(flat["W"]), mode=arch2["mode"],
                          centers=(np.asarray(flat["centers"])
                                   if "centers" in flat else None),
                          sigma=arch2["sigma"])
        dim = stored.W.shape[0]
        nbytes = path.stat().st_size
        rm = ResolvedModel(
            task=name, model_id=zm.name, version=f"{zm.name}@1.0",
            features=stored.features,
            head=_MeanHead(),
            profile=profile_for_model(n_params=float(stored.W.size),
                                      bytes_per_row=dim * 4),
            zoo_model=stored, store="blob", load_mode="full",
            loaded_bytes=nbytes, stored_bytes=nbytes,
            in_dim=dim, head_dim=self._trunk_out_dim(stored),
            # BLOB trunks have no layer identity: the version string is
            # the trunk fingerprint (same stored model -> shared lane)
            trunk_fp=f"{zm.name}@1.0")
        self._stage_all(rm, stored)
        return rm

    # -- decoupled store: partial-load resolution -------------------------
    @staticmethod
    def _trunk_out_dim(zm: ZooModel) -> int:
        if zm.mode == "radial":
            return int(zm.centers.shape[0])
        if zm.mode == "proj1d":
            return 2 * int(zm.W.shape[1])
        return int(zm.W.shape[1])

    def _load_trunk(self, model_id: str, arch: dict,
                    width_limit: Optional[int] = None) -> ZooModel:
        """Materialize a trunk from layer tables. ``width_limit`` slices
        the projection to the rows the input width actually touches."""
        in_dim = int(arch["in_dim"])
        if arch["mode"] == "radial":
            # radial features are distances to centers; the stored
            # projection (identity) never runs, so it never loads
            _, flat = self.dstore.load(
                model_id, layer_filter=lambda n: n == "trunk/centers")
            return ZooModel(name=arch["name"],
                            source_family=arch["source_family"],
                            W=np.eye(in_dim, dtype=np.float32),
                            mode="radial",
                            centers=np.asarray(flat["trunk/centers"]),
                            sigma=arch["sigma"])
        if width_limit is not None and width_limit < in_dim:
            W = np.asarray(self.dstore.load_layer_rows(
                model_id, "trunk/W", 0, width_limit))
        else:
            _, flat = self.dstore.load(
                model_id, layer_filter=lambda n: n == "trunk/W")
            W = np.asarray(flat["trunk/W"])
        return ZooModel(name=arch["name"],
                        source_family=arch["source_family"],
                        W=W, mode=arch["mode"], sigma=arch["sigma"])

    def _resolve_decoupled(self, name: str, zm: ZooModel, spec: TaskSpec,
                           X: np.ndarray, mode: str) -> ResolvedModel:
        if mode not in ("full", "partial", "head"):
            raise ValueError(f"unknown load mode {mode!r}")
        out_dim = self._trunk_out_dim(zm)
        arch = {"name": zm.name, "mode": zm.mode, "sigma": float(zm.sigma),
                "source_family": zm.source_family,
                "in_dim": int(zm.W.shape[0]), "out_dim": out_dim}
        try:
            already = (self.catalog.get_model(zm.name).storage
                       == "decoupled")
        except KeyError:
            already = False
        if not already:
            # layer tables: trunk/* (expensive extractor weights) +
            # head/* (the score head — a mean readout stored explicitly
            # so a head-only load has a real layer to fetch)
            params: Dict[str, np.ndarray] = {
                "trunk/W": zm.W,
                "head/w": np.full(out_dim, 1.0 / out_dim, np.float32)}
            if zm.centers is not None:
                params["trunk/centers"] = zm.centers
            self.dstore.save(zm.name, arch, params,
                             task_types=[spec.kind],
                             modality=spec.input_type)
        return self._resolve_from_store(name, zm.name, X, mode)

    def _resolve_from_store(self, name: str, model_id: str,
                            X: np.ndarray, mode: str) -> ResolvedModel:
        """Resolve a task directly against a model in the decoupled
        store. For fine-tune variants (catalog ``base_model`` lineage)
        every read composes ``base + delta``: a warm base trunk costs
        cache bytes, not disk bytes, and the Eq. 7 staging profile
        charges only the delta when the trunk is already resident."""
        if mode not in ("full", "partial", "head"):
            raise ValueError(f"unknown load mode {mode!r}")
        try:
            info = self.catalog.get_model(model_id)
        except KeyError:
            raise KeyError(
                f"model {model_id!r} not in the catalog; resolve its "
                "base task first or register_finetune() it") from None
        if info.storage != "decoupled":
            raise ValueError(
                f"model {model_id!r} is stored as {info.storage!r}; "
                "direct resolution needs decoupled layer tables")
        b0 = self.dstore.stats.loaded_bytes
        arch2, head_flat = self.dstore.load(
            model_id, layer_filter=lambda n: n.startswith("head/"))
        w_head = np.asarray(head_flat["head/w"], np.float32)
        head_bytes = self.dstore.stats.loaded_bytes - b0
        out_dim = int(arch2["out_dim"])
        in_dim_full = int(arch2["in_dim"])
        width_limit = (int(np.asarray(X).shape[1])
                       if mode == "partial" else None)
        # a width-sliced trunk is a distinct embedder for inputs wider
        # than the sample — tag the version so share-cache entries and
        # staged weights never cross between the slices
        sliced = width_limit is not None and width_limit < in_dim_full
        version = (f"{model_id}@1.0+w{width_limit}" if sliced
                   else f"{model_id}@1.0")
        # trunk identity from resolved layer paths: a fine-tune whose
        # trunk layers are all references fingerprints equal to its base
        # (shared embed lane), while a trunk-delta variant gets its own
        # identity; a width slice tags the fingerprint too
        trunk_fp = self.dstore.trunk_fingerprint(model_id)
        base_id = info.base_model or ""
        base_fp = (self.dstore.trunk_fingerprint(base_id) if base_id
                   else "")
        if sliced:
            trunk_fp = f"{trunk_fp}+w{width_limit}"
            if base_fp:
                base_fp = f"{base_fp}+w{width_limit}"
        delta_b = self.dstore.delta_bytes(model_id) if base_id else 0
        prof = profile_for_model(
            n_params=float(info.param_count),
            bytes_per_row=in_dim_full * 4,
            # compressed deltas / deduped pages shrink what a cold
            # resolve reads off disk; Eq. 7's host mem term charges the
            # on-disk bytes, the link term the full dequantized model
            stored_bytes=float(self.dstore.cold_resolve_bytes(model_id)))

        def trunk_resident(m: ResolvedModel) -> bool:
            # a head-mode resolution whose lazy trunk never materialized
            # hasn't loaded or staged anything — it can't discount this
            # variant's Eq. 7 staging cost
            zm = m.zoo_model
            return (m.trunk_fp == trunk_fp and zm is not None
                    and getattr(zm, "materialized", True))

        if base_id and any(trunk_resident(m)
                           for m in self.models.values()):
            # the shared trunk is already resident in this session:
            # staging this variant moves only its delta layers (Eq. 7)
            prof = delta_staged_profile(prof, delta_b)
        rm = ResolvedModel(
            task=name, model_id=model_id, version=version,
            features=None, head=None, profile=prof,
            zoo_model=None, store="decoupled", load_mode=mode,
            loaded_bytes=head_bytes,
            stored_bytes=self.dstore.stored_bytes(model_id),
            in_dim=(width_limit if sliced else in_dim_full),
            head_dim=out_dim, trunk_fp=trunk_fp,
            base_model_id=base_id, base_fp=base_fp,
            delta_bytes=delta_b)
        # a fine-tuned (non-uniform) head is no longer the mean readout
        # the device backends fuse — keep it on host for exactness
        rm.head_kind = ("mean" if np.allclose(w_head, 1.0 / max(out_dim, 1))
                        else "linear")
        rm.head = _LinearHead(w_head)

        def load_trunk() -> ZooModel:
            s0 = self.dstore.stats.loaded_bytes
            stored = self._load_trunk(model_id, arch2,
                                      width_limit=width_limit)
            rm.loaded_bytes += self.dstore.stats.loaded_bytes - s0
            return stored

        if mode == "head":
            lazy = _LazyZooModel(load_trunk)
            rm.zoo_model = lazy
            rm.features = lambda A, _l=lazy: _l._force().features(A)
            # no eager staging: backends late-stage through the lazy
            # proxy on the first embed that actually misses the cache
        else:
            stored = load_trunk()
            rm.zoo_model = stored
            rm.features = stored.features
            self._stage_all(rm, stored)
        return rm

    def calibrate(self, rows=(256, 2048),
                  repeats: int = 3) -> Dict[str, HardwareProfile]:
        """Measure per-row throughput + launch latency from each live
        backend (cost.calibrate) and use the measured profiles for all
        subsequent Eq. 10/11 planning decisions. A backend shared by
        several device names is measured once and the profile reused."""
        import dataclasses
        measured: Dict[int, HardwareProfile] = {}
        self.hw = {}
        for dev, b in self.backends.items():
            if id(b) not in measured:
                measured[id(b)] = calibrate(b, dev, rows=rows,
                                            repeats=repeats)
            self.hw[dev] = dataclasses.replace(measured[id(b)], name=dev)
        return self.hw

    # -- query execution -------------------------------------------------
    def compile(self, plan: LogicalPlan,
                nrows_hint: Optional[int] = None) -> LogicalPlan:
        """Run the optimizer passes against this session's resolutions."""
        if not self.optimize_plans:
            return plan
        profiles = {t: m.profile for t, m in self.models.items()}
        hint = nrows_hint or batch_len(self.tables.get(plan.table, {})) or 1024
        return optimize(plan, profiles, nrows_hint=hint,
                        devices=self.devices, hw=self.hw)

    # -- similarity queries -----------------------------------------------
    def _sim_model(self, nodes: List[PlanNode],
                   col: str) -> Optional[ResolvedModel]:
        """Task context for ``SIMILARITY(col, ...)``: the first
        embed/predict node consuming the column scopes similarity to
        that task's trunk embedding space; without one, similarity runs
        in raw row space."""
        for node in nodes:
            if (node.op in ("embed", "predict")
                    and node.args.get("col") == col):
                rm = self.models.get(node.args.get("task"))
                if rm is not None:
                    return rm
        return None

    def _sim_embed(self, tname: str, col: str, rows: np.ndarray,
                   rm: ResolvedModel) -> Tuple[np.ndarray, int]:
        """Embeddings for similarity scoring, served through the cache
        chain under the same (table, column, trunk) keys the embed
        nodes use — on a warm cache this is a pure gather (exact tier)
        or ANN reuse, zero trunk rows. Returns ``(E, trunk_rows)``."""
        if not self.enable_share:
            return np.asarray(rm.features(np.asarray(rows)),
                              np.float32), len(rows)
        c0 = self.cache_chain.computed_rows
        E = self.cache_chain.get_or_embed(
            tname, col, rows,
            lambda A: np.asarray(rm.features(np.asarray(A)), np.float32),
            version=(rm.trunk_fp or rm.version))
        return np.asarray(E, np.float32), \
            self.cache_chain.computed_rows - c0

    def _similarity_scores(self, tname: str, col: str, rows: np.ndarray,
                           query, rm: Optional[ResolvedModel]
                           ) -> Tuple[np.ndarray, int]:
        """Similarity (negative L2 distance — larger = nearer) of every
        table row to the query, in the task trunk's embedding space when
        one scopes the column, else raw row space. The query is a vector
        literal (input-width, or embedding-width to skip the query-side
        embed entirely) or a text string feature-hashed to input width.
        Returns ``(sims, trunk_rows)``."""
        R = np.asarray(rows)
        Rf = R.reshape(len(R), -1).astype(np.float32, copy=False)
        width = Rf.shape[1]
        if rm is None:                       # raw row space: no trunk
            q = (encode_text(query, width) if isinstance(query, str)
                 else np.asarray(query, np.float32).reshape(-1))
            q = adapt_input_width(q[None], width)[0]
            return -np.linalg.norm(Rf - q[None], axis=1), 0
        E, trunk_rows = self._sim_embed(tname, col, R, rm)
        if (not isinstance(query, str)
                and len(np.asarray(query).reshape(-1)) == rm.head_dim
                and rm.head_dim != width):
            # embedding-width literal: compare directly, no query embed
            qE = np.asarray(query, np.float32).reshape(-1)
        else:
            qrow = (encode_text(query, width) if isinstance(query, str)
                    else np.asarray(query, np.float32).reshape(-1))
            qrow = adapt_input_width(qrow[None], width).astype(
                Rf.dtype if R.dtype == np.float32 else np.float32)
            qe, qt = self._sim_embed(tname, col, qrow, rm)
            qE, trunk_rows = qe[0], trunk_rows + qt
        return -np.linalg.norm(E - qE[None], axis=1), trunk_rows

    def _run_index_scan(self, node: PlanNode, table: Batch
                        ) -> Tuple[Batch, np.ndarray, int]:
        """The lowered top-k fast path: score the whole table against
        the query through the cache chain (warm = ANN/exact gather, no
        trunk) and slice the k nearest rows as the new source table."""
        args = node.args
        rows = np.asarray(table[args["col"]])
        rm = self.models.get(args.get("task") or "")
        sims, trunk_rows = self._similarity_scores(
            args["table"], args["col"], rows, args["query"], rm)
        order = np.argsort(-sims, kind="stable")[:args["k"]]
        sliced = {c: np.asarray(v)[order] for c, v in table.items()}
        return sliced, sims[order], trunk_rows

    @staticmethod
    def _slice_rows(rows: Batch, idx: np.ndarray) -> Batch:
        return {c: np.asarray(v)[idx] for c, v in rows.items()}

    def execute_plan(self, plan: LogicalPlan, sql_text: str = "",
                     chunk_rows: Optional[int] = None,
                     max_inflight: Optional[int] = None) -> QueryResult:
        table = self.tables[plan.table]
        for node in plan.nodes:
            if node.op == "predict" and node.args["task"] not in self.models:
                raise RuntimeError(
                    f"task {node.args['task']!r} not resolved; call "
                    "resolve_task(name, X_sample, y_sample) first")
        plan = self.compile(plan, nrows_hint=batch_len(table))
        # similarity ordering + limit run over the concatenated stream
        # (like final aggregation); an index_scan source replaces the
        # scan entirely — the k-row slice feeds the rest of the dag
        post_nodes = [n for n in plan.nodes if n.op in ("sort", "limit")]
        core_nodes = [n for n in plan.nodes
                      if n.op not in ("sort", "limit")]
        idx_node = (core_nodes[0]
                    if core_nodes and core_nodes[0].op == "index_scan"
                    else None)
        if idx_node is not None:
            core_nodes = ([PlanNode("scan",
                                    {"table": idx_node.args["table"]})]
                          + core_nodes[1:])
        exec_plan = (LogicalPlan(core_nodes)
                     if (post_nodes or idx_node is not None) else plan)
        ctx = CompileContext(
            models=self.models,
            # embeddings depend only on the trunk, so the share cache and
            # the staged-weight lookup key on the trunk identity: fine-
            # tunes of one base reuse the base's cached embeddings and
            # staged trunk (BLOB models fall back to the version string).
            # With the ANN tier enabled the embed nodes consult the whole
            # chain row-granularly; otherwise the classic chunk-level
            # exact cache serves them.
            share=((self.cache_chain if self.ann is not None
                    else self.share) if self.enable_share else None),
            share_version_of={t: (m.trunk_fp or m.version)
                              for t, m in self.models.items()})
        dag, source_id, sink_id, agg_node = compile_plan(exec_plan, ctx)
        h0, m0 = self.share.stats.hits, self.share.stats.misses
        a0 = (self.ann.stats.approx_hits, self.ann.stats.false_accepts) \
            if self.ann is not None else (0, 0)
        sim_trunk_rows = 0
        sim_scores: Optional[np.ndarray] = None
        if idx_node is not None:
            table, sim_scores, sim_trunk_rows = \
                self._run_index_scan(idx_node, table)
        distinct_backends = {id(b): b for b in self.backends.values()}
        c0 = sum(getattr(b, "compile_count", 0)
                 for b in distinct_backends.values())
        ex = PipelineExecutor(dag, workers=self.workers,
                              backends=self.backends)
        if sink_id == source_id:                    # pure scan
            rows = table
        else:
            rows = ex.execute_chunked(
                source_id, table, chunk_rows=chunk_rows or self.chunk_rows,
                sink_id=sink_id, max_inflight=max_inflight
                or self.max_inflight)
        # final aggregation over the concatenated stream (exact groups)
        if agg_node is not None:
            g = agg_node.args.get("group_by")
            specs = agg_node.args["specs"]
            rows = (groupby_aggs(rows, g, specs) if g
                    else aggregate(rows, specs))
        drop_col: Optional[str] = None
        if idx_node is not None:
            # chunked execution of a filterless plan preserves row
            # order, so the index_scan's similarity column re-attaches
            # positionally to the k output rows
            if sim_scores is not None and batch_len(rows) == len(sim_scores):
                rows = dict(rows)
                rows["_sim"] = sim_scores
            drop_col = idx_node.args.get("drop_col")
        for pn in post_nodes:
            if pn.op == "sort":
                col = pn.args["col"]
                rm = self._sim_model(core_nodes, col)
                sims, t = self._similarity_scores(
                    plan.table, col, np.asarray(rows[col]),
                    pn.args["query"], rm)
                sim_trunk_rows += t
                order = np.argsort(
                    sims if pn.args.get("ascending") else -sims,
                    kind="stable")
                rows = self._slice_rows(rows, order)
                rows["_sim"] = sims[order]
                drop_col = pn.args.get("drop_col") or drop_col
            elif pn.op == "limit":
                k = pn.args["k"]
                if batch_len(rows) > k:
                    rows = self._slice_rows(
                        rows, np.arange(k, dtype=np.int64))
        if drop_col is not None and drop_col in rows:
            rows = {c: v for c, v in rows.items() if c != drop_col}
        report = QueryReport(
            sql=sql_text, plan=plan.describe(),
            resolution={t: m.model_id for t, m in self.models.items()
                        if any(n.op in ("predict", "embed")
                               and n.args.get("task") == t
                               for n in plan.nodes)},
            wall_seconds=ex.stats.wall_seconds,
            rows_in=batch_len(table), rows_out=batch_len(rows),
            op_seconds=dict(ex.stats.op_seconds),
            device_of=dict(ex.stats.device_of),
            backend_of=dict(ex.stats.backend_of),
            compile_count=sum(getattr(b, "compile_count", 0)
                              for b in distinct_backends.values()) - c0,
            batch_size_of={n.args["task"]: int(n.args["batch_size"])
                           for n in plan.nodes
                           if n.op == "embed" and "batch_size" in n.args},
            share_hits=self.share.stats.hits - h0,
            share_misses=self.share.stats.misses - m0,
            approx_hits=(self.ann.stats.approx_hits - a0[0]
                         if self.ann is not None else 0),
            false_accepts=(self.ann.stats.false_accepts - a0[1]
                           if self.ann is not None else 0),
            sim_trunk_rows=sim_trunk_rows,
            index_scan=idx_node is not None)
        for t in report.resolution:
            m = self.models[t]
            report.loaded_bytes += m.loaded_bytes
            report.stored_bytes += m.stored_bytes
            report.delta_bytes += m.delta_bytes
        sstats = self.dstore.stats
        report.dedup_pages = sstats.dedup_pages
        report.dedup_bytes_saved = sstats.dedup_bytes_saved
        report.compressed_delta_bytes = sstats.compressed_delta_bytes
        report.quant_error_bound = sstats.quant_error_bound
        for st in ctx.batcher_stats.values():
            report.batch_batches += st.batches
            report.batch_rows += st.rows
            report.batch_infer_seconds += st.infer_seconds
        return QueryResult(rows=rows, report=report)

    def sql(self, statement: str, sample: Optional[Tuple] = None):
        """Execute one SQL statement. ``sample=(X, y)`` supplies the
        resolution sample for any not-yet-resolved task references."""
        stmt = parse(statement)
        if isinstance(stmt, CreateTaskStmt):
            self.create_task(stmt.spec)
            return f"TASK {stmt.spec.name} CREATED"
        assert isinstance(stmt, QueryStmt)
        for t in stmt.tasks:
            if t not in self.registry._tasks:
                raise ValueError(f"unknown task {t}; CREATE TASK first")
            if t not in self.models:
                if sample is None:
                    raise RuntimeError(
                        f"task {t} unresolved and no sample given")
                self.resolve_task(t, *sample)
        return self.execute_plan(stmt.plan, sql_text=statement)
