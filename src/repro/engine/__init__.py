"""Task-centric query engine: SQL -> logical plan -> optimizer ->
annotated DAG -> chunked pipeline runtime, with model resolution through
the selection subspace + storage catalog and pre-embedding via the
vector-share cache. `MorphingSession` is the single entry point.
"""
from repro.engine.config import EngineConfig
from repro.engine.dispatch import (DispatchServer, DispatchStats,
                                   PlacementPolicy)
from repro.engine.plan import (CompileContext, LogicalPlan, PlanNode,
                               annotate_plan, compile_plan, insert_embeds,
                               lower_similarity, optimize,
                               push_down_filters)
from repro.engine.serve import (MorphingServer, ServeResult, ServerStats)
from repro.pipeline.admission import (AdmissionPolicy, CircuitOpen,
                                      Rejected, RequestError)
from repro.engine.session import (MorphingSession, QueryReport, QueryResult,
                                  ResolvedModel)
from repro.engine.sql import (CreateTaskStmt, QueryStmt, SelectItem,
                              TaskCall, encode_text, parse, tokenize)
from repro.pipeline.share import (AnnConfig, AnnShareTier, CacheChain,
                                  CacheTier, IvfFlatIndex, TierLookup)

__all__ = [
    "EngineConfig",
    "DispatchServer", "DispatchStats", "PlacementPolicy",
    "CompileContext", "LogicalPlan", "PlanNode", "annotate_plan",
    "compile_plan", "insert_embeds", "lower_similarity", "optimize",
    "push_down_filters",
    "MorphingServer", "ServeResult", "ServerStats",
    "AdmissionPolicy", "CircuitOpen", "Rejected", "RequestError",
    "MorphingSession", "QueryReport", "QueryResult", "ResolvedModel",
    "CreateTaskStmt", "QueryStmt", "SelectItem", "TaskCall",
    "encode_text", "parse", "tokenize",
    "AnnConfig", "AnnShareTier", "CacheChain", "CacheTier",
    "IvfFlatIndex", "TierLookup",
]
