"""EngineConfig: the one construction surface for the engine.

`MorphingSession` and `MorphingServer` historically grew overlapping
keyword arguments (the server's ``devices=`` int versus the session's
``device_count=``, duplicated store/calibration/share knobs forwarded
through ``**session_kw``), each pair needing its own conflict check.
`EngineConfig` collapses them into one validated dataclass consumed by
both entry points::

    cfg = EngineConfig(model_store="decoupled", device_count=2,
                       cache_tiers=("exact", "ann"),
                       ann=AnnConfig(error_bound=0.1))
    sess = MorphingSession(selector=sel, zoo=zoo, config=cfg)
    server = MorphingServer(config=cfg)

Every legacy keyword keeps working as a deprecation shim: explicit
kwargs overlay the config (and the server's ``devices=`` emits a
DeprecationWarning pointing at ``device_count``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.pipeline.share import AnnConfig

# sentinel distinguishing "kwarg not passed" from an explicit value, so
# legacy kwargs can overlay a provided config without clobbering it
UNSET: Any = object()

_VALID_STORES = ("blob", "decoupled")
_VALID_TIERS = ("exact", "ann")


@dataclass
class EngineConfig:
    """Shared engine configuration (session + server).

    ``cache_tiers`` names the share-cache chain in lookup order:
    ``("exact",)`` is the classic fingerprint-equality cache;
    ``("exact", "ann")`` appends the opt-in approximate tier
    (:class:`repro.pipeline.share.AnnShareTier`) configured by ``ann``.
    ``policy`` is the serving admission policy (ignored by plain
    sessions).

    ``calib_memo_path`` opts fast auto-calibration into an on-disk memo
    (JSON) keyed by a host/backend/device-count fingerprint, so N worker
    processes and repeated CI legs stop re-paying the two-point probe;
    entries go stale — and re-probe — when the jax version or device
    count changes (the fingerprint embeds both). ``workers`` doubles as
    the dispatch tier's default worker-process count
    (:class:`repro.engine.dispatch.DispatchServer`)."""

    model_store: str = "blob"
    backend: str = "auto"
    devices: Tuple[str, ...] = ("host", "tpu")
    device_count: int = 1
    # decoupled-store compression (docs/architecture.md): sparse/quantized
    # fine-tune deltas and content-hashed tensor-page dedup. Off by
    # default — both change on-disk layout (reads stay transparent).
    compress_deltas: bool = False
    quant_dtype: str = "int8"            # code width for dense residuals
    sparse_eps: float = 0.0              # |delta| <= eps sparsified away
    dedup_pages: bool = False
    page_bytes: int = 64 << 10
    auto_calibrate: bool = True
    calib_memo_path: Optional[str] = None
    enable_share: bool = True
    share_capacity_bytes: int = 1 << 30
    cache_tiers: Tuple[str, ...] = ("exact",)
    ann: Optional[AnnConfig] = None
    chunk_rows: int = 256
    max_inflight: int = 3
    workers: int = 4
    optimize_plans: bool = True
    policy: Optional[Any] = None         # AdmissionPolicy (serving only)

    def validate(self) -> "EngineConfig":
        if self.model_store not in _VALID_STORES:
            raise ValueError(f"unknown model_store {self.model_store!r}")
        tiers = tuple(self.cache_tiers)
        unknown = [t for t in tiers if t not in _VALID_TIERS]
        if unknown:
            raise ValueError(
                f"unknown cache tier(s) {unknown}; valid: {_VALID_TIERS}")
        if tiers and tiers[0] != "exact":
            # approximate tiers serve *residual* misses; putting one in
            # front of the exact tier would approximate rows the cache
            # could have answered exactly
            raise ValueError("cache_tiers must start with 'exact'")
        if self.device_count < 1:
            raise ValueError(
                f"device_count must be >= 1, got {self.device_count}")
        if self.quant_dtype not in ("int8", "int16"):
            raise ValueError(
                f"quant_dtype must be int8|int16, got {self.quant_dtype!r}")
        if self.sparse_eps < 0:
            raise ValueError(
                f"sparse_eps must be >= 0, got {self.sparse_eps}")
        if self.page_bytes < 1:
            raise ValueError(
                f"page_bytes must be >= 1, got {self.page_bytes}")
        return self

    def overlaid(self, overrides: Dict[str, Any]) -> "EngineConfig":
        """Copy with explicitly-passed legacy kwargs overlaid (UNSET
        entries are dropped)."""
        real = {k: v for k, v in overrides.items() if v is not UNSET}
        return dataclasses.replace(self, **real) if real else self
