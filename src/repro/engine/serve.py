"""MorphingServer: the continuous-batching serving path of the engine.

Batch analytics (``MorphingSession.sql``) plans one big query; the online
regime is many small concurrent ``PREDICT ... USING TASK`` requests
arriving inside the DBMS. Paying the full parse/plan/chunked-executor
machinery per request wastes exactly the overheads the cost model says
batching amortizes, so the server keeps one *lane* per task:

- admission goes through a long-running :class:`ContinuousBatcher`
  (start/submit/result/stop, results condition variable, drain-on-stop);
- same-task requests are coalesced into cost-model-sized batches — the
  lane's row budget comes from Eq. 11 (``choose_batch_size`` over the
  task's calibrated :class:`HardwareProfile`), with the batcher counting
  payload *rows*, not requests;
- each coalesced batch executes through the task's staged
  :class:`ExecutionBackend` (weights staged once at resolve, jit shapes
  bucketed), so stage/compile costs amortize across requests exactly as
  TransCost (Eq. 7) assumes;
- resolution rides the session's partial-load path: on a decoupled
  store, a lane's model loads only the layers its requests need, and
  ``ServerStats`` reports loaded-vs-stored bytes next to the latency
  percentiles.

    server = MorphingServer(session=sess).start()
    rid = server.submit("PREDICT emb USING TASK sent FROM reviews "
                        "WHERE len > 20")
    out = server.result(rid)          # ServeResult: scores + latency
    server.stats().p95_latency_s
    server.stop()                     # drains the queues, joins workers
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.zoo import adapt_input_width
from repro.engine.session import MorphingSession
from repro.engine.sql import QueryStmt, parse
from repro.engine.plan import _make_pred
from repro.pipeline.backend import InferSpec, default_host_backend
from repro.pipeline.batcher import BatcherStats, ContinuousBatcher, Request
from repro.pipeline.cost import choose_batch_size, choose_device

# Eq. 11 candidates for the serving row budget: lanes coalesce many
# requests, so the sweep extends past the per-operator 8-128 window.
_LANE_BATCH_CANDIDATES = (32, 64, 128, 256, 512, 1024, 2048, 4096)


@dataclass
class ServeResult:
    """One served PREDICT request."""
    req_id: int
    task: str
    scores: np.ndarray
    rows: int
    latency_s: float


@dataclass
class ServerStats:
    """Aggregate serving telemetry across all task lanes."""
    requests: int = 0
    rows: int = 0
    batches: int = 0
    requests_by_task: Dict[str, int] = field(default_factory=dict)
    mean_coalesced: float = 0.0      # requests fused per executed batch
    p50_latency_s: float = 0.0
    p95_latency_s: float = 0.0
    max_latency_s: float = 0.0
    infer_seconds: float = 0.0
    loaded_bytes: int = 0            # model bytes read from disk
    stored_bytes: int = 0            # model bytes held by the store

    @property
    def rows_per_second(self) -> float:
        return self.rows / self.infer_seconds if self.infer_seconds else 0.0


@dataclass
class _Lane:
    """Per-task serving lane: one batcher + one staged backend spec."""
    task: str
    device: str
    batcher: ContinuousBatcher
    spec: InferSpec
    batch_rows: int
    requests: int = 0


class MorphingServer:
    """Concurrent PREDICT requests -> per-task continuous batching.

    Wraps a :class:`MorphingSession` (constructing one from ``**session_kw``
    when not given — the session auto-calibrates unless opted out, so
    lane batch sizes come from measured hardware profiles). The server
    only accepts ``PREDICT col USING TASK t FROM table [WHERE ...]``
    statements; analytics SQL belongs on ``session.sql``.
    """

    def __init__(self, session: Optional[MorphingSession] = None, *,
                 max_wait_s: float = 0.002, idle_wait_s: float = 0.05,
                 mem_cap_bytes: float = 2e9, nrows_hint: int = 2048,
                 **session_kw):
        self.session = session or MorphingSession(**session_kw)
        self.max_wait_s = max_wait_s
        self.idle_wait_s = idle_wait_s
        self.mem_cap_bytes = mem_cap_bytes
        self.nrows_hint = nrows_hint
        self._lanes: Dict[str, _Lane] = {}
        self._task_of: Dict[int, str] = {}
        self._ids = itertools.count()
        self._lock = threading.Lock()
        self._running = False

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "MorphingServer":
        with self._lock:
            if self._running:
                raise RuntimeError("server already started")
            self._running = True
            for lane in self._lanes.values():
                lane.batcher.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop every lane. With ``drain`` (default) queued requests are
        served before the workers join; otherwise they are dropped and
        their ``result()`` calls raise."""
        with self._lock:
            if not self._running:
                return
            self._running = False
            lanes = list(self._lanes.values())
        for lane in lanes:
            lane.batcher.stop(drain=drain)

    def __enter__(self) -> "MorphingServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request admission -------------------------------------------------
    def _parse_predict(self, sql: str) -> Tuple[str, str, str, list]:
        stmt = parse(sql)
        ops = stmt.plan.ops() if isinstance(stmt, QueryStmt) else []
        if ops not in (["scan", "predict"], ["scan", "predict", "filter"]):
            raise ValueError(
                "MorphingServer serves PREDICT ... USING TASK statements; "
                "run analytics SQL through MorphingSession.sql")
        pred = next(n for n in stmt.plan.nodes if n.op == "predict")
        preds = [p for n in stmt.plan.nodes if n.op == "filter"
                 for p in n.args["preds"]]
        return pred.args["task"], pred.args["col"], stmt.plan.table, preds

    def _rows_for(self, table: str, col: str, preds: list) -> np.ndarray:
        tab = self.session.tables[table]
        X = np.asarray(tab[col])
        if preds:
            X = X[_make_pred(preds)(tab)]
        return X

    def _lane_for(self, task: str) -> _Lane:
        lane = self._lanes.get(task)
        if lane is not None:
            return lane
        with self._lock:
            lane = self._lanes.get(task)
            if lane is not None:
                return lane
            sess = self.session
            rm = sess.models[task]
            device = choose_device(rm.profile, self.nrows_hint,
                                   sess.devices, sess.hw)
            backend = sess.backends.get(device) or default_host_backend()
            batch_rows = choose_batch_size(
                rm.profile, device, candidates=_LANE_BATCH_CANDIDATES,
                mem_cap_bytes=self.mem_cap_bytes, hw=sess.hw)
            spec = InferSpec(
                kind="predict", task=task, col="x", out="y",
                table="__serve__", version=rm.version, model=rm,
                batch_size=batch_rows, share=None, stats=BatcherStats())

            def step(payloads: List[np.ndarray],
                     _b=backend, _s=spec) -> List[np.ndarray]:
                lens = [len(p) for p in payloads]
                out = np.asarray(
                    _b.run_infer(_s, {"x": _stack(payloads)})["y"])
                offs = np.cumsum([0] + lens)
                return [out[a:b] for a, b in zip(offs[:-1], offs[1:])]

            batcher = ContinuousBatcher(
                step, batch_size=batch_rows, size_of=len,
                max_wait_s=self.max_wait_s, idle_wait_s=self.idle_wait_s)
            lane = _Lane(task=task, device=device, batcher=batcher,
                         spec=spec, batch_rows=batch_rows)
            if self._running:
                batcher.start()
            self._lanes[task] = lane
            return lane

    def resolve_task(self, name: str, X: np.ndarray, y: np.ndarray,
                     **kw) -> None:
        """Resolve a task ahead of traffic (partial-load aware)."""
        with self._lock:
            if name not in self.session.models:
                self.session.resolve_task(name, X, y, **kw)

    def submit(self, sql: str,
               sample: Optional[Tuple[np.ndarray, np.ndarray]] = None
               ) -> int:
        """Admit one PREDICT statement; returns its request id. The rows
        the statement selects are snapshotted at admission (the window
        the request observed) and coalesced with other requests for the
        same task."""
        task, col, table, preds = self._parse_predict(sql)
        if not self._running:
            raise RuntimeError(
                "server not started: call start() or use 'with server:'")
        if task not in self.session.models:
            if sample is None:
                raise RuntimeError(
                    f"task {task} unresolved and no sample given")
            self.resolve_task(task, *sample)
        lane = self._lane_for(task)
        X = self._rows_for(table, col, preds)
        req_id = next(self._ids)
        # bookkeeping only after a successful admission (submit raises
        # when racing a stop()); counter writes go under the lock
        lane.batcher.submit(Request(req_id, X))
        self._task_of[req_id] = task
        with self._lock:
            lane.requests += 1
        return req_id

    def result(self, req_id: int,
               timeout: Optional[float] = None) -> ServeResult:
        """Block until the request's batch has executed. Each result is
        retrievable once: returning it releases the server's per-request
        state (long-running services stay memory-bounded)."""
        task = self._task_of[req_id]
        lane = self._lanes[task]
        try:
            scores = lane.batcher.result(req_id, timeout=timeout,
                                         evict=False)
            latency = lane.batcher.latency(req_id)
        except TimeoutError:
            raise                        # still pending: retry result()
        except BaseException:
            lane.batcher.evict(req_id)   # failed: release the slot
            self._task_of.pop(req_id, None)
            raise
        lane.batcher.evict(req_id)
        self._task_of.pop(req_id, None)
        return ServeResult(req_id=req_id, task=task,
                           scores=np.asarray(scores), rows=len(scores),
                           latency_s=latency)

    def predict(self, sql: str,
                sample: Optional[Tuple[np.ndarray, np.ndarray]] = None,
                timeout: Optional[float] = None) -> ServeResult:
        """submit + result convenience for a single caller thread."""
        return self.result(self.submit(sql, sample=sample),
                           timeout=timeout)

    # -- telemetry ---------------------------------------------------------
    def stats(self) -> ServerStats:
        st = ServerStats()
        lat: List[float] = []
        coalesced: List[int] = []
        with self._lock:
            lanes = list(self._lanes.values())
        for lane in lanes:
            lane_lat, lane_sizes = lane.batcher.telemetry()
            st.requests += lane.requests
            st.requests_by_task[lane.task] = lane.requests
            st.rows += lane.spec.stats.rows
            st.batches += len(lane_sizes)
            st.infer_seconds += lane.spec.stats.infer_seconds
            lat.extend(lane_lat)
            coalesced.extend(lane_sizes)
        if coalesced:
            st.mean_coalesced = float(np.mean(coalesced))
        if lat:
            st.p50_latency_s = float(np.percentile(lat, 50))
            st.p95_latency_s = float(np.percentile(lat, 95))
            st.max_latency_s = float(np.max(lat))
        # bytes are scoped to tasks actually served through a lane — a
        # shared session's analytics-only resolutions don't belong in
        # serving telemetry
        for lane in lanes:
            rm = self.session.models.get(lane.task)
            if rm is not None:
                st.loaded_bytes += rm.loaded_bytes
                st.stored_bytes += rm.stored_bytes
        return st


def _stack(payloads: List[np.ndarray]) -> np.ndarray:
    """Concatenate request payloads, width-adapting narrower ones so
    requests over differently-shaped tables can share a batch (the
    backend re-adapts to the model's input width anyway)."""
    arrs = [np.asarray(p, np.float32) for p in payloads]
    if len(arrs) == 1:
        return arrs[0]
    width = max(a.shape[1] for a in arrs)
    return np.concatenate([adapt_input_width(a, width) for a in arrs])
