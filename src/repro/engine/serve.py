"""MorphingServer: the share-aware continuous-batching serving path.

Paper cross-reference: lane row budgets are Eq. 11 batch-size selection
applied per stage (``cost.split_profile`` sizes the trunk's embed budget
and the head's much larger budget separately), and the one-time weight
staging per trunk lane is exactly the amortization TransCost (Eq. 7)
assumes — including its delta-aware form, where a fleet of fine-tunes
sharing one base trunk stages it once. Field-by-field telemetry
reference: ``docs/serving.md``.

Batch analytics (``MorphingSession.sql``) plans one big query; the online
regime is many small concurrent ``PREDICT ... USING TASK`` requests
arriving inside the DBMS. The optimizer's biggest throughput lever — the
embed/head split with vector sharing (paper §5.1) — lives inside the
server too: lanes are keyed by *trunk*, not task, and split every request
into a share-cached embed stage plus a cheap per-task head stage. Because
the lane key is ``ResolvedModel.trunk_fp`` — the *resolved layer-path*
identity — K fine-tune deltas of one base model land in their base
trunk's embed lane automatically: one trunk forward (staged once, under
the trunk fingerprint) feeds K cheap delta-composed head stages
(``ExecutionBackend.run_head``), and ``ServerStats`` reports the fleet's
delta task count and byte accounting.

- admission goes through a long-running :class:`ContinuousBatcher` per
  trunk lane (start/submit/result/stop, results condition variable,
  drain-on-stop); tasks whose resolved models share a trunk fingerprint
  (``ResolvedModel.trunk_fp``, tracked by the DecoupledStore layer-tensor
  identity) feed one lane;
- a lane's coalesced batch consults the :class:`VectorShareCache` first
  through the batched row-granular API (``get_many`` — one vectorized
  fingerprint pass over the whole chunk), so warm rows cost a gather,
  not a forward pass;
- identical in-flight rows are single-flight deduplicated: each lane has
  one worker, batches serialize, and within a batch only the *unique*
  missing rows run through the trunk (``ServerStats.dedup_rows`` counts
  the folded duplicates); results write back via ``put_many`` before the
  next batch collects, so N concurrent identical requests compute one
  embedding;
- row budgets come from Eq. 11 sized per stage (``cost.split_profile``):
  the embed lane batches to the trunk's budget, the head stage to its
  own (much larger) budget, executed through the backend's head-only
  entry point (``ExecutionBackend.run_head``);
- resolution rides the session's partial-load path: on a decoupled
  store, a head-mode task's trunk stays on disk while the share cache
  keeps hitting.

    server = MorphingServer(session=sess).start()
    rid = server.submit("PREDICT emb USING TASK sent FROM reviews "
                        "WHERE len > 20")
    out = server.result(rid)          # ServeResult: scores + latency
    server.stats().share_hit_rate
    server.stop()                     # drains the queues, joins workers

``share_lanes=False`` restores the per-task full-predict lanes (the
ablation baseline ``benchmarks/bench_serving.py`` measures against).
"""
from __future__ import annotations

import itertools
import threading
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.zoo import adapt_input_width
from repro.engine.config import EngineConfig
from repro.engine.session import MorphingSession
from repro.engine.sql import QueryStmt, parse
from repro.engine.plan import _make_pred
from repro.pipeline.admission import (AdmissionPolicy, CircuitOpen,
                                      PRIORITIES, validate_priority)
from repro.pipeline.backend import (ExecutionBackend, InferSpec,
                                    default_host_backend)
from repro.pipeline.batcher import BatcherStats, ContinuousBatcher, Request
from repro.pipeline.cost import (choose_batch_size, choose_device,
                                 split_profile)

# Eq. 11 candidates for the serving row budgets: lanes coalesce many
# requests, so the sweep extends past the per-operator 8-128 window.
_LANE_BATCH_CANDIDATES = (32, 64, 128, 256, 512, 1024, 2048, 4096)
# the serving row cache is content-addressed per trunk, not per table:
# identical rows from different requests/tables share one entry
_SHARE_TABLE = "__serve__"


@dataclass
class ServeResult:
    """One served PREDICT request."""
    req_id: int
    task: str
    scores: np.ndarray
    rows: int
    latency_s: float


@dataclass
class ServerStats:
    """Aggregate serving telemetry across all trunk lanes."""
    requests: int = 0
    rows: int = 0                    # rows served (scored by a head)
    batches: int = 0
    requests_by_task: Dict[str, int] = field(default_factory=dict)
    mean_coalesced: float = 0.0      # requests fused per executed batch
    p50_latency_s: float = 0.0
    p95_latency_s: float = 0.0
    max_latency_s: float = 0.0
    infer_seconds: float = 0.0       # embed + head compute seconds
    loaded_bytes: int = 0            # model bytes read from disk
    stored_bytes: int = 0            # model bytes held by the store
    # share-aware serving: the embed/head split inside the lanes
    share_hits: int = 0              # embed rows served exactly from cache
    share_misses: int = 0            # embed rows not in cache (pre-dedup)
    approx_hits: int = 0             # embed rows served by the ANN tier
    #                                # (nearest cached neighbor within the
    #                                # calibrated radius, not byte-equal)
    false_accepts: int = 0           # audited approx hits whose exact
    #                                # recomputation exceeded the bound
    dedup_rows: int = 0              # in-flight duplicates folded away
    embed_rows: int = 0              # rows actually run through a trunk
    embed_batches: int = 0
    head_rows: int = 0               # rows scored by per-task head stages
    head_batches: int = 0
    share_hit_rate_by_lane: Dict[str, float] = field(default_factory=dict)
    # fine-tune delta serving: tasks whose resolved model is a delta
    # variant (ResolvedModel.base_model_id) riding a shared trunk lane
    lanes: int = 0                   # live embed/predict lanes
    tasks_by_lane: Dict[str, int] = field(default_factory=dict)
    # mesh dimension: how many devices the trunk embed lanes span, and
    # the measured aggregate embed rate across them (rows the trunks
    # actually computed / their wall seconds — share hits excluded)
    devices: int = 1
    mesh_rows_per_s: float = 0.0
    delta_tasks: int = 0             # served tasks that are fine-tunes
    delta_loaded_bytes: int = 0      # disk bytes their resolutions read
    #                                # (≈ K·delta when the base is warm)
    delta_stored_bytes: int = 0      # their delta layers' bytes on disk
    # storage-compression gauges (session-lifetime DecoupledStore stats;
    # docs/architecture.md "Compressed deltas & tensor-page dedup")
    dedup_pages: int = 0             # page writes elided by content dedup
    dedup_bytes_saved: int = 0       # bytes those elided writes would cost
    compressed_delta_bytes: int = 0  # on-disk bytes of compressed deltas
    quant_error_bound: float = 0.0   # max declared quant bound in play
    # admission / robustness layer (populated when the server carries an
    # AdmissionPolicy; zeros otherwise) — docs/serving.md "Admission &
    # SLOs" documents every field
    rejected: int = 0                # submits pushed back (Rejected)
    rejected_by_priority: Dict[str, int] = field(default_factory=dict)
    retries: int = 0                 # transient-failure batch retries
    failed_batches: int = 0          # batches that failed after retries
    deadline_misses: int = 0         # served past their deadline_ms
    deadlines_admitted: int = 0      # requests admitted with a deadline
    breaker_trips: int = 0           # lane breakers tripped open
    breaker_resets: int = 0          # supervisor breaker resets
    breaker_open_lanes: List[str] = field(default_factory=list)
    p50_latency_s_by_priority: Dict[str, float] = field(
        default_factory=dict)
    p95_latency_s_by_priority: Dict[str, float] = field(
        default_factory=dict)
    batch_rows_by_lane: Dict[str, int] = field(default_factory=dict)
    budget_shrinks: int = 0          # dynamic-budget shrink events
    budget_grows: int = 0            # dynamic-budget regrow events

    @property
    def rows_per_second(self) -> float:
        return self.rows / self.infer_seconds if self.infer_seconds else 0.0

    @property
    def share_hit_rate(self) -> float:
        """Cache-served fraction of embed rows — exact and approximate
        hits both spared a trunk forward."""
        hits = self.share_hits + self.approx_hits
        t = hits + self.share_misses
        return hits / t if t else 0.0

    @property
    def dedup_rate(self) -> float:
        """Fraction of would-be trunk rows eliminated by single-flight
        dedup of identical in-flight rows."""
        t = self.dedup_rows + self.embed_rows
        return self.dedup_rows / t if t else 0.0


@dataclass
class _HeadStage:
    """Per-task head stage: consumes embeddings at its own Eq. 11 row
    budget (``spec.batch_size``) through the backend's head-only entry
    point, which owns the slicing and the stats accumulation."""
    task: str
    spec: InferSpec                  # kind='head'; stats = head telemetry
    backend: ExecutionBackend
    batch_rows: int

    def run(self, F: np.ndarray) -> np.ndarray:
        return self.backend.run_head(self.spec, F)


@dataclass
class _Lane:
    """One serving lane: a batcher plus the embed/head stage specs.

    With share lanes the key is the trunk fingerprint and ``heads`` maps
    every task feeding the lane to its head stage; in legacy mode the
    key is the task and ``spec`` executes the fused full predict.
    """
    key: str
    device: str
    batcher: ContinuousBatcher
    spec: InferSpec                  # embed spec (share) / predict (legacy)
    batch_rows: int                  # Eq. 11 embed (or predict) row budget
    heads: Dict[str, _HeadStage] = field(default_factory=dict)
    in_dim: int = 0                  # trunk input width (0 = adapt per batch)
    requests_by_task: Dict[str, int] = field(default_factory=dict)
    # share counters are written by the single lane worker and read by
    # stats() under the lane lock
    lock: threading.Lock = field(default_factory=threading.Lock)
    share_hits: int = 0
    share_misses: int = 0
    approx_hits: int = 0
    false_accepts: int = 0
    dedup_rows: int = 0

    @property
    def requests(self) -> int:
        return sum(self.requests_by_task.values())


class MorphingServer:
    """Concurrent PREDICT requests -> share-aware continuous batching.

    Wraps a :class:`MorphingSession` (constructing one from ``**session_kw``
    when not given — the session auto-calibrates unless opted out, so
    lane batch sizes come from measured hardware profiles). The server
    only accepts ``PREDICT col USING TASK t FROM table [WHERE ...]``
    statements; analytics SQL belongs on ``session.sql``.
    """

    def __init__(self, session: Optional[MorphingSession] = None, *,
                 config: Optional[EngineConfig] = None,
                 max_wait_s: float = 0.002, idle_wait_s: float = 0.05,
                 mem_cap_bytes: float = 2e9, nrows_hint: int = 2048,
                 share_lanes: bool = True, devices: Optional[int] = None,
                 stop_timeout_s: float = 30.0,
                 policy: Optional[AdmissionPolicy] = None, **session_kw):
        if devices is not None:
            warnings.warn(
                "MorphingServer(devices=...) is deprecated; pass "
                "config=EngineConfig(device_count=...) (shared with "
                "MorphingSession) instead", DeprecationWarning,
                stacklevel=2)
        if session is None:
            if devices is not None:
                session_kw.setdefault("device_count", devices)
            session = MorphingSession(config=config, **session_kw)
        elif devices is not None and devices != getattr(
                session, "device_count", 1):
            raise ValueError(
                f"devices={devices} conflicts with the session's backend "
                f"pool ({getattr(session, 'device_count', 1)} devices); "
                "construct the session with device_count instead")
        self.session = session
        # effective mesh width of the session's backend pool (clamped to
        # real devices): trunk embed lanes size their Eq. 11 row budgets
        # against this many devices' aggregate throughput
        self.devices = getattr(session, "device_count", 1)
        self.max_wait_s = max_wait_s
        self.idle_wait_s = idle_wait_s
        self.mem_cap_bytes = mem_cap_bytes
        self.nrows_hint = nrows_hint
        self.share_lanes = share_lanes
        self.stop_timeout_s = stop_timeout_s
        # admission policy is applied to every lane; None keeps the
        # legacy unbounded FIFO lanes. The shared EngineConfig is the
        # canonical source (explicit policy= overrides it).
        if policy is None:
            src = config or getattr(session, "config", None)
            policy = src.policy if src is not None else None
        self.policy = policy
        # decoupled-store trunk pins held for the active lanes (released
        # on stop): the layer-cache LRU never evicts a trunk a live
        # embed lane would immediately re-read
        self._pins: List[str] = []
        self._lanes: Dict[str, _Lane] = {}
        self._lane_of_task: Dict[str, _Lane] = {}
        self._task_of: Dict[int, str] = {}
        self._ids = itertools.count()
        self._lock = threading.Lock()
        self._running = False

    # -- lifecycle ---------------------------------------------------------
    def _pin_task(self, rm) -> None:
        """Pin a served task's trunk layers in the decoupled store so the
        byte-capped layer cache evicts around them (must be called with
        ``self._lock`` held; refcounted, released on :meth:`stop`)."""
        if rm.store != "decoupled":
            return
        try:
            self.session.dstore.pin_model(rm.model_id)
        except KeyError:
            return                   # not in this store's catalog
        self._pins.append(rm.model_id)

    def start(self) -> "MorphingServer":
        with self._lock:
            if self._running:
                raise RuntimeError("server already started")
            self._running = True
            for lane in self._lanes.values():
                # a restart re-pins the lanes' trunks (stop released them)
                for task in lane.requests_by_task:
                    rm = self.session.models.get(task)
                    if rm is not None:
                        self._pin_task(rm)
                lane.batcher.start()
        return self

    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> None:
        """Stop every lane. With ``drain`` (default) queued requests are
        served before the workers join — including their share-cache
        write-backs; otherwise they are dropped and their ``result()``
        calls raise.

        Workers are joined with a per-lane ``timeout`` (default
        ``stop_timeout_s``); a worker stuck in a step — a wedged backend,
        a deadlocked kernel — surfaces as a RuntimeError naming the
        stuck lanes instead of hanging the shutdown forever. The stuck
        workers stay daemon threads; a later ``stop()`` retries the
        join."""
        with self._lock:
            was_running = self._running
            self._running = False
            lanes = list(self._lanes.values())
        if not was_running and all(lane.batcher._thread is None
                                   for lane in lanes):
            return          # nothing left to join: idempotent stop
        # not-running but with live workers = a prior stop() timed out
        # on a wedged lane; fall through so this call retries the joins
        timeout = self.stop_timeout_s if timeout is None else timeout
        stuck: List[str] = []
        try:
            for lane in lanes:
                try:
                    lane.batcher.stop(drain=drain, timeout=timeout)
                except TimeoutError:
                    stuck.append(lane.key)
        finally:
            # release the trunk pins: a stopped server's lanes no longer
            # defend their trunks against layer-cache eviction
            with self._lock:
                pins, self._pins = self._pins, []
            for mid in pins:
                self.session.dstore.unpin_model(mid)
        if stuck:
            raise RuntimeError(
                f"serving lane worker(s) did not join within {timeout}s: "
                f"{stuck}; their step functions are still running "
                "(wedged backend?) — results for their pending requests "
                "will not arrive")

    def unstage_trunk(self, key: str, *,
                      timeout: Optional[float] = None) -> bool:
        """Tear down one trunk lane (the dispatch tier's scale-in path):
        drain and join its batcher, release the member tasks' store
        pins, and evict the staged weights from every backend. The tasks
        stay resolved — the next submit for one of them rebuilds the
        lane, re-staging the trunk (Eq. 7 paid again, by design).
        Returns False when no lane with that key exists. Callers should
        quiesce traffic for the trunk first; the drain serves whatever
        is still queued."""
        with self._lock:
            lane = self._lanes.pop(key, None)
            if lane is None:
                return False
            tasks = [t for t, ln in list(self._lane_of_task.items())
                     if ln is lane]
            for t in tasks:
                self._lane_of_task.pop(t, None)
        lane.batcher.stop(drain=True,
                          timeout=(self.stop_timeout_s
                                   if timeout is None else timeout))
        for b in {id(b): b for b in
                  self.session.backends.values()}.values():
            b.unstage(lane.spec.version)
        with self._lock:
            for t in tasks:
                rm = self.session.models.get(t)
                if rm is not None and rm.model_id in self._pins:
                    self._pins.remove(rm.model_id)
                    self.session.dstore.unpin_model(rm.model_id)
        return True

    def __enter__(self) -> "MorphingServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request admission -------------------------------------------------
    def _parse_predict(self, sql: str) -> Tuple[str, str, str, list]:
        stmt = parse(sql)
        ops = stmt.plan.ops() if isinstance(stmt, QueryStmt) else []
        if ops not in (["scan", "predict"], ["scan", "predict", "filter"]):
            raise ValueError(
                "MorphingServer serves PREDICT ... USING TASK statements; "
                "run analytics SQL through MorphingSession.sql")
        pred = next(n for n in stmt.plan.nodes if n.op == "predict")
        preds = [p for n in stmt.plan.nodes if n.op == "filter"
                 for p in n.args["preds"]]
        return pred.args["task"], pred.args["col"], stmt.plan.table, preds

    def _rows_for(self, table: str, col: str, preds: list) -> np.ndarray:
        tab = self.session.tables[table]
        X = np.asarray(tab[col])
        if preds:
            X = X[_make_pred(preds)(tab)]
        return X

    # -- lane construction -------------------------------------------------
    def _head_stage(self, task: str, rm, backend) -> _HeadStage:
        _, head_prof = split_profile(rm.profile, rm.head_dim)
        head_rows = choose_batch_size(
            head_prof, "host", candidates=_LANE_BATCH_CANDIDATES,
            mem_cap_bytes=self.mem_cap_bytes, hw=self.session.hw)
        spec = InferSpec(kind="head", task=task, col="f", out="y",
                         table=_SHARE_TABLE, version=rm.version, model=rm,
                         batch_size=head_rows, share=None,
                         stats=BatcherStats())
        return _HeadStage(task=task, spec=spec, backend=backend,
                          batch_rows=head_rows)

    def _lane_for(self, task: str) -> _Lane:
        sess = self.session
        rm = sess.models[task]
        key = ((rm.trunk_fp or rm.version) if self.share_lanes else task)
        lane = self._lanes.get(key)
        if lane is not None and task in lane.requests_by_task:
            return lane
        with self._lock:
            lane = self._lanes.get(key)
            if lane is None:
                lane = self._build_lane(key, rm)
                if self._running:
                    lane.batcher.start()
                self._lanes[key] = lane
            if task not in lane.requests_by_task:
                # active lanes pin their trunks in the decoupled layer
                # cache (a fine-tune joining a shared lane pins the base
                # trunk its references resolve to)
                self._pin_task(rm)
                # a second task joining an existing trunk lane only needs
                # its own head stage; the trunk work is shared. Mutations
                # go under the lane lock: stats()/reset_telemetry()
                # iterate these dicts while traffic registers new tasks
                if self.share_lanes and task not in lane.heads:
                    backend = (sess.backends.get(lane.device)
                               or default_host_backend())
                    stage = self._head_stage(task, rm, backend)
                    with lane.lock:
                        lane.heads[task] = stage
                with lane.lock:
                    lane.requests_by_task.setdefault(task, 0)
            self._lane_of_task[task] = lane
            return lane

    def _build_lane(self, key: str, rm) -> _Lane:
        sess = self.session
        device = choose_device(rm.profile, self.nrows_hint,
                               sess.devices, sess.hw)
        backend = sess.backends.get(device) or default_host_backend()
        if not self.share_lanes:
            batch_rows = choose_batch_size(
                rm.profile, device, candidates=_LANE_BATCH_CANDIDATES,
                mem_cap_bytes=self.mem_cap_bytes, hw=sess.hw)
            # staging identity is the trunk fingerprint here too (the
            # session staged weights under it): the per-task ablation
            # lanes must not re-stage a duplicate trunk per task
            spec = InferSpec(
                kind="predict", task=rm.task, col="x", out="y",
                table=_SHARE_TABLE, version=(rm.trunk_fp or rm.version),
                model=rm, batch_size=batch_rows, share=None,
                stats=BatcherStats())
            lane = _Lane(key=key, device=device, batcher=None,  # type: ignore
                         spec=spec, batch_rows=batch_rows)
            step = self._legacy_step(lane, backend)
        else:
            embed_prof, _ = split_profile(rm.profile, rm.head_dim)
            batch_rows = choose_batch_size(
                embed_prof, device, candidates=_LANE_BATCH_CANDIDATES,
                mem_cap_bytes=self.mem_cap_bytes, hw=sess.hw)
            # mesh lanes budget against aggregate throughput: each of the
            # N devices takes batch/N rows, so the Eq. 11 optimum for one
            # device scales to N devices at the same per-device latency
            # and memory footprint (capped at the candidate ceiling)
            n_dev = int(getattr(backend, "device_count", 1))
            if n_dev > 1:
                batch_rows = min(batch_rows * n_dev,
                                 _LANE_BATCH_CANDIDATES[-1])
            # the staging identity is the trunk fingerprint (matching
            # MorphingSession._stage_all): fine-tunes riding this lane
            # reuse the one staged base trunk instead of re-staging K
            # identical copies; the share cache is keyed by the lane's
            # trunk fingerprint explicitly in _embed
            spec = InferSpec(
                kind="embed", task=rm.task, col="x", out="f",
                table=_SHARE_TABLE, version=(rm.trunk_fp or rm.version),
                model=rm, batch_size=batch_rows, share=None,
                stats=BatcherStats())
            lane = _Lane(key=key, device=device, batcher=None,  # type: ignore
                         spec=spec, batch_rows=batch_rows,
                         in_dim=int(rm.in_dim or 0))
            lane.heads[rm.task] = self._head_stage(rm.task, rm, backend)
            step = self._share_step(lane, backend)
        lane.batcher = ContinuousBatcher(
            step, batch_size=batch_rows, size_of=lambda p: len(p[1]),
            max_wait_s=self.max_wait_s, idle_wait_s=self.idle_wait_s,
            name=key, policy=self.policy)
        return lane

    # -- lane execution ----------------------------------------------------
    def _legacy_step(self, lane: _Lane, backend: ExecutionBackend):
        """Per-task full-predict step (the pre-share serving path)."""
        def step(payloads: List[Tuple[str, np.ndarray]]) -> List[np.ndarray]:
            arrs = [np.asarray(p, np.float32) for _, p in payloads]
            lens = [len(a) for a in arrs]
            out = np.asarray(
                backend.run_infer(lane.spec, {"x": _stack(arrs)})["y"])
            offs = np.cumsum([0] + lens)
            return [out[a:b] for a, b in zip(offs[:-1], offs[1:])]
        return step

    def _share_step(self, lane: _Lane, backend: ExecutionBackend):
        """Trunk-lane step: batched cache-chain lookup -> single-flight
        dedup -> trunk forward on unique missing rows -> write-back ->
        per-task head stages."""
        # with the ANN tier enabled the lanes consult the whole chain
        # (exact tier first, calibrated nearest-neighbor reuse for the
        # residual misses); otherwise just the exact tier
        share = (self.session.cache_chain
                 if getattr(self.session, "ann", None) is not None
                 else self.session.share)
        use_share = self.session.enable_share

        def step(payloads: List[Tuple[str, np.ndarray]]) -> List[np.ndarray]:
            arrs = [np.asarray(p, np.float32) for _, p in payloads]
            lens = [len(a) for a in arrs]
            X = _stack(arrs, width=lane.in_dim or None)
            n = len(X)
            E = self._embed(lane, backend, share if use_share else None, X)
            offs = np.cumsum([0] + lens)
            outs: List[np.ndarray] = []
            for (task, _), a, b in zip(payloads, offs[:-1], offs[1:]):
                outs.append(lane.heads[task].run(E[a:b]) if b > a
                            else np.zeros(0, np.float32))
            return outs
        return step

    def _embed(self, lane: _Lane, backend: ExecutionBackend,
               share, X: np.ndarray) -> np.ndarray:
        """Embeddings for one coalesced chunk: cache rows are gathered
        (exactly or via the ANN tier's calibrated reuse), unique missing
        rows computed once, results written back. Audited approx hits
        are recomputed exactly, reported via ``record_audit`` and served
        exact — the serving path keeps the tier's radius honest."""
        n = len(X)
        if n == 0:
            return np.zeros((0, 1), np.float32)
        if share is None:
            return np.asarray(
                backend.run_infer(lane.spec, {"x": X})[lane.spec.out])
        look = share.lookup_many(_SHARE_TABLE, lane.key, X,
                                 version=lane.key)
        keys, miss = look.keys, look.miss
        n_miss = int(miss.sum())
        n_approx = len(look.approx_idx)
        # rows that must run the trunk: real misses plus the audit
        # sample of the approximate hits
        need = miss.copy()
        if len(look.audit_idx):
            need[look.audit_idx] = True
        if not need.any():
            with lane.lock:
                lane.share_hits += n - n_approx
                lane.approx_hits += n_approx
            return look.found
        # single-flight dedup: identical in-flight rows (across the
        # coalesced requests of this batch) compute once. The lane's
        # single worker serializes batches, so rows computed here are in
        # the cache before any later batch looks them up.
        need_idx = np.flatnonzero(need)
        uniq, first = np.unique(keys[need_idx], return_index=True)
        comp_idx = need_idx[first]
        computed = np.asarray(
            backend.run_infer(lane.spec, {"x": X[comp_idx]})[lane.spec.out],
            np.float32)
        E = (np.asarray(look.found, np.float32) if look.found is not None
             else np.zeros((n, computed.shape[1]), np.float32))
        fa = 0
        if len(look.audit_idx):
            exact = computed[np.searchsorted(uniq, keys[look.audit_idx])]
            errs = np.linalg.norm(
                E[look.audit_idx].astype(np.float64) - exact, axis=1)
            order = np.argsort(look.approx_idx, kind="stable")
            loc = order[np.searchsorted(look.approx_idx[order],
                                        look.audit_idx)]
            record = getattr(share, "record_audit", None)
            if record is not None:
                record(_SHARE_TABLE, lane.key, lane.key,
                       look.approx_dist[loc], errs)
            ann = getattr(share, "ann", None)
            if ann is not None:
                fa = int((errs > ann.cfg.error_bound).sum())
        # computed[j] embeds uniq[j] (np.unique sorts): scatter back to
        # every duplicate needed row in one searchsorted — audited rows
        # get their exact recomputation, not the approximation
        E[need_idx] = computed[np.searchsorted(uniq, keys[need_idx])]
        share.insert_many(_SHARE_TABLE, lane.key, keys[comp_idx],
                          X[comp_idx], computed, version=lane.key)
        with lane.lock:
            lane.share_hits += n - n_miss - n_approx
            lane.share_misses += n_miss
            lane.approx_hits += n_approx
            lane.false_accepts += fa
            lane.dedup_rows += len(need_idx) - len(comp_idx)
        return E

    # -- request admission -------------------------------------------------
    def resolve_task(self, name: str, X: np.ndarray, y: np.ndarray,
                     **kw) -> None:
        """Resolve a task ahead of traffic (partial-load aware)."""
        with self._lock:
            if name not in self.session.models:
                self.session.resolve_task(name, X, y, **kw)

    def submit(self, sql: str,
               sample: Optional[Tuple[np.ndarray, np.ndarray]] = None, *,
               priority: str = "batch",
               deadline_ms: Optional[float] = None) -> int:
        """Admit one PREDICT statement; returns its request id. The rows
        the statement selects are snapshotted at admission (the window
        the request observed) and coalesced with other requests whose
        tasks resolve to the same trunk.

        With an :class:`AdmissionPolicy` on the server, ``priority``
        (``interactive``/``batch``/``best_effort``) picks the lane queue
        and drain weight, ``deadline_ms`` feeds the deadline-aware row
        budget and the deadline-miss counter, and this call raises
        :class:`Rejected` under backpressure or :class:`CircuitOpen`
        while the lane's breaker is open. The supervisor lives here: a
        tripped breaker past its cooldown is reset on the next submit
        (the lane "restarts" and the request is admitted)."""
        validate_priority(priority)
        task, col, table, preds = self._parse_predict(sql)
        if task not in self.session.models:
            if not self._running:
                raise RuntimeError(
                    "server not started: call start() or use "
                    "'with server:'")
            if sample is None:
                raise RuntimeError(
                    f"task {task} unresolved and no sample given")
            self.resolve_task(task, *sample)
        return self.submit_rows(task, self._rows_for(table, col, preds),
                                priority=priority, deadline_ms=deadline_ms)

    def submit_rows(self, task: str, X: np.ndarray, *,
                    priority: str = "batch",
                    deadline_ms: Optional[float] = None) -> int:
        """Admit pre-selected rows for an already-resolved task — the
        row-level entry the dispatch tier's workers use (the front door
        parsed the SQL and snapshotted the window before shipping the
        rows over). Identical admission semantics to :meth:`submit`:
        priority classes, deadlines, breaker supervision, and
        Rejected/CircuitOpen backpressure."""
        validate_priority(priority)
        if not self._running:
            raise RuntimeError(
                "server not started: call start() or use 'with server:'")
        if task not in self.session.models:
            raise RuntimeError(
                f"task {task} unresolved; resolve_task() it first")
        lane = self._lane_for(task)
        # supervisor: an open breaker whose cooldown elapsed is closed
        # here, so the first post-cooldown submit restarts the lane
        # instead of requiring an operator action
        lane.batcher.reset_breaker()
        req_id = next(self._ids)
        # bookkeeping only after a successful admission (submit raises
        # when racing a stop()); counter writes go under the lane lock
        lane.batcher.submit(Request(
            req_id, (task, np.asarray(X)), priority=priority,
            deadline_s=(deadline_ms / 1000.0
                        if deadline_ms is not None else None)))
        self._task_of[req_id] = task
        with lane.lock:
            lane.requests_by_task[task] = \
                lane.requests_by_task.get(task, 0) + 1
        return req_id

    def result(self, req_id: int,
               timeout: Optional[float] = None) -> ServeResult:
        """Block until the request's batch has executed. Each result is
        retrievable once: returning it releases the server's per-request
        state (long-running services stay memory-bounded)."""
        task = self._task_of[req_id]
        lane = self._lane_of_task[task]
        try:
            scores = lane.batcher.result(req_id, timeout=timeout,
                                         evict=False)
            latency = lane.batcher.latency(req_id)
        except TimeoutError:
            raise                        # still pending: retry result()
        except BaseException:
            lane.batcher.evict(req_id)   # failed: release the slot
            self._task_of.pop(req_id, None)
            raise
        lane.batcher.evict(req_id)
        self._task_of.pop(req_id, None)
        return ServeResult(req_id=req_id, task=task,
                           scores=np.asarray(scores), rows=len(scores),
                           latency_s=latency)

    def predict(self, sql: str,
                sample: Optional[Tuple[np.ndarray, np.ndarray]] = None,
                timeout: Optional[float] = None, *,
                priority: str = "batch",
                deadline_ms: Optional[float] = None) -> ServeResult:
        """submit + result convenience for a single caller thread."""
        return self.result(self.submit(sql, sample=sample,
                                       priority=priority,
                                       deadline_ms=deadline_ms),
                           timeout=timeout)

    # -- telemetry ---------------------------------------------------------
    def stats(self) -> ServerStats:
        st = ServerStats()
        st.devices = self.devices
        lat: List[float] = []
        lat_by_prio: Dict[str, List[float]] = {p: [] for p in PRIORITIES}
        coalesced: List[int] = []
        embed_seconds = 0.0
        with self._lock:
            lanes = list(self._lanes.values())
        st.lanes = len(lanes)
        for lane in lanes:
            lane_lat, lane_sizes = lane.batcher.telemetry()
            for p, samples in lane.batcher.telemetry_by_priority().items():
                lat_by_prio[p].extend(samples)
            h = lane.batcher.health()
            st.rejected += h["rejected"]
            for p, c in h["rejected_by_priority"].items():
                if c:
                    st.rejected_by_priority[p] = \
                        st.rejected_by_priority.get(p, 0) + c
            st.retries += h["retries"]
            st.failed_batches += h["failed_batches"]
            st.deadline_misses += h["deadline_misses"]
            st.deadlines_admitted += h["deadlines_admitted"]
            st.breaker_trips += h["breaker_trips"]
            st.breaker_resets += h["breaker_resets"]
            if h["breaker_open"]:
                st.breaker_open_lanes.append(lane.key)
            st.batch_rows_by_lane[lane.key] = h["batch_rows"]
            st.budget_shrinks += h["budget_shrinks"]
            st.budget_grows += h["budget_grows"]
            with lane.lock:
                served_tasks = list(lane.requests_by_task.items())
                heads = list(lane.heads.values())
                st.share_hits += lane.share_hits
                st.share_misses += lane.share_misses
                st.approx_hits += lane.approx_hits
                st.false_accepts += lane.false_accepts
                st.dedup_rows += lane.dedup_rows
                hits = lane.share_hits + lane.approx_hits
                t = hits + lane.share_misses
                st.share_hit_rate_by_lane[lane.key] = \
                    hits / t if t else 0.0
                st.tasks_by_lane[lane.key] = len(lane.requests_by_task)
            for task, c in served_tasks:
                st.requests += c
                st.requests_by_task[task] = \
                    st.requests_by_task.get(task, 0) + c
            st.batches += len(lane_sizes)
            if heads:                            # share-aware lane
                st.embed_rows += lane.spec.stats.rows
                st.embed_batches += lane.spec.stats.batches
                st.infer_seconds += lane.spec.stats.infer_seconds
                embed_seconds += lane.spec.stats.infer_seconds
                for h in heads:
                    st.rows += h.spec.stats.rows     # every served row
                    st.head_rows += h.spec.stats.rows  # passes one head
                    st.head_batches += h.spec.stats.batches
                    st.infer_seconds += h.spec.stats.infer_seconds
            else:                                # legacy full-predict lane
                st.rows += lane.spec.stats.rows
                st.infer_seconds += lane.spec.stats.infer_seconds
            lat.extend(lane_lat)
            coalesced.extend(lane_sizes)
        if embed_seconds:
            st.mesh_rows_per_s = st.embed_rows / embed_seconds
        if coalesced:
            st.mean_coalesced = float(np.mean(coalesced))
        if lat:
            st.p50_latency_s = float(np.percentile(lat, 50))
            st.p95_latency_s = float(np.percentile(lat, 95))
            st.max_latency_s = float(np.max(lat))
        for p, samples in lat_by_prio.items():
            if samples:
                st.p50_latency_s_by_priority[p] = \
                    float(np.percentile(samples, 50))
                st.p95_latency_s_by_priority[p] = \
                    float(np.percentile(samples, 95))
        # bytes are scoped to tasks actually served through a lane — a
        # shared session's analytics-only resolutions don't belong in
        # serving telemetry
        seen = set()
        for lane in lanes:
            with lane.lock:
                tasks = list(lane.requests_by_task)
            for task in tasks:
                rm = self.session.models.get(task)
                if rm is not None and task not in seen:
                    seen.add(task)
                    st.loaded_bytes += rm.loaded_bytes
                    st.stored_bytes += rm.stored_bytes
                    if rm.is_delta:
                        st.delta_tasks += 1
                        st.delta_loaded_bytes += rm.loaded_bytes
                        st.delta_stored_bytes += rm.delta_bytes
        sstats = self.session.dstore.stats
        st.dedup_pages = sstats.dedup_pages
        st.dedup_bytes_saved = sstats.dedup_bytes_saved
        st.compressed_delta_bytes = sstats.compressed_delta_bytes
        st.quant_error_bound = sstats.quant_error_bound
        return st

    def health(self) -> Dict[str, Dict]:
        """Per-lane robustness snapshot (queue depths, rejections,
        retries, breaker state, current dynamic row budget) keyed by
        lane. The fleet aggregate lives on :meth:`stats`."""
        with self._lock:
            lanes = list(self._lanes.values())
        return {lane.key: lane.batcher.health() for lane in lanes}

    def reset_telemetry(self) -> None:
        """Re-base every telemetry window: latency/batch-size deques,
        share/dedup counters, and per-stage BatcherStats. Percentiles and
        rates from :meth:`stats` then describe only the traffic served
        after the reset (e.g. post-warmup). Pending requests still serve
        normally — only the counters restart."""
        with self._lock:
            lanes = list(self._lanes.values())
        for lane in lanes:
            lane.batcher.reset_telemetry()
            with lane.lock:
                lane.share_hits = lane.share_misses = lane.dedup_rows = 0
                lane.approx_hits = lane.false_accepts = 0
                for task in lane.requests_by_task:
                    lane.requests_by_task[task] = 0
                heads = list(lane.heads.values())
            # fresh sinks: backends read spec.stats per call, so swapping
            # the object re-bases without racing in-flight accumulation
            lane.spec.stats = BatcherStats()
            for h in heads:
                h.spec.stats = BatcherStats()


def _stack(payloads: List[np.ndarray],
           width: Optional[int] = None) -> np.ndarray:
    """Concatenate request payloads, adapting rows to a common width so
    requests over differently-shaped tables can share a batch. With
    ``width`` (the lane trunk's input width) rows are adapted to the
    model's own geometry, which keeps content fingerprints stable across
    batches; otherwise the widest payload wins (the backend re-adapts to
    the model's input width anyway)."""
    arrs = [np.asarray(p, np.float32) for p in payloads]
    if any(a.ndim < 2 for a in arrs):        # non-tabular rows: as-is
        return arrs[0] if len(arrs) == 1 else np.concatenate(arrs)
    if width is None:
        if len(arrs) == 1:
            return arrs[0]
        width = max(a.shape[1] for a in arrs)
    if len(arrs) == 1:
        return adapt_input_width(arrs[0], width)
    return np.concatenate([adapt_input_width(a, width) for a in arrs])
