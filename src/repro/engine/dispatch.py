"""Multi-process dispatch tier: front-door routing to mesh-backed workers.

ROADMAP: "Multi-host dispatch tier (scale past one process)". PR 6
scaled serving across devices *inside* one process (mesh embed lanes);
this module scales past the process boundary: :class:`DispatchServer`
speaks the same ``submit/predict/result/stats/health/stop`` surface as
:class:`MorphingServer` but routes coalesced ``PREDICT`` batches to N
worker processes, each owning its own ``MorphingSession`` — backends,
``BackendPool``/mesh, share cache — spawned via stdlib
``multiprocessing`` (queue transport, no new dependencies). The shape
is modeled on EVA's parallel executor dispatching plan fragments to
remote workers.

Dataflow:

- the **front door** owns a full session on a shared ``DecoupledStore``
  root: it parses the SQL, snapshots the selected rows, resolves tasks
  (persisting models into the shared store so workers can resolve them
  by ``model_id``), and runs one admission
  :class:`~repro.pipeline.batcher.ContinuousBatcher` per *trunk* — the
  same coalescing, priority classes, backpressure and breaker
  supervision the in-process server applies, now in front of the
  process boundary;
- a front lane's coalesced batch becomes a **lease**: its items
  ``(req_id, task, rows, priority, deadline)`` ship to a worker over
  its command queue, results return on the shared results queue, and
  the lease stays outstanding until some worker answers. Worker
  **heartbeats** plus process liveness decide when a worker is dead;
  its outstanding leases re-dispatch to survivors — at-most-once per
  request: the first completed copy of a lease wins, late duplicates
  are counted (``DispatchStats.duplicates_dropped``) and dropped;
- **placement** is staging-aware and cost-driven
  (:class:`PlacementPolicy`): a trunk is resident on as few workers as
  its measured load needs, so K fine-tunes of one base hit one worker's
  shared embed lane. A hot trunk scales out only when the front lane's
  backlog crosses the admission watermark *and* the Eq. 7 staging cost
  is earned back by the Eq. 10/11 throughput gain computed from the
  worker's calibrated :class:`~repro.pipeline.cost.HardwareProfile`;
  idle trunks drain back to one replica (workers unstage via
  ``MorphingServer.unstage_trunk``);
- :class:`DispatchStats` aggregates every worker's ``ServerStats``
  (rows/s, share/approx hits, breaker state) with the dispatch-level
  counters (leases, re-dispatches, duplicates dropped, scale in/out,
  per-worker staged bytes) into one view.

Everything crossing the boundary is picklable by construction:
``ResolvedModel`` heads are module-level callables, ``ServerStats`` is
a plain dataclass, and the typed admission errors carry their fields
through ``__reduce__`` — regression-tested in ``tests/test_dispatch.py``
so a new field can't silently break transport.
"""
from __future__ import annotations

import dataclasses
import itertools
import multiprocessing as mp
import queue as queue_mod
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.engine.config import EngineConfig
from repro.engine.serve import (MorphingServer, ServeResult, ServerStats,
                                _LANE_BATCH_CANDIDATES)
from repro.engine.session import MorphingSession
from repro.pipeline.admission import AdmissionPolicy, validate_priority
from repro.pipeline.batcher import ContinuousBatcher, Request
from repro.pipeline.cost import (HardwareProfile, choose_batch_size,
                                 exec_time, split_profile, trans_cost)


# ---------------------------------------------------------------------------
# Placement policy
# ---------------------------------------------------------------------------

@dataclass
class PlacementPolicy:
    """Staging-aware replica policy for one dispatch tier.

    ``watermark_rows`` is the admission watermark: a trunk is considered
    for scale-out only while its front lane's queued rows are at/above
    it. Crossing the watermark is necessary, not sufficient — with
    ``cost_gated`` (default) the new replica must also *pay for itself*:
    the Eq. 6 drain-time reduction of going from R to R+1 replicas,
    computed on the worker's calibrated HardwareProfile, must exceed the
    Eq. 7 staging cost of moving the trunk plus ``min_gain_s``.

    ``max_replicas`` caps a trunk's replicas (0 = every live worker).
    ``idle_scale_in_s`` of front-lane silence drains a multi-replica
    trunk back to one worker (the extras unstage). ``stage_timeout_s``
    bounds the front door's wait for a worker's staged/unstaged ack.
    """
    watermark_rows: int = 4096
    max_replicas: int = 0
    idle_scale_in_s: float = 5.0
    min_gain_s: float = 0.0
    cost_gated: bool = True
    stage_timeout_s: float = 120.0


# ---------------------------------------------------------------------------
# Aggregated stats
# ---------------------------------------------------------------------------

@dataclass
class DispatchStats:
    """One view over the whole dispatch tier (docs/serving.md "Dispatch
    tier" documents every field)."""
    # tier shape
    workers: int = 0                 # workers spawned
    alive_workers: int = 0           # workers currently alive
    # front-door traffic
    requests: int = 0                # requests admitted at the front door
    rows: int = 0                    # rows returned to callers
    rejected: int = 0                # front-lane admission rejections
    p50_latency_s: float = 0.0       # end-to-end front-door latency
    p95_latency_s: float = 0.0
    # lease / failover accounting
    leases: int = 0                  # batches dispatched (first sends)
    redispatches: int = 0            # leases re-sent after a worker death
    duplicates_dropped: int = 0      # late duplicate lease answers dropped
    worker_deaths: int = 0           # workers declared dead
    # placement
    scale_outs: int = 0              # trunk replicas added under load
    scale_ins: int = 0               # idle replicas drained back
    staged_bytes_by_worker: Dict[int, int] = field(default_factory=dict)
    trunks_by_worker: Dict[int, List[str]] = field(default_factory=dict)
    replicas_by_trunk: Dict[str, int] = field(default_factory=dict)
    # per-worker ServerStats plus their aggregates
    per_worker: Dict[int, ServerStats] = field(default_factory=dict)
    worker_rows: int = 0             # rows scored across all workers
    infer_seconds: float = 0.0       # summed worker compute seconds
    share_hits: int = 0
    share_misses: int = 0
    approx_hits: int = 0
    dedup_rows: int = 0
    embed_rows: int = 0
    retries: int = 0
    failed_batches: int = 0
    breaker_open_lanes: List[str] = field(default_factory=list)

    @property
    def rows_per_second(self) -> float:
        return (self.worker_rows / self.infer_seconds
                if self.infer_seconds else 0.0)

    @property
    def share_hit_rate(self) -> float:
        hits = self.share_hits + self.approx_hits
        t = hits + self.share_misses
        return hits / t if t else 0.0


# ---------------------------------------------------------------------------
# Worker process
# ---------------------------------------------------------------------------

def _worker_main(wid: int, root: str, cfg: EngineConfig, serve_kw: dict,
                 opts: dict, cmd_q, res_q) -> None:
    """Worker entry point (spawn target; must stay module-level so the
    child can import it). Owns a full session + server on the shared
    store root; serves leases in gather threads so the command loop
    stays responsive to stage/stats/stop while batches run."""
    hb_interval_s = float(opts.get("hb_interval_s", 0.25))
    result_timeout_s = float(opts.get("result_timeout_s", 120.0))
    try:
        sess = MorphingSession(root=Path(root), config=cfg)
        server = MorphingServer(session=sess, **serve_kw).start()
    except Exception as e:          # startup failure: report, don't hang
        res_q.put(("ready", wid, None, f"{type(e).__name__}: {e}"))
        return
    res_q.put(("ready", wid, sess.hw, None))
    stop_evt = threading.Event()

    def _heartbeat() -> None:
        while not stop_evt.is_set():
            try:
                res_q.put(("hb", wid, time.time()))
            except Exception:       # queue torn down: front door is gone
                return
            stop_evt.wait(hb_interval_s)

    threading.Thread(target=_heartbeat, daemon=True,
                     name=f"dispatch-hb-{wid}").start()

    def _serve_lease(lease_id: int, items: list) -> None:
        # slots mirror items positionally: ("ok", scores) on success,
        # ("err", exception) for per-request failures — the typed
        # admission errors pickle with their fields intact
        slots: List[Tuple[str, Any]] = [None] * len(items)
        waiting = []
        for i, (req_id, task, X, priority, deadline_ms) in enumerate(items):
            try:
                local = server.submit_rows(task, np.asarray(X),
                                           priority=priority,
                                           deadline_ms=deadline_ms)
                waiting.append((i, local))
            except Exception as e:
                slots[i] = ("err", e)
        for i, local in waiting:
            try:
                out = server.result(local, timeout=result_timeout_s)
                slots[i] = ("ok", np.asarray(out.scores))
            except Exception as e:
                slots[i] = ("err", e)
        res_q.put(("done", wid, lease_id, slots))

    while True:
        try:
            msg = cmd_q.get(timeout=1.0)
        except queue_mod.Empty:
            continue
        except (EOFError, OSError):
            break
        kind = msg[0]
        try:
            if kind == "stage":
                _, task, model_id, spec, in_dim, mode = msg
                try:
                    # the front door may have registered the model after
                    # this worker's catalog loaded: re-read the tables
                    sess.catalog.reload()
                    if task not in sess.registry._tasks:
                        sess.create_task(spec)
                    sample = np.zeros((1, max(int(in_dim or 1), 1)),
                                      np.float32)
                    sess.resolve_task(task, sample, None,
                                      model_id=model_id,
                                      mode=mode or "full")
                    res_q.put(("staged", wid, task, None))
                except Exception as e:
                    res_q.put(("staged", wid, task,
                               f"{type(e).__name__}: {e}"))
            elif kind == "unstage":
                _, trunk, tasks = msg
                ok = server.unstage_trunk(trunk)
                for t in tasks:
                    # drop the resolutions too: scale-in releases the
                    # trunk bytes, not just the staged device state
                    sess.models.pop(t, None)
                res_q.put(("unstaged", wid, trunk, ok))
            elif kind == "batch":
                _, lease_id, items = msg
                threading.Thread(target=_serve_lease,
                                 args=(lease_id, items), daemon=True,
                                 name=f"dispatch-lease-{lease_id}").start()
            elif kind == "stats":
                res_q.put(("stats", wid, server.stats()))
            elif kind == "health":
                res_q.put(("health", wid, server.health()))
            elif kind == "reset":
                server.reset_telemetry()
            elif kind == "fault":
                from repro.training.fault import FaultInjector
                fault_kw = msg[1]
                sess.backends.set_fault_injector(
                    FaultInjector(**fault_kw) if fault_kw else None)
                res_q.put(("fault_set", wid, None))
            elif kind == "stop":
                drain = bool(msg[1]) if len(msg) > 1 else True
                try:
                    server.stop(drain=drain)
                except Exception:
                    pass
                stop_evt.set()
                res_q.put(("stopped", wid))
                break
        except Exception as e:      # a broken command must not kill the
            try:                    # worker loop; report and keep serving
                res_q.put(("worker_error", wid,
                           f"{kind}: {type(e).__name__}: {e}"))
            except Exception:
                break


# ---------------------------------------------------------------------------
# Front-door bookkeeping
# ---------------------------------------------------------------------------

def _payload_rows(p) -> int:
    return max(len(p[2]), 1)


class _Mailbox:
    """Keyed one-slot mailbox for worker acks (staged/stats/health/...).
    The receiver thread posts; request threads wait on their key."""

    def __init__(self):
        self._cv = threading.Condition()
        self._msgs: Dict[Tuple, Any] = {}

    def post(self, msg: tuple) -> None:
        kind, wid = msg[0], msg[1]
        key = (kind, wid)
        if kind in ("staged", "unstaged"):
            key = (kind, wid, msg[2])
        with self._cv:
            self._msgs[key] = msg
            self._cv.notify_all()

    def wait(self, key: Tuple, timeout: float,
             alive=None) -> Optional[tuple]:
        deadline = time.time() + timeout
        with self._cv:
            while key not in self._msgs:
                if alive is not None and not alive():
                    return None
                remaining = deadline - time.time()
                if remaining <= 0:
                    return None
                self._cv.wait(min(remaining, 0.2))
            return self._msgs.pop(key)


@dataclass
class _WorkerHandle:
    wid: int
    proc: Any
    cmd_q: Any
    alive: bool = True
    last_hb: float = 0.0
    ready: threading.Event = field(default_factory=threading.Event)
    ready_err: Optional[str] = None
    hw: Optional[Dict[str, HardwareProfile]] = None
    stage_lock: threading.Lock = field(default_factory=threading.Lock)
    staged_tasks: Set[str] = field(default_factory=set)
    trunks: Dict[str, int] = field(default_factory=dict)   # fp -> bytes
    delta_bytes: Dict[str, int] = field(default_factory=dict)
    inflight_rows: int = 0
    last_stats: Optional[ServerStats] = None
    errors: List[str] = field(default_factory=list)

    @property
    def staged_bytes(self) -> int:
        return sum(self.trunks.values()) + sum(self.delta_bytes.values())


@dataclass
class _Lease:
    lease_id: int
    wid: int
    trunk: str
    items: list
    rows: int
    event: threading.Event = field(default_factory=threading.Event)
    slots: Optional[list] = None
    done: bool = False
    redispatched: int = 0


@dataclass
class _TrunkPlacement:
    trunk: str
    tasks: Set[str] = field(default_factory=set)
    replicas: List[int] = field(default_factory=list)
    last_active: float = 0.0
    scaling: bool = False            # a scale-out is already in flight


@dataclass
class _FrontLane:
    key: str
    batcher: ContinuousBatcher
    batch_rows: int


# ---------------------------------------------------------------------------
# The front door
# ---------------------------------------------------------------------------

class DispatchServer:
    """Front-door server routing coalesced PREDICT batches to worker
    processes. Same surface as :class:`MorphingServer`
    (``submit/submit_rows/predict/result/stats/health/stop`` plus
    context-manager lifecycle); requires a ``model_store='decoupled'``
    session because workers resolve models from the shared store root
    by ``model_id``.

    ``workers`` defaults to ``EngineConfig.workers``. ``worker_backend``
    overrides the workers' backend flavour (the front door's own
    backends never run inference — ``'numpy'`` workers give real
    multi-core scaling on CPU hosts and skip the jax import at spawn).
    Workers auto-calibrate through the on-disk memo
    (``EngineConfig.calib_memo_path``, defaulted to a file under the
    shared root) so N processes pay the two-point probe once.
    """

    def __init__(self, session: Optional[MorphingSession] = None, *,
                 config: Optional[EngineConfig] = None,
                 workers: Optional[int] = None,
                 placement: Optional[PlacementPolicy] = None,
                 policy: Optional[AdmissionPolicy] = None,
                 worker_backend: Optional[str] = None,
                 max_wait_s: float = 0.002, idle_wait_s: float = 0.05,
                 mem_cap_bytes: float = 2e9,
                 heartbeat_s: float = 0.25,
                 heartbeat_timeout_s: float = 2.0,
                 monitor_interval_s: float = 0.2,
                 lease_timeout_s: float = 120.0,
                 stop_timeout_s: float = 30.0,
                 start_timeout_s: float = 120.0,
                 **session_kw):
        if session is None:
            cfg = config or EngineConfig(model_store="decoupled")
            session = MorphingSession(config=cfg, **session_kw)
        self.session = session
        cfg = session.config
        if session.model_store != "decoupled":
            raise ValueError(
                "DispatchServer requires model_store='decoupled': workers "
                "resolve served models from the shared store root")
        self.workers_requested = int(
            workers if workers is not None else cfg.workers)
        if self.workers_requested < 1:
            raise ValueError(
                f"workers must be >= 1, got {self.workers_requested}")
        self.placement = placement or PlacementPolicy()
        self.policy = policy if policy is not None else cfg.policy
        self.max_wait_s = max_wait_s
        self.idle_wait_s = idle_wait_s
        self.mem_cap_bytes = mem_cap_bytes
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.monitor_interval_s = monitor_interval_s
        self.lease_timeout_s = lease_timeout_s
        self.stop_timeout_s = stop_timeout_s
        self.start_timeout_s = start_timeout_s
        # workers inherit the engine config with their own backend
        # flavour, a default retry/breaker policy for their lanes, and
        # the shared calibration memo (first prober writes, rest read)
        self._worker_cfg = dataclasses.replace(
            cfg,
            backend=worker_backend or cfg.backend,
            model_store="decoupled",
            policy=cfg.policy or AdmissionPolicy(),
            calib_memo_path=(cfg.calib_memo_path or
                             str(self.session.root / "hw_calib_memo.json")))
        self._serve_kw = {"max_wait_s": max_wait_s,
                          "idle_wait_s": idle_wait_s,
                          "mem_cap_bytes": mem_cap_bytes,
                          "share_lanes": True,
                          "stop_timeout_s": stop_timeout_s}
        self._worker_opts = {"hb_interval_s": heartbeat_s,
                             "result_timeout_s": lease_timeout_s}
        self._workers: Dict[int, _WorkerHandle] = {}
        self._lanes: Dict[str, _FrontLane] = {}
        self._lane_of_task: Dict[str, _FrontLane] = {}
        self._task_of: Dict[int, str] = {}
        self._placements: Dict[str, _TrunkPlacement] = {}
        self._leases: Dict[int, _Lease] = {}
        self._finished: Set[int] = set()
        self._mail = _Mailbox()
        self._ids = itertools.count()
        self._lease_ids = itertools.count()
        self._lock = threading.Lock()
        self._place_lock = threading.Lock()
        self._res_q = None
        self._recv_thread: Optional[threading.Thread] = None
        self._monitor_thread: Optional[threading.Thread] = None
        self._stopping = threading.Event()   # halts failover/monitor
        self._recv_stop = threading.Event()  # halts the receiver last
        self._running = False
        self._stopped = False
        # counters (under self._lock)
        self._requests = 0
        self._rows_served = 0
        self._lease_count = 0
        self._redispatches = 0
        self._dup_dropped = 0
        self._worker_deaths = 0
        self._scale_outs = 0
        self._scale_ins = 0

    # reuse the in-process server's statement parsing + row snapshot —
    # the front door admits exactly what MorphingServer would
    _parse_predict = MorphingServer._parse_predict
    _rows_for = MorphingServer._rows_for

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "DispatchServer":
        with self._lock:
            if self._running:
                raise RuntimeError("server already started")
            if self._stopped:
                raise RuntimeError("a stopped DispatchServer cannot be "
                                   "restarted; construct a new one")
            self._running = True
        ctx = mp.get_context("spawn")
        self._res_q = ctx.Queue()
        for wid in range(self.workers_requested):
            cmd_q = ctx.Queue()
            proc = ctx.Process(
                target=_worker_main,
                args=(wid, str(self.session.root), self._worker_cfg,
                      self._serve_kw, self._worker_opts, cmd_q,
                      self._res_q),
                daemon=True, name=f"dispatch-worker-{wid}")
            self._workers[wid] = _WorkerHandle(wid=wid, proc=proc,
                                               cmd_q=cmd_q)
        self._recv_thread = threading.Thread(
            target=self._recv_loop, daemon=True, name="dispatch-recv")
        self._recv_thread.start()
        for h in self._workers.values():
            h.proc.start()
        for h in self._workers.values():
            deadline = time.time() + self.start_timeout_s
            while not h.ready.wait(timeout=0.2):
                if not h.proc.is_alive():
                    self.stop(drain=False)
                    raise RuntimeError(
                        f"dispatch worker {h.wid} died during startup "
                        f"(exitcode {h.proc.exitcode})")
                if time.time() > deadline:
                    self.stop(drain=False)
                    raise RuntimeError(
                        f"dispatch worker {h.wid} did not come up within "
                        f"{self.start_timeout_s}s")
            if h.ready_err:
                self.stop(drain=False)
                raise RuntimeError(
                    f"dispatch worker {h.wid} failed to start: "
                    f"{h.ready_err}")
        self._monitor_thread = threading.Thread(
            target=self._monitor_loop, daemon=True, name="dispatch-monitor")
        self._monitor_thread.start()
        return self

    def __enter__(self) -> "DispatchServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> None:
        """Drain the front lanes (dispatching whatever is queued), stop
        every worker, and join the plumbing threads. Idempotent."""
        with self._lock:
            if self._stopped:
                return
            self._stopped = True
            self._running = False
            lanes = list(self._lanes.values())
        timeout = self.stop_timeout_s if timeout is None else timeout
        stuck: List[str] = []
        for lane in lanes:
            try:
                lane.batcher.stop(drain=drain, timeout=timeout)
            except TimeoutError:
                stuck.append(lane.key)
        # failover must not react to the shutdown kills below
        self._stopping.set()
        for h in self._workers.values():
            if h.alive and h.proc.is_alive():
                try:
                    h.cmd_q.put(("stop", drain))
                except Exception:
                    pass
        deadline = time.time() + max(timeout, 5.0)
        for h in self._workers.values():
            h.proc.join(timeout=max(deadline - time.time(), 0.1))
            if h.proc.is_alive():
                h.proc.terminate()
                h.proc.join(timeout=2.0)
            h.alive = False
        self._recv_stop.set()
        for t in (self._monitor_thread, self._recv_thread):
            if t is not None:
                t.join(timeout=2.0)
        for h in self._workers.values():
            try:
                h.cmd_q.close()
                h.cmd_q.cancel_join_thread()
            except Exception:
                pass
        if self._res_q is not None:
            try:
                self._res_q.close()
                self._res_q.cancel_join_thread()
            except Exception:
                pass
        if stuck:
            raise RuntimeError(
                f"front lane worker(s) did not join within {timeout}s: "
                f"{stuck}")

    # -- receiver / monitor ------------------------------------------------
    def _recv_loop(self) -> None:
        while not self._recv_stop.is_set():
            try:
                msg = self._res_q.get(timeout=0.2)
            except queue_mod.Empty:
                continue
            except (EOFError, OSError):
                return
            kind = msg[0]
            if kind == "hb":
                h = self._workers.get(msg[1])
                if h is not None:
                    h.last_hb = time.time()
            elif kind == "done":
                self._complete_lease(msg[2], msg[1], msg[3])
            elif kind == "ready":
                h = self._workers.get(msg[1])
                if h is not None:
                    h.hw = msg[2]
                    h.ready_err = msg[3]
                    h.last_hb = time.time()
                    h.ready.set()
            elif kind == "worker_error":
                h = self._workers.get(msg[1])
                if h is not None:
                    h.errors.append(msg[2])
            else:
                self._mail.post(msg)

    def _monitor_loop(self) -> None:
        while not self._stopping.wait(self.monitor_interval_s):
            now = time.time()
            for h in list(self._workers.values()):
                if not h.alive:
                    continue
                hb_stale = (h.last_hb > 0 and
                            now - h.last_hb > self.heartbeat_timeout_s)
                if not h.proc.is_alive() or hb_stale:
                    self._on_worker_death(h)
            self._maybe_scale_in(now)

    # -- worker failure / lease failover -----------------------------------
    def _on_worker_death(self, h: _WorkerHandle) -> None:
        with self._lock:
            if not h.alive:
                return
            h.alive = False
            self._worker_deaths += 1
            orphans = [ls for ls in self._leases.values()
                       if ls.wid == h.wid and not ls.done]
        with self._place_lock:
            h.staged_tasks.clear()
            h.trunks.clear()
            h.delta_bytes.clear()
            for pl in self._placements.values():
                if h.wid in pl.replicas:
                    pl.replicas.remove(h.wid)
        for lease in orphans:
            try:
                self._redispatch(lease)
            except Exception as e:
                self._fail_lease(lease, RuntimeError(
                    f"worker {h.wid} died and lease {lease.lease_id} "
                    f"could not be re-dispatched: {e}"))

    def _redispatch(self, lease: _Lease) -> None:
        """Re-send a dead worker's lease to a survivor, re-staging the
        trunk where the load moved if no replica survives."""
        with self._place_lock:
            pl = self._placements.get(lease.trunk)
            cands = [w for w in (pl.replicas if pl else [])
                     if self._workers[w].alive]
        if cands:
            wid = cands[0]
        else:
            wid = self._add_replica(lease.trunk, exclude=(lease.wid,))
        with self._lock:
            if lease.done:           # answered while we were re-staging
                return
            lease.wid = wid
            lease.redispatched += 1
            self._redispatches += 1
            self._workers[wid].inflight_rows += lease.rows
        self._workers[wid].cmd_q.put(("batch", lease.lease_id, lease.items))

    def _fail_lease(self, lease: _Lease, err: Exception) -> None:
        with self._lock:
            if lease.done:
                return
            lease.done = True
            lease.slots = [("err", err)] * len(lease.items)
            self._leases.pop(lease.lease_id, None)
            self._finished.add(lease.lease_id)
        lease.event.set()

    def _complete_lease(self, lease_id: int, wid: int, slots: list) -> None:
        with self._lock:
            lease = self._leases.get(lease_id)
            if lease is None or lease.done:
                # a re-dispatched lease answered twice: first copy won
                if lease_id in self._finished:
                    self._dup_dropped += 1
                return
            lease.done = True
            lease.slots = slots
            self._leases.pop(lease_id, None)
            self._finished.add(lease_id)
            h = self._workers.get(lease.wid)
            if h is not None:
                h.inflight_rows = max(h.inflight_rows - lease.rows, 0)
        lease.event.set()

    # -- placement ---------------------------------------------------------
    def _pick_worker(self, exclude: Tuple[int, ...] = ()) -> int:
        """Least-loaded live worker for a new trunk replica: fewest
        staged bytes (Eq. 7 pressure), then fewest in-flight rows."""
        alive = [h for h in self._workers.values()
                 if h.alive and h.wid not in exclude]
        if not alive:
            raise RuntimeError("no live dispatch workers")
        return min(alive, key=lambda h: (h.staged_bytes,
                                         h.inflight_rows, h.wid)).wid

    def _stage_on(self, wid: int, task: str) -> None:
        """Synchronous stage handshake: ship the task spec + model_id,
        wait for the worker's staged ack, record the staging bytes."""
        rm = self.session.models[task]
        h = self._workers[wid]
        with h.stage_lock:
            if task in h.staged_tasks:
                return
            spec = self.session.registry.get(task)
            h.cmd_q.put(("stage", task, rm.model_id, spec,
                         int(rm.in_dim or 1), rm.load_mode))
            msg = self._mail.wait(("staged", wid, task),
                                  self.placement.stage_timeout_s,
                                  alive=lambda: h.alive)
            if msg is None:
                raise RuntimeError(
                    f"worker {wid} did not acknowledge staging task "
                    f"{task!r} (dead or wedged)")
            if msg[3] is not None:
                raise RuntimeError(
                    f"worker {wid} failed to stage task {task!r}: "
                    f"{msg[3]}")
            with self._place_lock:
                h.staged_tasks.add(task)
                trunk = rm.trunk_fp or rm.version
                # the shared trunk's bytes count once per (worker,
                # trunk); each fine-tune adds only its delta bytes
                trunk_bytes = max(int(rm.stored_bytes) -
                                  int(rm.delta_bytes), 0)
                prev = h.trunks.get(trunk, 0)
                h.trunks[trunk] = max(prev, trunk_bytes)
                if rm.is_delta and rm.delta_bytes:
                    h.delta_bytes[task] = int(rm.delta_bytes)

    def _add_replica(self, trunk: str,
                     exclude: Tuple[int, ...] = ()) -> int:
        """Stage every task riding ``trunk`` onto a fresh worker and
        register it as a replica. Returns the worker id."""
        with self._place_lock:
            pl = self._placements.setdefault(
                trunk, _TrunkPlacement(trunk=trunk,
                                       last_active=time.time()))
            tasks = sorted(pl.tasks)
            exclude = tuple(exclude) + tuple(pl.replicas)
        wid = self._pick_worker(exclude=exclude)
        for task in tasks:
            self._stage_on(wid, task)
        with self._place_lock:
            if wid not in pl.replicas:
                pl.replicas.append(wid)
        return wid

    def _ensure_placed(self, trunk: str, task: str) -> None:
        """First-touch placement: a trunk starts on exactly one worker
        (K fine-tunes of one base share that worker's embed lane until
        load justifies replication)."""
        with self._place_lock:
            pl = self._placements.setdefault(
                trunk, _TrunkPlacement(trunk=trunk,
                                       last_active=time.time()))
            pl.tasks.add(task)
            replicas = [w for w in pl.replicas if self._workers[w].alive]
            need: List[int] = [w for w in replicas
                               if task not in
                               self._workers[w].staged_tasks]
            fresh = not replicas
        if fresh:
            self._add_replica(trunk)
        else:
            for wid in need:
                self._stage_on(wid, task)

    def _scale_out_pays(self, trunk: str, backlog_rows: int,
                        replicas: int) -> bool:
        """Eq. 7 vs Eq. 10/11 on the worker's calibrated profile: does
        splitting the backlog over one more replica save more drain time
        than staging the trunk there costs?"""
        if not self.placement.cost_gated:
            return True
        with self._place_lock:
            pl = self._placements.get(trunk)
            task = next(iter(pl.tasks)) if pl and pl.tasks else None
        rm = self.session.models.get(task) if task else None
        if rm is None:
            return True
        hw = None
        for h in self._workers.values():   # workers are homogeneous
            if h.alive and h.hw:
                hw = h.hw
                break
        drain = exec_time(rm.profile, int(backlog_rows), "host", hw)
        gain = drain * (1.0 / max(replicas, 1) - 1.0 / (replicas + 1))
        stage = trans_cost(rm.profile, 0, "host", hw)
        return gain > stage + self.placement.min_gain_s

    def _maybe_scale_out(self, trunk: str, lane: _FrontLane) -> None:
        backlog = lane.batcher.queued_units
        if backlog < max(self.placement.watermark_rows, 1):
            return
        with self._place_lock:
            pl = self._placements.get(trunk)
            if pl is None:
                return
            live = [w for w in pl.replicas if self._workers[w].alive]
            alive_total = sum(1 for h in self._workers.values() if h.alive)
            cap = self.placement.max_replicas or alive_total
            if not live or len(live) >= min(cap, alive_total):
                return
            if pl.scaling:            # one scale-out in flight per trunk:
                return                # concurrent submits must not stack
            pl.scaling = True
            replicas = len(live)
        try:
            if not self._scale_out_pays(trunk, backlog, replicas):
                return
            try:
                self._add_replica(trunk)
            except RuntimeError:
                return                # no spare live worker: stay put
            with self._lock:
                self._scale_outs += 1
        finally:
            with self._place_lock:
                pl.scaling = False

    def _maybe_scale_in(self, now: float) -> None:
        with self._place_lock:
            placements = list(self._placements.values())
        for pl in placements:
            with self._place_lock:
                live = [w for w in pl.replicas if self._workers[w].alive]
                idle_for = now - pl.last_active
            if len(live) <= 1:
                continue
            if idle_for < self.placement.idle_scale_in_s:
                continue
            lane = self._lanes.get(pl.trunk)
            if lane is not None and (lane.batcher.queued_units or
                                     lane.batcher.pending):
                continue
            with self._lock:
                outstanding = any(ls.trunk == pl.trunk and not ls.done
                                  for ls in self._leases.values())
            if outstanding:
                continue
            for wid in live[1:]:     # drain back to a single replica
                self._unstage_on(wid, pl)

    def _unstage_on(self, wid: int, pl: _TrunkPlacement) -> None:
        h = self._workers[wid]
        with self._place_lock:
            tasks = sorted(pl.tasks)
        try:
            h.cmd_q.put(("unstage", pl.trunk, tasks))
        except Exception:
            return
        self._mail.wait(("unstaged", wid, pl.trunk),
                        self.placement.stage_timeout_s,
                        alive=lambda: h.alive)
        with self._place_lock:
            if wid in pl.replicas:
                pl.replicas.remove(wid)
            for task in tasks:
                h.staged_tasks.discard(task)
                h.delta_bytes.pop(task, None)
            h.trunks.pop(pl.trunk, None)
        with self._lock:
            self._scale_ins += 1

    def prestage(self, task: str,
                 replicas: Optional[int] = None) -> List[int]:
        """Explicitly stage a resolved task's trunk on ``replicas``
        workers (default: all live ones) ahead of traffic — the warm
        path benchmarks and latency-critical deployments use to skip
        the organic watermark ramp. Returns the replica worker ids."""
        rm = self.session.models[task]
        trunk = rm.trunk_fp or rm.version
        self._ensure_placed(trunk, task)
        want = (sum(1 for h in self._workers.values() if h.alive)
                if replicas is None else int(replicas))
        while True:
            with self._place_lock:
                pl = self._placements[trunk]
                have = [w for w in pl.replicas if self._workers[w].alive]
            if len(have) >= want:
                return have
            try:
                self._add_replica(trunk)
            except RuntimeError:
                return have

    # -- front lanes -------------------------------------------------------
    def _front_step(self, key: str):
        def step(payloads: List[tuple]) -> List[Any]:
            with self._place_lock:
                pl = self._placements.get(key)
                if pl is not None:
                    pl.last_active = time.time()
                replicas = [w for w in (pl.replicas if pl else [])
                            if self._workers[w].alive]
            if not replicas:
                replicas = [self._add_replica(key)]
            parts = self._split(payloads, len(replicas))
            leases = []
            for wid, sub in zip(replicas, parts):
                if sub:
                    leases.append(self._dispatch(key, wid, sub))
            deadline = time.time() + self.lease_timeout_s
            for lease in leases:
                if not lease.event.wait(
                        max(deadline - time.time(), 0.001)):
                    self._fail_lease(lease, TimeoutError(
                        f"lease {lease.lease_id} on trunk {key} "
                        f"unanswered after {self.lease_timeout_s}s"))
            out_of: Dict[int, Any] = {}
            for lease in leases:
                for item, slot in zip(lease.items, lease.slots):
                    status, value = slot
                    out_of[item[0]] = value
            return [out_of[p[0]] for p in payloads]
        return step

    @staticmethod
    def _split(payloads: List[tuple], n: int) -> List[List[tuple]]:
        """Row-balanced partition of a coalesced batch across replicas
        (largest requests placed first onto the lightest part)."""
        parts: List[List[tuple]] = [[] for _ in range(n)]
        load = [0] * n
        for p in sorted(payloads, key=lambda p: -len(p[2])):
            i = load.index(min(load))
            parts[i].append(p)
            load[i] += max(len(p[2]), 1)
        return parts

    def _dispatch(self, trunk: str, wid: int, items: List[tuple]) -> _Lease:
        lease = _Lease(lease_id=next(self._lease_ids), wid=wid,
                       trunk=trunk, items=items,
                       rows=sum(len(p[2]) for p in items))
        with self._lock:
            self._leases[lease.lease_id] = lease
            self._lease_count += 1
            self._workers[wid].inflight_rows += lease.rows
        self._workers[wid].cmd_q.put(("batch", lease.lease_id, items))
        return lease

    def _front_lane(self, task: str) -> _FrontLane:
        rm = self.session.models[task]
        key = rm.trunk_fp or rm.version
        lane = self._lanes.get(key)
        if lane is None:
            with self._lock:
                lane = self._lanes.get(key)
                if lane is None:
                    embed_prof, _ = split_profile(rm.profile, rm.head_dim)
                    rows = choose_batch_size(
                        embed_prof, "host",
                        candidates=_LANE_BATCH_CANDIDATES,
                        mem_cap_bytes=self.mem_cap_bytes,
                        hw=self.session.hw)
                    # the front lane feeds every replica: scale the
                    # Eq. 11 budget by the worker count so one coalesced
                    # batch can saturate the whole tier
                    rows = int(rows) * max(self.workers_requested, 1)
                    lane = _FrontLane(
                        key=key, batch_rows=rows,
                        batcher=ContinuousBatcher(
                            self._front_step(key), batch_size=rows,
                            max_wait_s=self.max_wait_s,
                            idle_wait_s=self.idle_wait_s,
                            size_of=_payload_rows,
                            name=f"dispatch:{key}", policy=self.policy))
                    if self._running:
                        lane.batcher.start()
                    self._lanes[key] = lane
        self._lane_of_task[task] = lane
        self._ensure_placed(key, task)
        return lane

    # -- request surface ---------------------------------------------------
    def resolve_task(self, name: str, X, y, **kw) -> None:
        """Resolve into the *shared* store (workers stage from it)."""
        with self._lock:
            if name not in self.session.models:
                self.session.resolve_task(name, X, y, **kw)

    def submit(self, sql: str,
               sample: Optional[Tuple[np.ndarray, np.ndarray]] = None, *,
               priority: str = "batch",
               deadline_ms: Optional[float] = None) -> int:
        """Admit one PREDICT statement (same contract as
        :meth:`MorphingServer.submit`: snapshot at admission, typed
        ``Rejected``/``CircuitOpen`` backpressure from the front lane).
        Crossing the placement watermark may scale the task's trunk out
        to another worker before this call returns."""
        validate_priority(priority)
        task, col, table, preds = self._parse_predict(sql)
        if task not in self.session.models:
            if not self._running:
                raise RuntimeError("server not started: call start() or "
                                   "use 'with server:'")
            if sample is None:
                raise RuntimeError(
                    f"task {task} unresolved and no sample given")
            self.resolve_task(task, *sample)
        return self.submit_rows(task, self._rows_for(table, col, preds),
                                priority=priority, deadline_ms=deadline_ms)

    def submit_rows(self, task: str, X: np.ndarray, *,
                    priority: str = "batch",
                    deadline_ms: Optional[float] = None) -> int:
        validate_priority(priority)
        if not self._running:
            raise RuntimeError(
                "server not started: call start() or use 'with server:'")
        if task not in self.session.models:
            raise RuntimeError(
                f"task {task} unresolved; resolve_task() it first")
        X = np.asarray(X)
        lane = self._front_lane(task)
        lane.batcher.reset_breaker()
        req_id = next(self._ids)
        lane.batcher.submit(Request(
            req_id, (req_id, task, X, priority, deadline_ms),
            priority=priority,
            deadline_s=(deadline_ms / 1000.0
                        if deadline_ms is not None else None)))
        self._task_of[req_id] = task
        with self._lock:
            self._requests += 1
        rm = self.session.models[task]
        self._maybe_scale_out(rm.trunk_fp or rm.version, lane)
        return req_id

    def result(self, req_id: int,
               timeout: Optional[float] = None) -> ServeResult:
        """Block for the request's scores. Worker-side failures surface
        here with their original typed exception (Rejected /
        RequestError / CircuitOpen cross the process boundary with
        fields intact)."""
        task = self._task_of[req_id]
        lane = self._lane_of_task[task]
        try:
            out = lane.batcher.result(req_id, timeout=timeout,
                                      evict=False)
            latency = lane.batcher.latency(req_id)
        except TimeoutError:
            raise
        except BaseException:
            lane.batcher.evict(req_id)
            self._task_of.pop(req_id, None)
            raise
        lane.batcher.evict(req_id)
        self._task_of.pop(req_id, None)
        if isinstance(out, BaseException):
            raise out
        scores = np.asarray(out)
        with self._lock:
            self._rows_served += len(scores)
        return ServeResult(req_id=req_id, task=task, scores=scores,
                           rows=len(scores), latency_s=latency)

    def predict(self, sql: str,
                sample: Optional[Tuple[np.ndarray, np.ndarray]] = None,
                timeout: Optional[float] = None, *,
                priority: str = "batch",
                deadline_ms: Optional[float] = None) -> ServeResult:
        return self.result(self.submit(sql, sample=sample,
                                       priority=priority,
                                       deadline_ms=deadline_ms),
                           timeout=timeout)

    # -- telemetry ---------------------------------------------------------
    def stats(self, timeout: float = 10.0) -> DispatchStats:
        """Aggregate the tier: dispatch counters + per-worker
        ``ServerStats`` (dead workers contribute their last report)."""
        st = DispatchStats()
        with self._lock:
            handles = list(self._workers.values())
            st.workers = len(handles)
            st.requests = self._requests
            st.rows = self._rows_served
            st.leases = self._lease_count
            st.redispatches = self._redispatches
            st.duplicates_dropped = self._dup_dropped
            st.worker_deaths = self._worker_deaths
            st.scale_outs = self._scale_outs
            st.scale_ins = self._scale_ins
            lanes = list(self._lanes.values())
        for h in handles:
            if h.alive:
                try:
                    h.cmd_q.put(("stats",))
                except Exception:
                    pass
        for h in handles:
            if h.alive:
                msg = self._mail.wait(("stats", h.wid), timeout,
                                      alive=lambda h=h: h.alive)
                if msg is not None:
                    h.last_stats = msg[2]
                st.alive_workers += 1
            st.staged_bytes_by_worker[h.wid] = h.staged_bytes
            st.trunks_by_worker[h.wid] = sorted(h.trunks)
            ws = h.last_stats
            if ws is not None:
                st.per_worker[h.wid] = ws
                st.worker_rows += ws.rows
                st.infer_seconds += ws.infer_seconds
                st.share_hits += ws.share_hits
                st.share_misses += ws.share_misses
                st.approx_hits += ws.approx_hits
                st.dedup_rows += ws.dedup_rows
                st.embed_rows += ws.embed_rows
                st.retries += ws.retries
                st.failed_batches += ws.failed_batches
                st.breaker_open_lanes.extend(
                    f"w{h.wid}:{k}" for k in ws.breaker_open_lanes)
        with self._place_lock:
            for trunk, pl in self._placements.items():
                st.replicas_by_trunk[trunk] = sum(
                    1 for w in pl.replicas if self._workers[w].alive)
        lat: List[float] = []
        for lane in lanes:
            lane_lat, _ = lane.batcher.telemetry()
            lat.extend(lane_lat)
            st.rejected += lane.batcher.health()["rejected"]
        if lat:
            st.p50_latency_s = float(np.percentile(lat, 50))
            st.p95_latency_s = float(np.percentile(lat, 95))
        return st

    def health(self) -> Dict[str, Dict]:
        """Front-lane health (same schema as ``MorphingServer.health``,
        keyed ``lane:<trunk>``) plus per-worker liveness rows."""
        out: Dict[str, Dict] = {}
        with self._lock:
            lanes = list(self._lanes.items())
        for key, lane in lanes:
            out[f"lane:{key}"] = lane.batcher.health()
        now = time.time()
        for wid, h in self._workers.items():
            out[f"worker:{wid}"] = {
                "alive": bool(h.alive and h.proc.is_alive()),
                "pid": h.proc.pid,
                "heartbeat_age_s": ((now - h.last_hb)
                                    if h.last_hb else None),
                "staged_trunks": sorted(h.trunks),
                "staged_tasks": sorted(h.staged_tasks),
                "inflight_rows": h.inflight_rows,
                "errors": list(h.errors),
            }
        return out

    def reset_telemetry(self) -> None:
        """Clear latency windows + rate counters on the front lanes and
        every live worker (placement/failover counters are retained)."""
        with self._lock:
            lanes = list(self._lanes.values())
            self._requests = 0
            self._rows_served = 0
        for lane in lanes:
            lane.batcher.reset_telemetry()
        for h in self._workers.values():
            if h.alive:
                try:
                    h.cmd_q.put(("reset",))
                except Exception:
                    pass

    # -- chaos hooks -------------------------------------------------------
    def inject_fault(self, wid: int,
                     fault_kw: Optional[dict]) -> None:
        """Arm (or clear, with None) a ``FaultInjector`` on one worker's
        backends — the test/chaos-bench hook for exercising worker-side
        retry and failover without killing the process."""
        h = self._workers[wid]
        h.cmd_q.put(("fault", fault_kw))
        self._mail.wait(("fault_set", wid), 10.0, alive=lambda: h.alive)

    def kill_worker(self, wid: int) -> None:
        """Hard-kill one worker process (failover tests: SIGTERM, no
        drain). The monitor declares it dead and re-dispatches its
        leases to survivors."""
        h = self._workers[wid]
        h.proc.terminate()
