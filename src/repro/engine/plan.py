"""Logical plan IR + optimizer for the task-centric query engine.

A :class:`LogicalPlan` is an ordered chain of operators over one table
(the SQL subset the engine speaks is single-table):

    scan -> [filter|project|embed|predict]* -> [agg]

The optimizer runs three passes before lowering to a `repro.pipeline.Dag`:

1. **Predicate pushdown** — filters that only reference base columns are
   moved below `predict`/`embed` nodes so inference never runs on rows a
   WHERE clause would discard.
2. **Embed insertion** (paper §5.1 pre-embedding) — each `predict` is
   split into an `embed` node (the expensive feature extraction, routed
   through :class:`~repro.pipeline.share.VectorShareCache` so repeated
   queries over the same data reuse stored vectors) and a cheap head-only
   `predict`.
3. **Placement + batch annotation** (paper Eq. 10/11) — each inference
   node is annotated with the cost-model device and batch size; the
   executor is a pure runtime and only reads the annotations.

Lowering (:func:`compile_plan`) binds operator closures: `embed` nodes go
through the share cache with a :class:`~repro.pipeline.batcher.WindowBatcher`
inside (window aggregation -> one batched device call), `filter` nodes
evaluate conjunctive predicates, and the final `agg` is *not* streamed —
the session applies it after chunks are concatenated so grouped results
are exact under chunked execution.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.pipeline.backend import InferSpec, default_host_backend
from repro.pipeline.batcher import BatcherStats
from repro.pipeline.cost import (HardwareProfile, OpProfile,
                                 choose_batch_size, choose_device)
from repro.pipeline.dag import Dag, Node
from repro.pipeline.operators import Batch, filter_op

# predicate operators for conjunctive WHERE clauses
_CMP: Dict[str, Callable[[np.ndarray, Any], np.ndarray]] = {
    ">": lambda c, v: c > v,
    ">=": lambda c, v: c >= v,
    "<": lambda c, v: c < v,
    "<=": lambda c, v: c <= v,
    "=": lambda c, v: c == v,
    "!=": lambda c, v: c != v,
}


@dataclass
class PlanNode:
    op: str                      # scan | filter | project | embed
    #                            # | predict | agg | sort | limit
    #                            # | index_scan
    args: Dict[str, Any] = field(default_factory=dict)

    def describe(self) -> str:
        a = self.args
        if self.op == "scan":
            return f"scan({a['table']})"
        if self.op == "index_scan":
            return (f"index_scan({a['table']}.{a['col']} "
                    f"top-{a['k']} via cache chain)")
        if self.op == "sort":
            d = "ASC" if a.get("ascending") else "DESC"
            return f"sort(SIMILARITY({a['col']}) {d})"
        if self.op == "limit":
            return f"limit({a['k']})"
        if self.op == "filter":
            preds = " AND ".join(f"{c}{o}{v!r}" for c, o, v in a["preds"])
            return f"filter({preds})"
        if self.op == "project":
            return f"project({', '.join(a['cols'])})"
        if self.op == "embed":
            dev = a.get("device", "?")
            bs = a.get("batch_size", "?")
            return (f"embed({a['task']}.{a['col']} -> {a['out']} "
                    f"@{dev} b={bs} shared)")
        if self.op == "predict":
            dev = a.get("device", "?")
            head = " head" if a.get("head_only") else ""
            return f"predict({a['task']}({a['col']}) -> {a['out']} @{dev}{head})"
        if self.op == "agg":
            g = a.get("group_by")
            s = ", ".join(f"{agg}({c})" for c, agg, _ in a["specs"])
            return f"agg({s}{' GROUP BY ' + g if g else ''})"
        return self.op


@dataclass
class LogicalPlan:
    nodes: List[PlanNode] = field(default_factory=list)

    # -- builder ---------------------------------------------------------
    @staticmethod
    def scan(table: str) -> "LogicalPlan":
        return LogicalPlan([PlanNode("scan", {"table": table})])

    def filter(self, preds: Sequence[Tuple[str, str, Any]]) -> "LogicalPlan":
        self.nodes.append(PlanNode("filter", {"preds": list(preds)}))
        return self

    def project(self, cols: Sequence[str]) -> "LogicalPlan":
        self.nodes.append(PlanNode("project", {"cols": list(cols)}))
        return self

    def predict(self, task: str, col: str,
                out: Optional[str] = None) -> "LogicalPlan":
        self.nodes.append(PlanNode("predict", {
            "task": task, "col": col, "out": out or "_score"}))
        return self

    def agg(self, group_by: Optional[str],
            specs: Sequence[Tuple[str, str, str]]) -> "LogicalPlan":
        self.nodes.append(PlanNode("agg", {"group_by": group_by,
                                           "specs": list(specs)}))
        return self

    def order_by_similarity(self, col: str, query: Any,
                            ascending: bool = False,
                            drop_col: Optional[str] = None
                            ) -> "LogicalPlan":
        """Rank rows by nearness of ``col`` to ``query`` (a vector or a
        text string). Like `agg`, sorting is applied by the session over
        the concatenated stream, not per chunk. ``drop_col`` marks a
        column carried only for ordering (dropped from the output)."""
        self.nodes.append(PlanNode("sort", {
            "col": col, "query": query, "ascending": ascending,
            "drop_col": drop_col}))
        return self

    def limit(self, k: int) -> "LogicalPlan":
        self.nodes.append(PlanNode("limit", {"k": int(k)}))
        return self

    # -- introspection ---------------------------------------------------
    @property
    def table(self) -> str:
        return self.nodes[0].args["table"]

    def describe(self) -> str:
        return " -> ".join(n.describe() for n in self.nodes)

    def ops(self) -> List[str]:
        return [n.op for n in self.nodes]


# ---------------------------------------------------------------------------
# Optimizer passes
# ---------------------------------------------------------------------------

def _produced_columns(node: PlanNode) -> List[str]:
    if node.op in ("embed", "predict"):
        return [node.args["out"]]
    return []


def push_down_filters(plan: LogicalPlan) -> LogicalPlan:
    """Move filters below embed/predict nodes whose outputs they don't
    reference (classic predicate pushdown: don't infer on rows WHERE
    would drop)."""
    nodes = list(plan.nodes)
    moved = True
    while moved:
        moved = False
        for i in range(1, len(nodes)):
            if nodes[i].op != "filter":
                continue
            above = nodes[i - 1]
            if above.op not in ("embed", "predict", "project"):
                continue
            pred_cols = {c for c, _, _ in nodes[i].args["preds"]}
            if above.op == "project":
                # projection only narrows columns; filter needs them upstream
                if not pred_cols <= set(above.args["cols"]):
                    continue
            elif pred_cols & set(_produced_columns(above)):
                continue  # filter reads the inference output: can't move
            nodes[i - 1], nodes[i] = nodes[i], nodes[i - 1]
            moved = True
    plan.nodes = nodes
    return plan


def insert_embeds(plan: LogicalPlan) -> LogicalPlan:
    """Split each full `predict` into `embed` (expensive features, served
    through the vector-share cache) + head-only `predict`."""
    out: List[PlanNode] = []
    for node in plan.nodes:
        if node.op == "predict" and not node.args.get("head_only"):
            task, col = node.args["task"], node.args["col"]
            emb_col = f"__emb_{task}_{col}"
            out.append(PlanNode("embed", {
                "task": task, "col": col, "out": emb_col}))
            out.append(PlanNode("predict", {
                "task": task, "col": emb_col, "out": node.args["out"],
                "head_only": True}))
        else:
            out.append(node)
    plan.nodes = out
    return plan


def annotate_plan(plan: LogicalPlan, profiles: Dict[str, OpProfile],
                  nrows_hint: int = 1024, devices=("host", "tpu"),
                  mem_cap_bytes: float = 2e9,
                  hw: Optional[Dict[str, HardwareProfile]] = None
                  ) -> LogicalPlan:
    """Plan-time device placement (Eq. 10) and batch-size selection
    (Eq. 11). ``profiles`` maps task name -> OpProfile of the resolved
    model; ``hw`` supplies calibrated hardware profiles (measured from
    the live backends) that override the spec-sheet defaults. Head-only
    predicts are O(rows) host work."""
    for node in plan.nodes:
        if node.op == "embed" or (node.op == "predict"
                                  and not node.args.get("head_only")):
            prof = profiles.get(node.args["task"])
            if prof is None:
                node.args.setdefault("device", "host")
                node.args.setdefault("batch_size", 32)
                continue
            dev = choose_device(prof, nrows_hint, devices, hw)
            node.args["device"] = dev
            node.args["batch_size"] = choose_batch_size(
                prof, dev, mem_cap_bytes=mem_cap_bytes, hw=hw)
        elif node.op == "predict":
            node.args["device"] = "host"
    return plan


def lower_similarity(plan: LogicalPlan) -> LogicalPlan:
    """Serve ``ORDER BY SIMILARITY(...) LIMIT k`` straight from the
    share-cache chain: when the plan has no filter or aggregate and
    wants the nearest rows first, the scan is replaced by an
    ``index_scan`` node that scores the whole table through the cache
    tiers (warm cache = exact/ANN gather, zero trunk rows) and feeds
    only the k nearest rows to the rest of the plan."""
    ops = plan.ops()
    if "sort" not in ops or "limit" not in ops:
        return plan
    if "filter" in ops or "agg" in ops:
        # predicates/aggregates must see every surviving row before the
        # top-k cut; fall back to the post-stream sort + limit
        return plan
    sort = next(n for n in plan.nodes if n.op == "sort")
    if sort.args.get("ascending"):
        return plan                  # fast path is nearest-first only
    lim = next(n for n in plan.nodes if n.op == "limit")
    col = sort.args["col"]
    # an embed/predict consuming the column scopes similarity to that
    # task's trunk embedding space (the session resolves the model)
    task = next((n.args["task"] for n in plan.nodes
                 if n.op in ("embed", "predict")
                 and n.args.get("col") == col), None)
    idx = PlanNode("index_scan", {
        "table": plan.table, "col": col, "query": sort.args["query"],
        "k": int(lim.args["k"]), "task": task,
        "drop_col": sort.args.get("drop_col")})
    plan.nodes = [idx] + [n for n in plan.nodes[1:]
                          if n.op not in ("sort", "limit")]
    return plan


def optimize(plan: LogicalPlan, profiles: Dict[str, OpProfile],
             nrows_hint: int = 1024, devices=("host", "tpu"),
             hw: Optional[Dict[str, HardwareProfile]] = None) -> LogicalPlan:
    plan = push_down_filters(plan)
    plan = insert_embeds(plan)
    # pushdown again: embed insertion may leave a filter above an embed
    plan = push_down_filters(plan)
    plan = lower_similarity(plan)
    return annotate_plan(plan, profiles, nrows_hint, devices, hw=hw)


# ---------------------------------------------------------------------------
# Lowering: LogicalPlan -> pipeline Dag
# ---------------------------------------------------------------------------

@dataclass
class CompileContext:
    """Runtime bindings the lowered DAG closes over."""
    models: Dict[str, Any]                  # task -> ResolvedModel
    share: Optional[Any] = None             # VectorShareCache
    batcher_stats: Dict[str, BatcherStats] = field(default_factory=dict)
    share_version_of: Dict[str, str] = field(default_factory=dict)


def _make_pred(preds: Sequence[Tuple[str, str, Any]]):
    def pred(b: Batch) -> np.ndarray:
        mask = None
        for col, op, val in preds:
            m = _CMP[op](b[col], val)
            mask = m if mask is None else (mask & m)
        return mask
    return pred


def _infer_node(op_id: str, kind: str, spec: InferSpec,
                device: str, cost_hint: float) -> Node:
    """Build an inference Node: the InferSpec in ``meta`` is what a
    registered backend executes natively; ``fn`` is the host fallback
    (same spec through the singleton numpy backend) for executors built
    without a registry."""
    node = Node(op_id, kind,
                fn=lambda b, _s=spec: default_host_backend().run_infer(_s, b),
                cost_hint=cost_hint, device=device)
    node.meta["infer"] = spec
    return node


def compile_plan(plan: LogicalPlan, ctx: CompileContext,
                 workers_hint: int = 4) -> Tuple[Dag, str, str,
                                                 Optional[PlanNode]]:
    """Lower to a Dag. Returns (dag, source_id, sink_id, agg_node);
    ``agg_node`` (if any) is applied by the caller *after* chunked
    results are concatenated, so grouped aggregates stay exact."""
    dag = Dag()
    table = plan.table
    dag.add(Node(table, "scan"))
    prev = table
    agg_node: Optional[PlanNode] = None
    counters: Dict[str, int] = {}

    def fresh(opname: str) -> str:
        counters[opname] = counters.get(opname, 0) + 1
        n = counters[opname]
        return opname if n == 1 else f"{opname}{n}"

    for node in plan.nodes[1:]:
        if node.op == "agg":
            agg_node = node
            continue
        if node.op == "filter":
            op_id = fresh("filter")
            pred = _make_pred(node.args["preds"])
            dag.add(Node(op_id, "filter",
                         fn=(lambda p: lambda b: filter_op(b, p))(pred)),
                    deps=(prev,))
        elif node.op == "project":
            op_id = fresh("project")
            cols = list(node.args["cols"])
            dag.add(Node(op_id, "project",
                         fn=(lambda cs: lambda b: {k: b[k] for k in cs
                                                   if k in b})(cols)),
                    deps=(prev,))
        elif node.op == "embed":
            op_id = fresh("embed")
            task = node.args["task"]
            spec = InferSpec(
                kind="embed", task=task, col=node.args["col"],
                out=node.args["out"], table=table,
                version=ctx.share_version_of.get(task, "v1"),
                model=ctx.models[task],
                batch_size=int(node.args.get("batch_size", 32)),
                share=ctx.share,
                stats=ctx.batcher_stats.setdefault(task, BatcherStats()))
            dag.add(_infer_node(op_id, "embed", spec, cost_hint=8.0,
                                device=node.args.get("device", "host")),
                    deps=(prev,))
        elif node.op == "predict":
            op_id = fresh("predict")
            task = node.args["task"]
            model = ctx.models[task]
            col, out = node.args["col"], node.args["out"]
            if node.args.get("head_only"):
                # cheap O(rows) score head: stays a host closure
                def pred_fn(b, _c=col, _o=out, _m=model):
                    res = dict(b)
                    res[_o] = _m.head(b[_c])
                    return res
                dag.add(Node(op_id, "predict", fn=pred_fn, cost_hint=1.0,
                             device=node.args.get("device", "host")),
                        deps=(prev,))
            else:
                spec = InferSpec(
                    kind="predict", task=task, col=col, out=out,
                    table=table,
                    version=ctx.share_version_of.get(task, "v1"),
                    model=model,
                    batch_size=int(node.args.get("batch_size", 32)),
                    share=None,
                    stats=ctx.batcher_stats.setdefault(task,
                                                       BatcherStats()))
                dag.add(_infer_node(op_id, "predict", spec, cost_hint=8.0,
                                    device=node.args.get("device", "host")),
                        deps=(prev,))
        else:
            raise ValueError(f"cannot lower plan op {node.op}")
        prev = op_id
    return dag, table, prev, agg_node
