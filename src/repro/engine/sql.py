"""MiniSQL: the task-centric SQL surface (paper §2.1, Table 1), promoted
from the original `examples/` regex demo into a real tokenizer + recursive
descent parser that lowers to the engine's logical plan IR.

Supported statements::

    CREATE TASK name (INPUT=Series, OUTPUT IN ('POS','NEG'),
        TYPE='Classification');

    SELECT gender, AVG(sentiment_classifier(emb)), COUNT(*)
        FROM reviews WHERE len > 20 AND gender = 1 GROUP BY gender;

    PREDICT emb USING TASK sentiment_classifier FROM reviews
        WHERE len > 20;

WHERE supports conjunctions of ``col <op> literal`` with op in
``> >= < <= = !=``; aggregates are ``COUNT(*|col)``, ``SUM``, ``AVG``
over plain columns or task calls ``task(col)``. Task calls resolve to a
model through the session (selection subspace + catalog) — the user never
names a model.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

from repro.core.task import TaskSpec
from repro.engine.plan import LogicalPlan

_TOKEN_RE = re.compile(
    r"\s*(?:(?P<num>-?\d+\.\d+|-?\d+)|(?P<id>[A-Za-z_]\w*)"
    r"|(?P<str>'[^']*'|\"[^\"]*\")|(?P<sym><=|>=|!=|<>|[(),*=<>;]))")

_AGGS = {"COUNT": "count", "SUM": "sum", "AVG": "mean"}
_CMP_OPS = {">", ">=", "<", "<=", "=", "!=", "<>"}


def tokenize(sql: str) -> List[str]:
    toks, pos = [], 0
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            if sql[pos:].strip():
                raise ValueError(f"bad token at: {sql[pos:pos + 20]!r}")
            break
        pos = m.end()
        tok = m.group().strip()
        if tok:
            toks.append(tok)
    return toks


@dataclass
class TaskCall:
    task: str
    col: str


@dataclass
class SelectItem:
    expr: Any                    # str column | TaskCall
    agg: Optional[str] = None    # count | sum | mean
    star: bool = False           # COUNT(*)


@dataclass
class CreateTaskStmt:
    spec: TaskSpec


@dataclass
class QueryStmt:
    plan: LogicalPlan
    tasks: List[str] = field(default_factory=list)
    output_cols: List[str] = field(default_factory=list)


Statement = Any  # CreateTaskStmt | QueryStmt


class _Parser:
    def __init__(self, toks: List[str]):
        self.toks = toks
        self.i = 0

    # -- plumbing --------------------------------------------------------
    def peek(self) -> Optional[str]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> str:
        t = self.peek()
        if t is None:
            raise ValueError("unexpected end of statement")
        self.i += 1
        return t

    def expect(self, *alts: str) -> str:
        t = self.next()
        if t.upper() not in alts and t not in alts:
            raise ValueError(f"expected {'/'.join(alts)}, got {t!r}")
        return t

    def at_kw(self, kw: str) -> bool:
        t = self.peek()
        return t is not None and t.upper() == kw

    # -- terminals -------------------------------------------------------
    def literal(self) -> Any:
        t = self.next()
        if t[0] in "'\"":
            return t[1:-1]
        if re.fullmatch(r"-?\d+", t):
            return int(t)
        if re.fullmatch(r"-?\d+\.\d+", t):
            return float(t)
        return t  # bare identifier treated as string literal

    # -- clauses ---------------------------------------------------------
    def where_clause(self) -> List[Tuple[str, str, Any]]:
        preds = []
        while True:
            col = self.next()
            op = self.next()
            if op not in _CMP_OPS:
                raise ValueError(f"bad comparison operator {op!r}")
            if op == "<>":
                op = "!="
            preds.append((col, op, self.literal()))
            if self.at_kw("AND"):
                self.next()
                continue
            break
        return preds

    def select_item(self) -> SelectItem:
        t = self.next()
        up = t.upper()
        if up in _AGGS:
            self.expect("(")
            if self.peek() == "*":
                self.next()
                self.expect(")")
                return SelectItem(None, agg=_AGGS[up], star=True)
            inner = self.next()
            if self.peek() == "(":          # task call inside aggregate
                self.next()
                col = self.next()
                self.expect(")")
                self.expect(")")
                return SelectItem(TaskCall(inner, col), agg=_AGGS[up])
            self.expect(")")
            return SelectItem(inner, agg=_AGGS[up])
        if self.peek() == "(":              # bare task call
            self.next()
            col = self.next()
            self.expect(")")
            return SelectItem(TaskCall(t, col))
        return SelectItem(t)

    # -- statements ------------------------------------------------------
    def create_task(self) -> CreateTaskStmt:
        self.expect("TASK")
        name = self.next()
        self.expect("(")
        self.expect("INPUT")
        self.expect("=")
        input_type = self.next().lower()
        self.expect(",")
        self.expect("OUTPUT")
        self.expect("IN")
        self.expect("(")
        labels = []
        while self.peek() != ")":
            labels.append(str(self.literal()))
            if self.peek() == ",":
                self.next()
        self.expect(")")
        self.expect(",")
        self.expect("TYPE")
        self.expect("=")
        kind = str(self.literal()).lower()
        self.expect(")")
        return CreateTaskStmt(TaskSpec(name, input_type, tuple(labels),
                                       kind))

    def select(self) -> QueryStmt:
        items = [self.select_item()]
        while self.peek() == ",":
            self.next()
            items.append(self.select_item())
        self.expect("FROM")
        table = self.next()
        preds = []
        if self.at_kw("WHERE"):
            self.next()
            preds = self.where_clause()
        group_by = None
        if self.at_kw("GROUP"):
            self.next()
            self.expect("BY")
            group_by = self.next()
        return self._build_select(items, table, preds, group_by)

    def _build_select(self, items, table, preds, group_by) -> QueryStmt:
        plan = LogicalPlan.scan(table)
        tasks: List[str] = []
        score_of = {}               # (task, col) -> score column

        def score_col(tc: TaskCall) -> str:
            key = (tc.task, tc.col)
            if key not in score_of:
                name = "_score" if not score_of else f"_score{len(score_of) + 1}"
                score_of[key] = name
                plan.predict(tc.task, tc.col, out=name)
                tasks.append(tc.task)
            return score_of[key]

        specs: List[Tuple[str, str, str]] = []
        out_cols: List[str] = []
        plain_cols: List[str] = []
        has_agg = any(it.agg for it in items)
        for it in items:
            if it.agg:
                if it.star:
                    specs.append(("*", "count", "count"))
                    out_cols.append("count")
                    continue
                col = (score_col(it.expr)
                       if isinstance(it.expr, TaskCall) else it.expr)
                name = f"{it.agg}_{col}"
                specs.append((col, it.agg, name))
                out_cols.append(name)
            elif isinstance(it.expr, TaskCall):
                if has_agg:
                    raise ValueError("bare task calls cannot be mixed "
                                     "with aggregates")
                out_cols.append(score_col(it.expr))
            else:
                plain_cols.append(it.expr)
                out_cols.append(it.expr)
        # WHERE is evaluated after SELECT-item lowering here (inference
        # first); the optimizer's pushdown pass restores filter-first
        # order whenever predicates only touch base columns.
        if preds:
            plan.filter(preds)
        if has_agg:
            if plain_cols and group_by is None:
                raise ValueError("bare columns with aggregates require "
                                 "GROUP BY")
            for c in plain_cols:
                if c != group_by:
                    raise ValueError(f"column {c!r} not in GROUP BY")
            plan.agg(group_by, specs)
        elif group_by is not None:
            raise ValueError("GROUP BY without aggregates")
        else:
            plan.project(out_cols)      # SELECT list narrows the output
        return QueryStmt(plan, tasks=tasks, output_cols=out_cols)

    def predict_stmt(self) -> QueryStmt:
        col = self.next()
        self.expect("USING")
        self.expect("TASK")
        task = self.next()
        self.expect("FROM")
        table = self.next()
        preds = []
        if self.at_kw("WHERE"):
            self.next()
            preds = self.where_clause()
        plan = LogicalPlan.scan(table)
        plan.predict(task, col, out="_score")
        if preds:
            plan.filter(preds)
        return QueryStmt(plan, tasks=[task], output_cols=["_score"])

    def statement(self) -> Statement:
        t = self.next().upper()
        if t == "CREATE":
            return self.create_task()
        if t == "SELECT":
            return self.select()
        if t == "PREDICT":
            return self.predict_stmt()
        raise ValueError(f"unsupported statement {t}")


def parse(sql: str) -> Statement:
    toks = tokenize(sql.strip().rstrip(";"))
    p = _Parser([t for t in toks if t != ";"])
    stmt = p.statement()
    if p.peek() is not None:
        raise ValueError(f"trailing tokens: {p.toks[p.i:]}")
    return stmt
