"""MiniSQL: the task-centric SQL surface (paper §2.1, Table 1), promoted
from the original `examples/` regex demo into a real tokenizer + recursive
descent parser that lowers to the engine's logical plan IR.

Supported statements::

    CREATE TASK name (INPUT=Series, OUTPUT IN ('POS','NEG'),
        TYPE='Classification');

    SELECT gender, AVG(sentiment_classifier(emb)), COUNT(*)
        FROM reviews WHERE len > 20 AND gender = 1 GROUP BY gender;

    PREDICT emb USING TASK sentiment_classifier FROM reviews
        WHERE len > 20;

    SELECT id FROM reviews
        ORDER BY SIMILARITY(emb, [0.1, 0.2, 0.3]) LIMIT 5;

WHERE supports conjunctions of ``col <op> literal`` with op in
``> >= < <= = !=``; aggregates are ``COUNT(*|col)``, ``SUM``, ``AVG``
over plain columns or task calls ``task(col)``. Task calls resolve to a
model through the session (selection subspace + catalog) — the user never
names a model.

``ORDER BY SIMILARITY(col, <query>)`` ranks rows by nearness to the
query — a ``[v1, v2, ...]`` vector literal or a quoted text string
(feature-hashed to the column width by :func:`encode_text`). The default
(``DESC``) order is nearest-first; with ``LIMIT k`` and no filter or
aggregate, the optimizer lowers the whole query to an index scan served
from the share-cache chain (the ANN tier's top-k fast path).
"""
from __future__ import annotations

import re
import zlib
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core.task import TaskSpec
from repro.engine.plan import LogicalPlan

_TOKEN_RE = re.compile(
    r"\s*(?:(?P<num>-?\d+\.\d+|-?\d+)|(?P<id>[A-Za-z_]\w*)"
    r"|(?P<str>'[^']*'|\"[^\"]*\")|(?P<sym><=|>=|!=|<>|[(),*=<>;\[\]]))")

_AGGS = {"COUNT": "count", "SUM": "sum", "AVG": "mean"}
_CMP_OPS = {">", ">=", "<", "<=", "=", "!=", "<>"}


def encode_text(text: str, dim: int) -> np.ndarray:
    """Deterministic feature-hashing text vectorizer for SIMILARITY
    query literals: character trigrams hashed (crc32, stable across
    processes) into ``dim`` signed buckets, L2-normalised. Not a learned
    embedding — just a fixed, reproducible text -> R^dim map so quoted
    strings can be compared against vector columns."""
    v = np.zeros(max(int(dim), 1), dtype=np.float32)
    t = f"  {text.lower()}  "
    for i in range(len(t) - 2):
        h = zlib.crc32(t[i:i + 3].encode("utf-8"))
        v[h % len(v)] += 1.0 if (h >> 16) & 1 else -1.0
    n = float(np.linalg.norm(v))
    return v / n if n else v


def tokenize(sql: str) -> List[str]:
    toks, pos = [], 0
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            if sql[pos:].strip():
                raise ValueError(f"bad token at: {sql[pos:pos + 20]!r}")
            break
        pos = m.end()
        tok = m.group().strip()
        if tok:
            toks.append(tok)
    return toks


@dataclass
class TaskCall:
    task: str
    col: str


@dataclass
class SelectItem:
    expr: Any                    # str column | TaskCall
    agg: Optional[str] = None    # count | sum | mean
    star: bool = False           # COUNT(*)


@dataclass
class CreateTaskStmt:
    spec: TaskSpec


@dataclass
class QueryStmt:
    plan: LogicalPlan
    tasks: List[str] = field(default_factory=list)
    output_cols: List[str] = field(default_factory=list)


Statement = Any  # CreateTaskStmt | QueryStmt


class _Parser:
    def __init__(self, toks: List[str]):
        self.toks = toks
        self.i = 0

    # -- plumbing --------------------------------------------------------
    def peek(self) -> Optional[str]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> str:
        t = self.peek()
        if t is None:
            raise ValueError("unexpected end of statement")
        self.i += 1
        return t

    def expect(self, *alts: str) -> str:
        t = self.next()
        if t.upper() not in alts and t not in alts:
            raise ValueError(f"expected {'/'.join(alts)}, got {t!r}")
        return t

    def at_kw(self, kw: str) -> bool:
        t = self.peek()
        return t is not None and t.upper() == kw

    # -- terminals -------------------------------------------------------
    def literal(self) -> Any:
        t = self.next()
        if t[0] in "'\"":
            return t[1:-1]
        if re.fullmatch(r"-?\d+", t):
            return int(t)
        if re.fullmatch(r"-?\d+\.\d+", t):
            return float(t)
        return t  # bare identifier treated as string literal

    # -- clauses ---------------------------------------------------------
    def where_clause(self) -> List[Tuple[str, str, Any]]:
        preds = []
        while True:
            col = self.next()
            op = self.next()
            if op not in _CMP_OPS:
                raise ValueError(f"bad comparison operator {op!r}")
            if op == "<>":
                op = "!="
            preds.append((col, op, self.literal()))
            if self.at_kw("AND"):
                self.next()
                continue
            break
        return preds

    def similarity_clause(self) -> Tuple[str, Any, bool]:
        """``SIMILARITY(col, <[vector]|'text'>) [ASC|DESC]`` — returns
        (col, query, ascending); DESC (nearest first) is the default."""
        self.expect("SIMILARITY")
        self.expect("(")
        col = self.next()
        self.expect(",")
        if self.peek() == "[":
            self.next()
            vals: List[float] = []
            while self.peek() != "]":
                vals.append(float(self.literal()))
                if self.peek() == ",":
                    self.next()
            self.expect("]")
            query: Any = np.asarray(vals, dtype=np.float32)
        else:
            t = self.next()
            if t[0] not in "'\"":
                raise ValueError(
                    "SIMILARITY query must be a [vector] literal or a "
                    f"quoted text string, got {t!r}")
            query = t[1:-1]
        self.expect(")")
        ascending = False
        if self.at_kw("ASC"):
            self.next()
            ascending = True
        elif self.at_kw("DESC"):
            self.next()
        return col, query, ascending

    def order_limit(self) -> Tuple[Optional[Tuple[str, Any, bool]],
                                   Optional[int]]:
        order = None
        if self.at_kw("ORDER"):
            self.next()
            self.expect("BY")
            order = self.similarity_clause()
        limit = None
        if self.at_kw("LIMIT"):
            self.next()
            k = self.literal()
            if not isinstance(k, int) or k < 1:
                raise ValueError(f"LIMIT expects a positive integer, "
                                 f"got {k!r}")
            limit = k
        return order, limit

    def select_item(self) -> SelectItem:
        t = self.next()
        up = t.upper()
        if up in _AGGS:
            self.expect("(")
            if self.peek() == "*":
                self.next()
                self.expect(")")
                return SelectItem(None, agg=_AGGS[up], star=True)
            inner = self.next()
            if self.peek() == "(":          # task call inside aggregate
                self.next()
                col = self.next()
                self.expect(")")
                self.expect(")")
                return SelectItem(TaskCall(inner, col), agg=_AGGS[up])
            self.expect(")")
            return SelectItem(inner, agg=_AGGS[up])
        if self.peek() == "(":              # bare task call
            self.next()
            col = self.next()
            self.expect(")")
            return SelectItem(TaskCall(t, col))
        return SelectItem(t)

    # -- statements ------------------------------------------------------
    def create_task(self) -> CreateTaskStmt:
        self.expect("TASK")
        name = self.next()
        self.expect("(")
        self.expect("INPUT")
        self.expect("=")
        input_type = self.next().lower()
        self.expect(",")
        self.expect("OUTPUT")
        self.expect("IN")
        self.expect("(")
        labels = []
        while self.peek() != ")":
            labels.append(str(self.literal()))
            if self.peek() == ",":
                self.next()
        self.expect(")")
        self.expect(",")
        self.expect("TYPE")
        self.expect("=")
        kind = str(self.literal()).lower()
        self.expect(")")
        return CreateTaskStmt(TaskSpec(name, input_type, tuple(labels),
                                       kind))

    def select(self) -> QueryStmt:
        items = [self.select_item()]
        while self.peek() == ",":
            self.next()
            items.append(self.select_item())
        self.expect("FROM")
        table = self.next()
        preds = []
        if self.at_kw("WHERE"):
            self.next()
            preds = self.where_clause()
        group_by = None
        if self.at_kw("GROUP"):
            self.next()
            self.expect("BY")
            group_by = self.next()
        order, limit = self.order_limit()
        return self._build_select(items, table, preds, group_by,
                                  order, limit)

    def _build_select(self, items, table, preds, group_by,
                      order=None, limit=None) -> QueryStmt:
        plan = LogicalPlan.scan(table)
        tasks: List[str] = []
        score_of = {}               # (task, col) -> score column

        def score_col(tc: TaskCall) -> str:
            key = (tc.task, tc.col)
            if key not in score_of:
                name = "_score" if not score_of else f"_score{len(score_of) + 1}"
                score_of[key] = name
                plan.predict(tc.task, tc.col, out=name)
                tasks.append(tc.task)
            return score_of[key]

        specs: List[Tuple[str, str, str]] = []
        out_cols: List[str] = []
        plain_cols: List[str] = []
        has_agg = any(it.agg for it in items)
        for it in items:
            if it.agg:
                if it.star:
                    specs.append(("*", "count", "count"))
                    out_cols.append("count")
                    continue
                col = (score_col(it.expr)
                       if isinstance(it.expr, TaskCall) else it.expr)
                name = f"{it.agg}_{col}"
                specs.append((col, it.agg, name))
                out_cols.append(name)
            elif isinstance(it.expr, TaskCall):
                if has_agg:
                    raise ValueError("bare task calls cannot be mixed "
                                     "with aggregates")
                out_cols.append(score_col(it.expr))
            else:
                plain_cols.append(it.expr)
                out_cols.append(it.expr)
        # WHERE is evaluated after SELECT-item lowering here (inference
        # first); the optimizer's pushdown pass restores filter-first
        # order whenever predicates only touch base columns.
        if preds:
            plan.filter(preds)
        if has_agg:
            if order is not None:
                raise ValueError("ORDER BY SIMILARITY cannot be combined "
                                 "with aggregates")
            if plain_cols and group_by is None:
                raise ValueError("bare columns with aggregates require "
                                 "GROUP BY")
            for c in plain_cols:
                if c != group_by:
                    raise ValueError(f"column {c!r} not in GROUP BY")
            plan.agg(group_by, specs)
        elif group_by is not None:
            raise ValueError("GROUP BY without aggregates")
        elif order is not None:
            ocol, query, ascending = order
            proj = list(out_cols)
            drop = None
            if ocol not in proj:
                # ordering needs the column downstream of the projection;
                # carry it through and drop it from the final output
                proj.append(ocol)
                drop = ocol
            plan.project(proj)
            plan.order_by_similarity(ocol, query, ascending=ascending,
                                     drop_col=drop)
        else:
            plan.project(out_cols)      # SELECT list narrows the output
        if limit is not None:
            plan.limit(limit)
        return QueryStmt(plan, tasks=tasks, output_cols=out_cols)

    def predict_stmt(self) -> QueryStmt:
        col = self.next()
        self.expect("USING")
        self.expect("TASK")
        task = self.next()
        self.expect("FROM")
        table = self.next()
        preds = []
        if self.at_kw("WHERE"):
            self.next()
            preds = self.where_clause()
        order, limit = self.order_limit()
        plan = LogicalPlan.scan(table)
        plan.predict(task, col, out="_score")
        if preds:
            plan.filter(preds)
        if order is not None:
            # PREDICT keeps every column, so the ordering column is
            # already in the output: nothing to drop
            ocol, query, ascending = order
            plan.order_by_similarity(ocol, query, ascending=ascending)
        if limit is not None:
            plan.limit(limit)
        return QueryStmt(plan, tasks=[task], output_cols=["_score"])

    def statement(self) -> Statement:
        t = self.next().upper()
        if t == "CREATE":
            return self.create_task()
        if t == "SELECT":
            return self.select()
        if t == "PREDICT":
            return self.predict_stmt()
        raise ValueError(f"unsupported statement {t}")


def parse(sql: str) -> Statement:
    toks = tokenize(sql.strip().rstrip(";"))
    p = _Parser([t for t in toks if t != ";"])
    stmt = p.statement()
    if p.peek() is not None:
        raise ValueError(f"trailing tokens: {p.toks[p.i:]}")
    return stmt
