"""repro: MorphingDB (task-centric AI-native DBMS) as a multi-pod JAX
training/serving framework. See DESIGN.md and EXPERIMENTS.md.

Module map (query path top-down):

- ``engine``    — the task-centric query engine: MiniSQL parser, logical
  plan IR + optimizer (predicate pushdown, embed insertion, Eq. 10/11
  placement + batch annotation), and the ``MorphingSession`` facade that
  resolves tasks to models and executes compiled plans.
- ``core``      — task-centric model selection: NMF transferability
  subspace, two-phase ``ModelSelector``, ``TaskRegistry``, and the mini
  zoo/transfer substrate that validates it.
- ``pipeline``  — execution substrate: operator ``Dag`` (Algorithm 1),
  cost model (Eq. 5-11, ``place_dag``), columnar operators, window /
  continuous batchers, ``VectorShareCache`` pre-embedding, and the pure
  runtime ``PipelineExecutor`` (wave + chunked overlap execution).
- ``storage``   — model stores (BLOB / decoupled layer tables / API
  endpoints), the JSON system catalog, the Mvec tensor format, and
  distributed checkpointing.
- ``models``    — JAX model zoo: transformer, enc-dec, MoE, Mamba-2,
  RG-LRU, attention variants.
- ``kernels``   — Pallas TPU kernels (fused embed, attention, scans).
- ``training``  / ``distributed`` / ``launch`` — multi-pod training,
  sharding, serving entry points.
- ``analysis``  — FLOPs/HLO cost analysis and experiment reports.
- ``data`` / ``configs`` — input pipelines and model configs.
"""
__version__ = "1.1.0"
