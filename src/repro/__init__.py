"""repro: MorphingDB (task-centric AI-native DBMS) as a multi-pod JAX
training/serving framework. See DESIGN.md and EXPERIMENTS.md."""
__version__ = "1.0.0"
