"""Pallas fused pre-embedding: normalize + project + tanh in one kernel.

The TPU adaptation of MorphingDB's SIMD vectorized pre-embedding (§5.1):
the paper normalizes pixels/token vectors with SIMD registers before a
projection; here the normalization is fused into the MXU matmul's operand
load so the raw rows are read from HBM exactly once. Projection weights
live in VMEM across the whole grid (D x K <= 16k x 512 bf16 = 16 MB cap;
typical embedders are far smaller).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, *, mean: float, scale: float):
    x = (x_ref[...].astype(jnp.float32) - mean) * scale
    z = x @ w_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.tanh(z).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mean", "scale", "block_rows",
                                              "interpret"))
def fused_embed(x, w, *, mean: float = 0.0, scale: float = 1.0,
                block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: [N, D]; w: [D, K] -> tanh(((x-mean)*scale) @ w) [N, K].

    Any N is accepted: ragged row counts (the final chunk of a table not
    divisible by the block size) are zero-padded up to a whole number of
    blocks and the padding is sliced off the result.
    """
    N, D = x.shape
    K = w.shape[1]
    if N == 0:
        return jnp.zeros((0, K), x.dtype)
    br = min(block_rows, N)
    pad = (-N) % br
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    np_rows = N + pad
    out = pl.pallas_call(
        functools.partial(_kernel, mean=mean, scale=scale),
        grid=(np_rows // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((D, K), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_rows, K), x.dtype),
        interpret=interpret,
    )(xp, w)
    return out[:N] if pad else out
