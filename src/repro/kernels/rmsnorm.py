"""Pallas fused RMSNorm: one pass over HBM (read x, write y) instead of
XLA's unfused mean-square reduce + scale chain.

Grid over row blocks; each block [br, D] fits VMEM (br=256, D<=16384 bf16
=> 8 MB). Scale (1 + w) follows the gemma convention used zoo-wide.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * (1.0 + w_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps",
                                              "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False) -> jax.Array:
    """x: [N, D]; w: [D]."""
    N, D = x.shape
    br = min(block_rows, N)
    assert N % br == 0, (N, br)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(N // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(x, w)
