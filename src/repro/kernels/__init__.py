from repro.kernels.ops import (decode_attention, flash_attention,
                               fused_embed, rmsnorm)

__all__ = ["decode_attention", "flash_attention", "fused_embed", "rmsnorm"]
