"""Pallas TPU flash-decode: one-token attention against a long KV cache.

Grid: (batch, kv_block) — kv_block sequential, scratch carries the online
softmax state. All Q heads for the batch element live in VMEM (Hq x D is
small); kv tiles stream through. Positions >= ``length`` are masked (the
cache may be longer than the valid prefix).
VMEM working set: Hq*D (q) + 2*bk*Hkv*D (kv tile) + Hq*bk (scores).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            bk: int, nk: int, G: int, scale: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32) * scale            # [Hq, D]
    k = k_ref[0].astype(jnp.float32)                    # [Hkv, bk, D]
    v = v_ref[0].astype(jnp.float32)
    Hkv = k.shape[0]
    Hq, D = q.shape
    qg = q.reshape(Hkv, G, D)
    s = jnp.einsum("kgd,ksd->kgs", qg, k)               # [Hkv, G, bk]
    pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (Hkv, G, bk), 2)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)
    m_prev = m_sc[...]
    s2 = s.reshape(Hq, bk)
    m_new = jnp.maximum(m_prev, s2.max(axis=1))
    p = jnp.exp(s2 - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
    pv = jnp.einsum("kgs,ksd->kgd", p.reshape(Hkv, G, bk), v)
    acc_sc[...] = acc_sc[...] * corr[:, None] + pv.reshape(Hq, D)
    m_sc[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0] = (acc_sc[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, length, *,
                     block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: [B, Hq, D]; caches: [B, Hkv, S, D]; length: [B] valid prefix."""
    B, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    bk = min(block_k, S)
    assert S % bk == 0
    nk = S // bk
    grid = (B, nk)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))

    kernel = functools.partial(_kernel, bk=bk, nk=nk, G=G, scale=D ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Hq, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, Hkv, bk, D), lambda b, j: (b, 0, j, 0)),
            pl.BlockSpec((1, Hkv, bk, D), lambda b, j: (b, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(length, q, k_cache, v_cache)
