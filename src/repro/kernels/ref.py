"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Sk, D] -> [B, Hq, Sq, D]."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, length) -> jax.Array:
    """q: [B, Hq, D]; caches: [B, Hkv, S, D]; attends to pos < length."""
    B, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bkgd,bksd->bkgs", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(S) < length
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)


def rmsnorm_ref(x, w, eps: float = 1e-6) -> jax.Array:
    """x: [N, D]; w: [D] (1+w scaling)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def fused_embed_ref(x, w, mean: float = 0.0, scale: float = 1.0) -> jax.Array:
    """Normalize+project+tanh: x [N, D], w [D, K] -> [N, K]."""
    z = (x.astype(jnp.float32) - mean) * scale
    return jnp.tanh(z @ w.astype(jnp.float32)).astype(x.dtype)
