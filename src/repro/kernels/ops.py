"""jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True off-TPU (this container) and False on TPU —
the kernels are the TPU-target implementation; interpret mode executes the
same kernel bodies in Python for correctness validation.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_embed import fused_embed as _embed
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 512,
                    block_k: int = 512,
                    interpret: Optional[bool] = None):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k,
                  interpret=_default_interpret() if interpret is None
                  else interpret)


def decode_attention(q, k_cache, v_cache, length, *, block_k: int = 512,
                     interpret: Optional[bool] = None):
    return _decode(q, k_cache, v_cache, length, block_k=block_k,
                   interpret=_default_interpret() if interpret is None
                   else interpret)


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: Optional[bool] = None):
    return _rmsnorm(x, w, eps=eps, block_rows=block_rows,
                    interpret=_default_interpret() if interpret is None
                    else interpret)


def fused_embed(x, w, *, mean: float = 0.0, scale: float = 1.0,
                block_rows: int = 256, interpret: Optional[bool] = None):
    return _embed(x, w, mean=mean, scale=scale, block_rows=block_rows,
                  interpret=_default_interpret() if interpret is None
                  else interpret)
