"""Pallas TPU flash attention (causal / sliding-window, GQA-aware).

Grid: (batch, q_head, q_block, kv_block) — the last axis is sequential on
TPU, so VMEM scratch (running max / denominator / accumulator) persists
across kv blocks for a fixed q block (the online-softmax recurrence).
GQA: the k/v BlockSpec index maps fold q_head -> kv_head = qh * Hkv // Hq,
so kv tiles are fetched once per group without materializing repeats.

Block shapes are MXU-aligned (multiples of (128, 128) tiles on the
(seq, head_dim) axes); the q tile, one kv tile, and the f32 accumulator
bound the VMEM working set to
  bq*D + 2*bk*D + bq*bk + 2*bq*D(f32) floats,
e.g. 512x128 q / 512x128 kv tiles => ~1.3 MB << 16 MB VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            causal: bool, window: Optional[int], bq: int, bk: int,
            nk: int, scale: float):
    i = pl.program_id(2)   # q block
    j = pl.program_id(3)   # kv block

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_lo = i * bq
    k_lo = j * bk

    # block-level reachability (compute skipped entirely when masked out)
    reachable = True
    if causal:
        reachable = k_lo <= q_lo + bq - 1
    if window is not None:
        reachable = jnp.logical_and(
            reachable, k_lo + bk - 1 > q_lo - window) \
            if causal else (k_lo + bk - 1 > q_lo - window)

    @pl.when(reachable if not isinstance(reachable, bool) else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                          # [bq, bk]
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + p @ v
        m_sc[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_sc[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: [B, Hq, S, D]; k/v: [B, Hkv, S, D] -> [B, Hq, S, D]."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    grid = (B, Hq, nq, nk)

    def q_map(b, h, i, j):
        return (b, h, i, 0)

    def kv_map(b, h, i, j):
        return (b, (h * Hkv) // Hq, j, 0)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, bq=bq, bk=bk, nk=nk,
        scale=D ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), q_map),
            pl.BlockSpec((1, 1, bk, D), kv_map),
            pl.BlockSpec((1, 1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
