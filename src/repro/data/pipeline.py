"""Tokenized data pipeline: synthetic corpus + file-backed shards, per-host
sharding, deterministic resume (step -> batch mapping is stateless).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    # Markov-chain synthetic text: learnable structure (not pure noise)
    order_mix: float = 0.8
    branching: int = 16   # successors per token (lower = easier)


class SyntheticCorpus:
    """Deterministic synthetic LM data with learnable bigram structure.

    batch(step, host, num_hosts) is pure — restart-safe without dataloader
    checkpoints (the step index IS the state).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse bigram table: each token -> `branching` likely successors
        self._succ = rng.integers(0, v, size=(v, cfg.branching)).astype(
            np.int32)

    def batch(self, step: int, host: int = 0, num_hosts: int = 1
              ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // num_hosts
        seed = hash((cfg.seed, step, host)) % (1 << 31)
        rng = np.random.default_rng(seed)
        B, S = per_host, cfg.seq_len
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, B)
        follow = rng.random((B, S)) < cfg.order_mix
        choice = rng.integers(0, cfg.branching, (B, S))
        rand_tok = rng.integers(0, cfg.vocab_size, (B, S))
        for t in range(1, S):
            succ = self._succ[toks[:, t - 1], choice[:, t]]
            toks[:, t] = np.where(follow[:, t], succ, rand_tok[:, t])
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class FileShardedCorpus:
    """Pre-tokenized .npy shards, round-robin across hosts with a
    deterministic (step -> shard, offset) mapping for elastic restarts."""

    def __init__(self, root: Path, seq_len: int, global_batch: int):
        self.files = sorted(Path(root).glob("*.npy"))
        if not self.files:
            raise FileNotFoundError(f"no .npy shards under {root}")
        self.seq_len = seq_len
        self.global_batch = global_batch
        self._cache: Dict[int, np.ndarray] = {}

    def _shard(self, i: int) -> np.ndarray:
        if i not in self._cache:
            self._cache = {i: np.load(self.files[i], mmap_mode="r")}
        return self._cache[i]

    def batch(self, step: int, host: int = 0, num_hosts: int = 1):
        per_host = self.global_batch // num_hosts
        out = np.empty((per_host, self.seq_len), np.int32)
        for b in range(per_host):
            gidx = step * self.global_batch + host * per_host + b
            shard = self._shard(gidx % len(self.files))
            rows = (len(shard) - self.seq_len) or 1
            off = (gidx * 9176) % rows
            out[b] = shard[off:off + self.seq_len]
        return {"tokens": out}
