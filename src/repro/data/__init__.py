from repro.data.pipeline import DataConfig, FileShardedCorpus, SyntheticCorpus

__all__ = ["DataConfig", "FileShardedCorpus", "SyntheticCorpus"]
