"""OLMoE 1B-7B — 64-expert top-8 MoE [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("olmoe-1b-7b")
def olmoe_1b_7b() -> ModelConfig:
    return ModelConfig(
        arch_id="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1024,                # per-expert ff (spec)
        vocab_size=50304,
        head_dim=128,
        activation="swiglu",
        qk_norm=True,             # OLMoE uses QK-norm
        moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024,
                      impl="batched"),
        remat_policy="full",
        source="arXiv:2409.02060; hf",
    )
