"""Granite-3 8B — dense GQA with muP-style scalars [hf:ibm-granite]."""
from repro.configs.base import ModelConfig, register


@register("granite-3-8b")
def granite_3_8b() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-3-8b",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=12800,
        vocab_size=49155,
        head_dim=128,
        activation="swiglu",
        rope_theta=10000.0,
        embedding_multiplier=12.0,
        residual_multiplier=0.22,
        logits_scaling=16.0,
        tie_embeddings=True,
        remat_policy="full",
        source="hf:ibm-granite/granite-3.0-8b-base",
    )
