"""Kimi K2 — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2, paper table]."""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("kimi-k2-1t-a32b")
def kimi_k2_1t_a32b() -> ModelConfig:
    return ModelConfig(
        arch_id="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=2048,                # per-expert ff (spec)
        vocab_size=163840,
        head_dim=112,             # 7168 / 64 (spec-faithful; MXU pads to 128)
        activation="swiglu",
        moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                      impl="batched"),
        remat_policy="full",
        grad_accum=4,   # §Perf: accum 8->4 cuts ZeRO-3 regather traffic 31%
        source="arXiv:2501.kimi2 (paper-table)",
    )
