"""RecurrentGemma 9B — Griffin: RG-LRU + local attention, pattern 2:1 [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig, register


@register("recurrentgemma-9b")
def recurrentgemma_9b() -> ModelConfig:
    return ModelConfig(
        arch_id="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,         # MQA on the local-attention blocks
        d_ff=12288,
        vocab_size=256000,
        head_dim=256,
        activation="geglu",
        rmsnorm_one_plus=True,
        embed_scale=True,
        tie_embeddings=True,
        block_pattern=("rglru", "rglru", "attn"),
        rglru_width=4096,
        local_attn_window=2048,
        remat_policy="full",
        seq_parallel=True,  # §Perf: SP residual cuts the memory term 27%
        source="arXiv:2402.19427",
    )
