"""H2O-Danube 1.8B — llama/mistral mix with sliding-window attention [arXiv:2401.16818]."""
from repro.configs.base import ModelConfig, register


@register("h2o-danube-1.8b")
def h2o_danube_1_8b() -> ModelConfig:
    return ModelConfig(
        arch_id="h2o-danube-1.8b",
        family="dense",
        num_layers=24,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        head_dim=80,
        activation="swiglu",
        sliding_window=4096,
        rope_theta=10000.0,
        remat_policy="full",
        source="arXiv:2401.16818; hf",
    )
