"""Whisper-medium — encoder-decoder backbone; conv frontend STUBBED [arXiv:2212.04356].

``input_specs()`` provides precomputed frame embeddings (batch, frames,
d_model) for the encoder; the decoder consumes token ids. The assigned
seq_len is the total context budget, split (enc, dec) = (seq/2, seq/2).
"""
from repro.configs.base import ModelConfig, register


@register("whisper-medium")
def whisper_medium() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-medium",
        family="audio",
        num_layers=24,            # decoder layers
        num_encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        head_dim=64,
        activation="geglu",       # backbone uses gated MLP in our zoo
        norm="layernorm",
        is_encoder_decoder=True,
        frontend="audio_frames",
        rope_theta=10000.0,
        remat_policy="full",
        source="arXiv:2212.04356",
    )
