"""Chameleon 34B — early-fusion VLM token backbone with QK-norm [arXiv:2405.09818].

The modality frontend is a STUB: ``input_specs()`` provides mixed
text/VQ-image token ids directly (vocab 65536 includes image codes).
"""
from repro.configs.base import ModelConfig, register


@register("chameleon-34b")
def chameleon_34b() -> ModelConfig:
    return ModelConfig(
        arch_id="chameleon-34b",
        family="vlm",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22016,
        vocab_size=65536,
        head_dim=128,
        activation="swiglu",
        qk_norm=True,
        frontend="vq_tokens",
        remat_policy="full",
        grad_accum=4,
        source="arXiv:2405.09818",
    )
