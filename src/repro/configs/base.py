"""Config system for the repro framework.

Every assigned architecture is a ``ModelConfig``; input shapes are
``ShapeConfig``s. Configs are plain frozen dataclasses so they hash, print,
and override cleanly (``cfg.replace(...)``). The registry maps ``--arch``
ids to constructor functions (one module per arch under ``repro.configs``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # 'dense'  -> all-experts compute + gated combine (oracle; smoke scale)
    # 'ragged' -> sort + jax.lax.ragged_dot, EP under shard_map (production)
    impl: str = "ragged"
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N: SSM state size per head
    head_dim: int = 64            # P: channels per head
    expand: int = 2               # d_inner = expand * d_model
    conv_dim: int = 4             # depthwise temporal conv width
    chunk: int = 256              # SSD chunk length (train/prefill)
    n_groups: int = 1             # B/C groups


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


# The four assigned LM shapes.
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | ssm | hybrid | vlm | audio | moe
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    activation: str = "swiglu"      # swiglu | geglu
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    rmsnorm_one_plus: bool = False  # gemma-style (1 + w)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    qk_norm: bool = False           # chameleon
    sliding_window: Optional[int] = None   # SWA (h2o-danube)
    attn_logit_softcap: Optional[float] = None
    embed_scale: bool = False       # gemma: scale embeddings by sqrt(d_model)
    # granite μP-style scalars
    embedding_multiplier: float = 1.0
    residual_multiplier: float = 1.0
    logits_scaling: float = 1.0
    # MoE / SSM / hybrid
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (Griffin) layer pattern, cycled over num_layers.
    # entries: 'attn' | 'rglru'
    block_pattern: Optional[Tuple[str, ...]] = None
    rglru_width: int = 0            # lru width (0 -> d_model)
    local_attn_window: int = 2048   # hybrid local attention window
    # enc-dec
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # frontend stub ('none' | 'audio_frames' | 'vq_tokens')
    frontend: str = "none"
    # training / numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat_policy: str = "full"      # none | dots | full
    grad_accum: int = 1             # microbatch accumulation steps
    seq_parallel: bool = False      # sequence-parallel residual (train)
    # distribution overrides
    shard_attn_heads: bool = True   # False when heads < TP degree (gemma-2b)
    # metadata
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a multiple of 256 (TP divisibility + MXU lanes).
        Padded logit slots are masked to -inf in logits_from_hidden."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.moe is not None and self.moe.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state, RG-LRU+local attn, or SWA."""
        return (
            self.family == "ssm"
            or self.family == "hybrid"
            or self.sliding_window is not None
        )

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds, length num_layers."""
        if self.block_pattern:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        if self.family == "ssm":
            return ("ssm",) * self.num_layers
        return ("attn",) * self.num_layers

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for MODEL_FLOPS = 6 N D) ----------------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        embed = self.vocab_size * d
        unembed = 0 if self.tie_embeddings else self.vocab_size * d

        def attn_params() -> int:
            return d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d

        def mlp_params(ff: int) -> int:
            # gated (swiglu/geglu): in, gate, out
            return 3 * d * ff

        def ssm_params() -> int:
            assert self.ssm is not None
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_ch = d_in + 2 * s.n_groups * s.state_dim
            in_proj = d * (2 * d_in + 2 * s.n_groups * s.state_dim + nheads)
            return in_proj + conv_ch * s.conv_dim + d_in * d + d_in + 2 * nheads

        def rglru_params() -> int:
            w = self.rglru_width or d
            # in/out proj (x and gate branches) + conv + per-channel gates
            return 2 * d * w + w * d + w * self.ssm_conv() + 3 * w

        total = embed + unembed
        for kind in self.layer_kinds():
            total += 2 * d  # two norms
            if kind == "attn":
                total += attn_params() + mlp_params(self.d_ff)
            elif kind == "ssm":
                total += ssm_params() + (mlp_params(self.d_ff) if self.d_ff else 0)
            elif kind == "rglru":
                total += rglru_params() + mlp_params(self.d_ff)
            if self.is_moe and kind == "attn":
                m = self.moe
                total -= mlp_params(self.d_ff)
                n_e = m.top_k if active_only else m.num_experts
                total += 3 * d * m.d_ff_expert * n_e + d * m.num_experts
                total += 3 * d * m.d_ff_expert * m.num_shared_experts
        total += d  # final norm
        return int(total)

    def ssm_conv(self) -> int:
        return self.ssm.conv_dim if self.ssm else 4

    def active_param_count(self) -> int:
        return self.param_count(active_only=True)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(arch_id: str):
    def deco(fn):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch '{arch_id}'; available: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "llama3_405b", "gemma_2b", "granite_3_8b", "h2o_danube_1_8b",
    "mamba2_370m", "recurrentgemma_9b", "chameleon_34b", "whisper_medium",
    "olmoe_1b_7b", "kimi_k2_1t_a32b",
]

_loaded = False


def _ensure_loaded() -> None:
    global _loaded
    if _loaded:
        return
    import importlib
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _loaded = True


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(arch_id)
    kw = dict(
        num_layers=min(cfg.num_layers, 2 if not cfg.block_pattern else len(cfg.block_pattern)),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads > 1 else 1,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        rglru_width=128 if cfg.rglru_width else 0,
        local_attn_window=64,
        sliding_window=64 if cfg.sliding_window else None,
        remat_policy="none",
        param_dtype="float32",
        dtype="float32",
    )
    if cfg.moe:
        # capacity 8.0: zero token drops at smoke scale, so decode ==
        # full forward exactly (capacity drops are exercised separately
        # in tests/test_moe.py::test_capacity_drops_tokens)
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, d_ff_expert=64,
            capacity_factor=8.0)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk=32)
    if cfg.is_encoder_decoder:
        kw["num_encoder_layers"] = 2
    return cfg.replace(**kw)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Applicable assigned shapes for an arch (long_500k only if sub-quadratic)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)
