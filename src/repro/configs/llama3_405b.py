"""Llama-3 405B — dense GQA transformer [arXiv:2407.21783]."""
from repro.configs.base import ModelConfig, register


@register("llama3-405b")
def llama3_405b() -> ModelConfig:
    return ModelConfig(
        arch_id="llama3-405b",
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        d_ff=53248,
        vocab_size=128256,
        head_dim=128,
        activation="swiglu",
        rope_theta=500000.0,
        remat_policy="full",
        grad_accum=16,
        seq_parallel=True,  # §Perf: -20% memory term, temp 63->19 GB
        source="arXiv:2407.21783",
    )
