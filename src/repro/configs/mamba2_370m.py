"""Mamba-2 370M — attention-free SSD state-space model [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("mamba2-370m")
def mamba2_370m() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=32,          # SSD heads: d_inner / head_dim = 2048/64
        num_kv_heads=32,
        d_ff=0,                # mamba blocks have no separate MLP
        vocab_size=50280,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_dim=4,
                      chunk=256, n_groups=1),
        remat_policy="full",
        source="arXiv:2405.21060",
    )
