"""Gemma 2B — dense, GeGLU, MQA, head_dim 256, tied embeddings [arXiv:2403.08295]."""
from repro.configs.base import ModelConfig, register


@register("gemma-2b")
def gemma_2b() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma-2b",
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        d_ff=16384,
        vocab_size=256000,
        head_dim=256,
        activation="geglu",
        rmsnorm_one_plus=True,
        embed_scale=True,
        tie_embeddings=True,
        rope_theta=10000.0,
        # 8 Q heads < model-axis 16: replicate attention heads under TP,
        # shard d_ff / vocab instead (see DESIGN.md §6).
        shard_attn_heads=False,
        remat_policy="full",
        source="arXiv:2403.08295; hf",
    )
