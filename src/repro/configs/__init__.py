from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    get_config,
    list_archs,
    register,
    shapes_for,
    smoke_config,
)

__all__ = [
    "ALL_SHAPES", "DECODE_32K", "LONG_500K", "PREFILL_32K", "SHAPES",
    "TRAIN_4K", "ModelConfig", "MoEConfig", "ShapeConfig", "SSMConfig",
    "get_config", "list_archs", "register", "shapes_for", "smoke_config",
]
