"""Gradient compression for data-parallel all-reduce (beyond-paper
distributed-optimization trick).

int8 quantization with per-tensor scale and *error feedback*: the
quantization residual is carried into the next step, so compression error
does not accumulate (Karimireddy et al., 2019). Used by the shard_map
data-parallel trainer variant: grads are quantized, psum'd over the data
axis in int32 (8x less ICI traffic than f32; 4x less than bf16 + exact
integer reduction), then dequantized.

``compressed_psum`` is mesh-agnostic: call inside shard_map with the DP
axis name.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import axis_size


class EFState(NamedTuple):
    residual: Any  # pytree of f32 residuals, like grads


def init_ef_state(params) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, ef: EFState, axis_name: str,
                    enabled: bool = True) -> Tuple[Any, EFState]:
    """All-reduce-mean ``grads`` over ``axis_name`` with int8 EF compression.

    Returns (reduced grads, new error-feedback state). Scales are psum'd in
    f32 (bytes-negligible); payloads cross the interconnect as int8->int32.
    """
    if not enabled:
        red = jax.tree.map(
            lambda g: jax.lax.pmean(g.astype(jnp.float32), axis_name), grads)
        return red, ef

    n = axis_size(axis_name)

    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, scale = _quantize(g)
        # max-scale across replicas so integer sums commute
        gscale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(g / gscale), -127, 127).astype(jnp.int8)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        red = acc.astype(jnp.float32) * gscale / n
        new_r = g - _dequantize(q, gscale)  # local residual
        return red, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    red = jax.tree.unflatten(tdef, [o[0] for o in outs])
    res = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return red, EFState(res)


def compression_ratio(grads) -> float:
    """ICI byte ratio vs f32 all-reduce (int8 payload + f32 scale)."""
    total = sum(g.size * 4 for g in jax.tree.leaves(grads))
    comp = sum(g.size * 1 + 4 for g in jax.tree.leaves(grads))
    return comp / total
