"""Logical-axis sharding rules (MaxText-style) for the repro framework.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"q_heads", ...). A rule table maps logical names to physical mesh axes.
Rules are installed with the ``axis_rules`` context manager; when no rules
are active (e.g. single-device smoke tests) every annotation is a no-op.

FSDP+TP layout (see DESIGN.md §6):
  - params' embed dim            -> fsdp axes ("data",) or ("pod","data")
  - heads / mlp / vocab /experts -> "model" (TP / EP)
  - activations' batch           -> ("data",) or ("pod","data")
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisVal = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def _current() -> Optional[Dict[str, AxisVal]]:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, AxisVal], mesh: Optional[Mesh] = None):
    prev_r = getattr(_state, "rules", None)
    prev_m = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = prev_r
        _state.mesh = prev_m


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

def make_rules(*, multi_pod: bool = False,
               shard_attn_heads: bool = True,
               fsdp: bool = True,
               overrides: Optional[Dict[str, AxisVal]] = None) -> Dict[str, AxisVal]:
    """Default logical->physical table for the production meshes."""
    dp: AxisVal = ("pod", "data") if multi_pod else ("data",)
    fs: AxisVal = dp if fsdp else None
    rules: Dict[str, AxisVal] = {
        # --- parameters -----------------------------------------------
        "embed": fs,           # FSDP: shard d_model dim of weights over data
        "q_heads": "model" if shard_attn_heads else None,
        "kv_heads": None,      # kv heads in {1,8,16} -> replicated under TP=16
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",    # EP
        "expert_embed": fs,    # FSDP dim of expert weights (gathered in block)
        "expert_mlp": None,
        "rnn": "model",        # RG-LRU width TP (elementwise recurrence)
        "ssm_heads": "model",  # mamba heads TP
        "ssm_state": None,
        "conv": None,
        "layers": None,        # scan axis, never sharded
        # --- activations ----------------------------------------------
        "batch": dp,
        "seq": None,
        "cache_seq": None,   # decode overrides: ('model',) flash-decode
        # sequence-parallel residual stream (Korthikanti-style): shard the
        # seq dim of the residual over 'model' between TP blocks, turning
        # activation all-reduces into reduce-scatter + on-demand gathers.
        # Off by default; enabled per-cell in §Perf hillclimbs.
        "residual_seq": None,
        "act_embed": None,
        "act_heads": "model" if shard_attn_heads else None,
        "act_kv_heads": None,
        "act_mlp": "model",
        "act_vocab": "model",
        "act_rnn": "model",
        "act_ssm_heads": "model",
    }
    if overrides:
        rules.update(overrides)
    return rules


def rules_for_config(cfg, *, multi_pod: bool = False,
                     overrides: Optional[Dict[str, AxisVal]] = None) -> Dict[str, AxisVal]:
    return make_rules(multi_pod=multi_pod,
                      shard_attn_heads=cfg.shard_attn_heads,
                      overrides=overrides)


# ---------------------------------------------------------------------------
# Resolution + annotation
# ---------------------------------------------------------------------------

def to_pspec(axes: Sequence[Optional[str]],
             rules: Optional[Dict[str, AxisVal]] = None) -> PartitionSpec:
    """Logical axes tuple -> PartitionSpec under the active rules."""
    rules = rules if rules is not None else (_current() or {})
    parts = []
    used: set = set()
    for name in axes:
        val = rules.get(name) if name is not None else None
        # one mesh axis may appear only once in a spec
        if val is None:
            parts.append(None)
            continue
        vals = (val,) if isinstance(val, str) else tuple(val)
        vals = tuple(v for v in vals if v not in used)
        used.update(vals)
        if not vals:
            parts.append(None)
        elif len(vals) == 1:
            parts.append(vals[0])
        else:
            parts.append(vals)
    # trim trailing Nones for tidiness
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def lshard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes; no-op without rules."""
    rules = _current()
    if rules is None:
        return x
    assert len(axes) == x.ndim, f"{axes} vs rank {x.ndim}"
    spec = to_pspec(axes, rules)
    mesh = current_mesh()
    if mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, axes: Sequence[Optional[str]],
                   rules: Dict[str, AxisVal]) -> NamedSharding:
    return NamedSharding(mesh, to_pspec(axes, rules))


def tree_pspecs(axes_tree, rules: Dict[str, AxisVal]):
    """Map a pytree of logical-axes tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: to_pspec(axes, rules), axes_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            a is None or isinstance(a, str) for a in v),
    )


def tree_shardings(mesh: Mesh, axes_tree, rules: Dict[str, AxisVal]):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        tree_pspecs(axes_tree, rules),
                        is_leaf=lambda v: isinstance(v, PartitionSpec))
