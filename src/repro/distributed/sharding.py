"""Logical-axis sharding rules (MaxText-style) for the repro framework.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"q_heads", ...). A rule table maps logical names to physical mesh axes.
Rules are installed with the ``axis_rules`` context manager; when no rules
are active (e.g. single-device smoke tests) every annotation is a no-op.

FSDP+TP layout (see DESIGN.md §6):
  - params' embed dim            -> fsdp axes ("data",) or ("pod","data")
  - heads / mlp / vocab /experts -> "model" (TP / EP)
  - activations' batch           -> ("data",) or ("pod","data")
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisVal = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def _current() -> Optional[Dict[str, AxisVal]]:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, AxisVal], mesh: Optional[Mesh] = None):
    prev_r = getattr(_state, "rules", None)
    prev_m = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = prev_r
        _state.mesh = prev_m


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

def make_rules(*, multi_pod: bool = False,
               shard_attn_heads: bool = True,
               fsdp: bool = True,
               overrides: Optional[Dict[str, AxisVal]] = None) -> Dict[str, AxisVal]:
    """Default logical->physical table for the production meshes."""
    dp: AxisVal = ("pod", "data") if multi_pod else ("data",)
    fs: AxisVal = dp if fsdp else None
    rules: Dict[str, AxisVal] = {
        # --- parameters -----------------------------------------------
        "embed": fs,           # FSDP: shard d_model dim of weights over data
        "q_heads": "model" if shard_attn_heads else None,
        "kv_heads": None,      # kv heads in {1,8,16} -> replicated under TP=16
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",    # EP
        "expert_embed": fs,    # FSDP dim of expert weights (gathered in block)
        "expert_mlp": None,
        "rnn": "model",        # RG-LRU width TP (elementwise recurrence)
        "ssm_heads": "model",  # mamba heads TP
        "ssm_state": None,
        "conv": None,
        "layers": None,        # scan axis, never sharded
        # --- activations ----------------------------------------------
        "batch": dp,
        "seq": None,
        "cache_seq": None,   # decode overrides: ('model',) flash-decode
        # sequence-parallel residual stream (Korthikanti-style): shard the
        # seq dim of the residual over 'model' between TP blocks, turning
        # activation all-reduces into reduce-scatter + on-demand gathers.
        # Off by default; enabled per-cell in §Perf hillclimbs.
        "residual_seq": None,
        "act_embed": None,
        "act_heads": "model" if shard_attn_heads else None,
        "act_kv_heads": None,
        "act_mlp": "model",
        "act_vocab": "model",
        "act_rnn": "model",
        "act_ssm_heads": "model",
    }
    if overrides:
        rules.update(overrides)
    return rules


def rules_for_config(cfg, *, multi_pod: bool = False,
                     overrides: Optional[Dict[str, AxisVal]] = None) -> Dict[str, AxisVal]:
    return make_rules(multi_pod=multi_pod,
                      shard_attn_heads=cfg.shard_attn_heads,
                      overrides=overrides)


SERVING_MESH_AXES: Tuple[str, ...] = ("data",)


def serving_rules(overrides: Optional[Dict[str, AxisVal]] = None
                  ) -> Dict[str, AxisVal]:
    """Logical->physical table for the *serving* mesh (a 1-D "data" axis
    over the inference devices). Trunk embed is data-parallel: activation
    batches split over "data" while every weight axis stays replicated —
    the trunks the zoo serves are small enough that staging one copy per
    device is cheaper than cross-device weight gathers on the hot path.
    """
    rules: Dict[str, AxisVal] = {
        # trunk weights: replicated (staged once per device via the
        # batch-invariant NamedSharding below)
        "embed": None,          # input width dim of W / centers
        "mlp": None,            # output width dim of W
        "vocab": None,
        # activations: rows split across the mesh
        "batch": ("data",),
        "act_embed": None,
    }
    if overrides:
        rules.update(overrides)
    return rules


def serving_batch_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding of a [rows, width] activation batch on the serving mesh."""
    return named_sharding(mesh, ("batch", "act_embed"), serving_rules())


def serving_weight_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """Replicated sharding for a staged weight tensor (any rank)."""
    axes = ("embed", "mlp")[:ndim] if ndim <= 2 else (None,) * ndim
    return named_sharding(mesh, axes, serving_rules())


def axis_size(axis_name: str) -> int:
    """Version-portable mapped-axis size (inside shard_map bodies).

    jax >= 0.5 has ``jax.lax.axis_size``; on 0.4.x the same static size
    comes from ``jax.core.axis_frame``.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    import jax.core as _core
    return int(_core.axis_frame(axis_name))


def shard_map(f, *, mesh: Mesh, in_specs, out_specs,
              check_replication: bool = False):
    """Version-portable ``shard_map``.

    jax >= 0.5 exposes ``jax.shard_map`` (replication checking via
    ``check_vma``); 0.4.x only has ``jax.experimental.shard_map``
    (``check_rep``). Call sites in this repo always want the check off —
    Pallas calls and collectives inside the body defeat the checker —
    so both spellings are bridged behind one keyword.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             check_vma=check_replication)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_replication)


# ---------------------------------------------------------------------------
# Resolution + annotation
# ---------------------------------------------------------------------------

def to_pspec(axes: Sequence[Optional[str]],
             rules: Optional[Dict[str, AxisVal]] = None) -> PartitionSpec:
    """Logical axes tuple -> PartitionSpec under the active rules."""
    rules = rules if rules is not None else (_current() or {})
    parts = []
    used: set = set()
    for name in axes:
        val = rules.get(name) if name is not None else None
        # one mesh axis may appear only once in a spec
        if val is None:
            parts.append(None)
            continue
        vals = (val,) if isinstance(val, str) else tuple(val)
        vals = tuple(v for v in vals if v not in used)
        used.update(vals)
        if not vals:
            parts.append(None)
        elif len(vals) == 1:
            parts.append(vals[0])
        else:
            parts.append(vals)
    # trim trailing Nones for tidiness
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def lshard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes; no-op without rules."""
    rules = _current()
    if rules is None:
        return x
    assert len(axes) == x.ndim, f"{axes} vs rank {x.ndim}"
    spec = to_pspec(axes, rules)
    mesh = current_mesh()
    if mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, axes: Sequence[Optional[str]],
                   rules: Dict[str, AxisVal]) -> NamedSharding:
    return NamedSharding(mesh, to_pspec(axes, rules))


def tree_pspecs(axes_tree, rules: Dict[str, AxisVal]):
    """Map a pytree of logical-axes tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: to_pspec(axes, rules), axes_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            a is None or isinstance(a, str) for a in v),
    )


def tree_shardings(mesh: Mesh, axes_tree, rules: Dict[str, AxisVal]):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        tree_pspecs(axes_tree, rules),
                        is_leaf=lambda v: isinstance(v, PartitionSpec))
