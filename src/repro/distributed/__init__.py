from repro.distributed.sharding import (
    axis_rules,
    lshard,
    make_rules,
    named_sharding,
    rules_for_config,
    to_pspec,
    tree_pspecs,
    tree_shardings,
)

__all__ = [
    "axis_rules", "lshard", "make_rules", "named_sharding",
    "rules_for_config", "to_pspec", "tree_pspecs", "tree_shardings",
]
