"""GPipe-style pipeline parallelism over a mesh axis (the `pod` axis of
the multi-pod mesh: 2 stages x 256-chip pods, cutting cross-pod traffic
to one activation transfer per microbatch tick).

Collective pipelining under `shard_map`: each stage rank owns L/S layer
groups; microbatches ripple through a ppermute ring for M + S - 1 ticks.
Differentiable end-to-end (ppermute transposes to the reverse permute, so
the backward schedule falls out of autodiff), so the same runner serves
training.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import axis_size


def gpipe_apply(stage_fn: Callable, stage_params, microbatches: jax.Array,
                *, axis: str) -> jax.Array:
    """Run inside shard_map. stage_fn(params, x) -> y applies this rank's
    layer group. microbatches: [M, mb, ...] (replicated across stages).
    Returns [M, mb, ...] outputs of the final stage (replicated).
    """
    S = axis_size(axis)
    sid = jax.lax.axis_index(axis)
    M = microbatches.shape[0]
    T = M + S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]
    zero = jnp.zeros_like(microbatches[0])

    def tick(carry, t):
        buf_in, outputs = carry
        # stage 0 injects microbatch t (clamped; masked later)
        x0 = microbatches[jnp.clip(t, 0, M - 1)]
        x = jnp.where(sid == 0, x0, buf_in)
        y = stage_fn(stage_params, x)
        buf_next = jax.lax.ppermute(y, axis, perm)
        # final stage emits microbatch t-(S-1) at tick t
        out_idx = jnp.clip(t - (S - 1), 0, M - 1)
        is_out = jnp.logical_and(sid == S - 1, t >= S - 1)
        outputs = jax.lax.dynamic_update_index_in_dim(
            outputs,
            jnp.where(is_out, y, outputs[out_idx]), out_idx, 0)
        return (buf_next, outputs), None

    outputs0 = jnp.zeros_like(microbatches)
    (_, outputs), _ = jax.lax.scan(tick, (zero, outputs0),
                                   jnp.arange(T))
    # replicate the final-stage outputs to every rank
    return jax.lax.psum(jnp.where(sid == S - 1, outputs, 0.0), axis)


def make_pipelined_fn(stage_fn: Callable, mesh: Mesh, *, axis: str = "pod",
                      params_spec=None) -> Callable:
    """Wrap stage_fn into a jit-able pipelined forward.

    params are sharded over ``axis`` on their leading (stage) dim;
    microbatches are replicated. Returns f(stage_params, microbatches).
    """
    pspec = params_spec if params_spec is not None else P(axis)

    def fn(stage_params, microbatches):
        def inner(p, mb):
            # leading stage dim is 1 per rank -> squeeze
            local = jax.tree.map(lambda a: a[0], p)
            return gpipe_apply(lambda pp, x: stage_fn(pp, x), local, mb,
                               axis=axis)
        from repro.distributed.sharding import shard_map
        return shard_map(
            inner, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: pspec, stage_params), P()),
            out_specs=P())(stage_params, microbatches)

    return fn


def pipeline_bubble_fraction(num_micro: int, num_stages: int) -> float:
    """GPipe bubble overhead: (S-1)/(M+S-1)."""
    return (num_stages - 1) / (num_micro + num_stages - 1)
