"""HLO analysis: collective byte accounting + roofline terms.

``cost_analysis()`` gives FLOPs and bytes-accessed but NOT collective
traffic; we parse the post-SPMD optimized HLO text, build a symbol table of
instruction result sizes, and sum operand sizes for every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
including their -start async variants; -done variants are skipped to avoid
double counting).

Hardware model (TPU v5e, per brief):
  peak 197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    op_counts: Dict[str, int] = field(default_factory=dict)
    operand_bytes: Dict[str, int] = field(default_factory=dict)
    result_bytes: Dict[str, int] = field(default_factory=dict)

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_result_bytes(self) -> int:
        return sum(self.result_bytes.values())

    def to_dict(self) -> dict:
        return {"op_counts": self.op_counts,
                "operand_bytes": self.operand_bytes,
                "result_bytes": self.result_bytes,
                "total_operand_bytes": self.total_operand_bytes,
                "total_result_bytes": self.total_result_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    # symbol table: instruction name -> result bytes
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, type_str, _op = m.groups()
            sizes[name] = shape_bytes(type_str)

    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        stats.op_counts[base] = stats.op_counts.get(base, 0) + 1
        stats.result_bytes[base] = (stats.result_bytes.get(base, 0)
                                    + shape_bytes(type_str))
        # operand names inside the parens of this call
        paren = line[line.index("(") + 1:]
        depth = 1
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = paren[:end]
        ob = 0
        for am in re.finditer(r"%([\w.\-]+)", args):
            ob += sizes.get(am.group(1), 0)
        if ob == 0:  # operands may be typed inline without %-names
            ob = shape_bytes(args)
        stats.operand_bytes[base] = stats.operand_bytes.get(base, 0) + ob
    return stats


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------

# Effective link-cost weight per collective byte (ring schedules):
#   all-reduce moves ~2x the payload; others ~1x.
_COLL_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_operand_bytes: Dict[str, float]) -> dict:
    """Three roofline terms in seconds (per the brief's formulas).

    All inputs are per-device: FLOPs from the jaxpr counter (global/chips),
    bytes + collective traffic from the loop-aware HLO analyzer on the
    post-SPMD per-device module.
    """
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    coll_bytes = sum(coll_operand_bytes.values())
    weighted = sum(_COLL_WEIGHT.get(k, 1.0) * v
                   for k, v in coll_operand_bytes.items())
    collective_s = weighted / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "collective_bytes": coll_bytes,
        "collective_bytes_weighted": weighted,
        "dominant": dominant,
    }


def model_flops(cfg, shape, *, per_device: bool = True, chips: int = 256) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens.

    Enc-dec archs split the seq budget (enc, dec) = (S/2, S/2) and only the
    decoder runs at decode time, so N is apportioned per sub-stack.
    """
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    mult = 6.0 if shape.kind == "train" else 2.0
    if cfg.is_encoder_decoder:
        # rough split: encoder layers vs decoder layers (+embed on decoder)
        n_layers = cfg.num_layers + cfg.num_encoder_layers
        n_enc = n * cfg.num_encoder_layers / n_layers
        n_dec = n - n_enc
        se = shape.seq_len - shape.seq_len // 2
        sd = shape.seq_len // 2
        if shape.kind == "decode":
            total = mult * n_dec * shape.global_batch
        else:
            total = mult * (n_enc * se + n_dec * sd) * shape.global_batch
    elif shape.kind == "decode":
        total = mult * n * shape.global_batch
    else:
        total = mult * n * shape.global_batch * shape.seq_len
    return total / chips if per_device else total
