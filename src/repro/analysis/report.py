"""Roofline report: aggregate dry-run artifacts into the §Roofline table."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.analysis.hlo import HBM_BW, ICI_BW, PEAK_FLOPS

SKIP_NOTES = {
    ("llama3-405b", "long_500k"): "full attention — skipped per brief",
    ("gemma-2b", "long_500k"): "full attention — skipped per brief",
    ("granite-3-8b", "long_500k"): "full attention — skipped per brief",
    ("chameleon-34b", "long_500k"): "full attention — skipped per brief",
    ("whisper-medium", "long_500k"): "full attention — skipped per brief",
    ("olmoe-1b-7b", "long_500k"): "full attention — skipped per brief",
    ("kimi-k2-1t-a32b", "long_500k"): "full attention — skipped per brief",
}

IMPROVEMENT_NOTES = {
    "compute": ("remat recompute + attention-score FLOPs are the gap to "
                "6ND; reduce remat (policy) or fuse attention (Pallas)"),
    "memory": ("unfused attention-score/activation round-trips dominate; "
               "Pallas flash attention keeps them in VMEM"),
    "collective": ("gradient all-reduce should be a reduce-scatter onto "
                   "FSDP shards; overlap with bwd compute"),
}


def load_records(art_dir: Path, mesh: str = "single") -> List[dict]:
    recs = []
    for p in sorted((art_dir / mesh).glob("*/*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def roofline_row(rec: dict) -> dict:
    r = rec["roofline"]
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = (r["compute_s"] / bound) if bound > 0 else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": r["compute_s"],
        "memory_s": r["memory_s"],
        "collective_s": r["collective_s"],
        "dominant": dominant,
        "roofline_fraction": frac,   # compute / bound: 1.0 = compute-bound
        "useful_ratio": rec.get("useful_flops_ratio"),
        "model_flops_pd": rec.get("model_flops_per_device"),
        "flops_pd": rec.get("flops_per_device"),
        "note": IMPROVEMENT_NOTES[dominant],
    }


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute(s) | memory(s) | coll(s) | "
           "dominant | roofline-frac | 6ND/HLO |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        ur = r["useful_ratio"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} "
            f"| {r['collective_s']:.4g} | {r['dominant']} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {ur:.3f} |\n" if ur is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | - | - | - |\n")
    return "".join(out)


def summarize(art_dir: Path) -> Dict[str, list]:
    single = [roofline_row(r) for r in load_records(art_dir, "single")]
    multi = [roofline_row(r) for r in load_records(art_dir, "multi")]
    return {"single": single, "multi": multi}
