"""Loop-aware cost extraction from optimized (post-SPMD) HLO text.

XLA's ``cost_analysis()`` counts while-loop bodies once; real steps execute
the layer scan ``num_layers`` times. This parser reconstructs per-device
HBM bytes and collective traffic by walking the computation graph with
while-loop trip counts extracted from loop condition computations
(`compare(%iv, %constant(N)), direction=LT` -> N iterations).

Bytes model (matches XLA's "bytes accessed" semantics): every top-level
instruction contributes operands+result; fusion internals are free (they
never touch HBM); while/conditional/call recurse.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_TYPE_RE = re.compile(r"([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")
_OP_RE = re.compile(r"\s*([\w\-]+)\((.*)$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id", "iota"}


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    args: str
    line: str
    is_root: bool = False


def _parse_instr(raw: str) -> Optional["Instr"]:
    """Parse one instruction line; robust to tuple types with
    ``/*index=N*/`` comments and layout annotations."""
    s = _COMMENT_RE.sub("", raw)
    is_root = s.lstrip().startswith("ROOT")
    nm = _NAME_RE.match(s)
    if not nm:
        return None
    rest = s[nm.end():]
    if rest.startswith("("):  # tuple result type: balanced-paren scan
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str, tail = rest[:end + 1], rest[end + 1:]
    else:
        tm = _TYPE_RE.match(rest)
        if not tm:
            return None
        type_str, tail = tm.group(1), rest[tm.end():]
    om = _OP_RE.match(tail)
    if not om:
        return None
    return Instr(nm.group(1), type_str, om.group(1), om.group(2), s, is_root)


@dataclass
class HloCost:
    bytes_accessed: float = 0.0
    # dtype-promotion round-trips the CPU pipeline inserts (f32 copies of
    # bf16 weights/caches). The TPU MXU consumes bf16 natively, so these
    # are charged separately and excluded from bytes_accessed (documented
    # in EXPERIMENTS.md §Methodology).
    bytes_cpu_dtype_artifacts: float = 0.0
    dot_flops: float = 0.0
    collective_operand_bytes: Dict[str, float] = field(default_factory=dict)
    collective_result_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    # raw (uncorrected) operand bytes: the CPU pipeline promotes bf16
    # tensors to f32 before collectives; at jax level grads/activations are
    # bf16 (verified in tests), so f32 collective payloads are charged at
    # half size, with the raw figure kept here.
    collective_operand_bytes_raw: Dict[str, float] = field(
        default_factory=dict)
    loop_trip_counts: List[int] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_operand_bytes.values())

    def to_dict(self) -> dict:
        return {
            "bytes_cpu_dtype_artifacts": self.bytes_cpu_dtype_artifacts,
            "bytes_accessed": self.bytes_accessed,
            "dot_flops": self.dot_flops,
            "collective_operand_bytes": self.collective_operand_bytes,
            "collective_operand_bytes_raw": self.collective_operand_bytes_raw,
            "collective_result_bytes": self.collective_result_bytes,
            "collective_counts": self.collective_counts,
            "total_collective_bytes": self.total_collective_bytes,
            "loop_trip_counts": self.loop_trip_counts[:64],
        }


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self.sizes: Dict[str, int] = {}
        self.types: Dict[str, str] = {}
        self._producers: Dict[str, Instr] = {}
        for comp in self.computations.values():
            for ins in comp:
                self.sizes[ins.name] = shape_bytes(ins.type_str)
                self.types[ins.name] = ins.type_str
                self._producers[ins.name] = ins

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            m = _COMP_RE.match(raw)
            if m and raw.rstrip().endswith("{"):
                cur = m.group(1)
                self.computations[cur] = []
                if raw.lstrip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if raw.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            ins = _parse_instr(raw)
            if ins:
                self.computations[cur].append(ins)

    # -- helpers -----------------------------------------------------------
    def _operand_bytes(self, ins: Instr) -> int:
        depth, end = 1, len(ins.args)
        for i, ch in enumerate(ins.args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = ins.args[:end]
        total = 0
        seen = False
        for am in re.finditer(r"%([\w.\-]+)", args):
            total += self.sizes.get(am.group(1), 0)
            seen = True
        if not seen:
            total = shape_bytes(args)
        return total

    def _called(self, ins: Instr, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-]+)", ins.line)
        return m.group(1) if m else None

    def _trip_count(self, cond_name: str) -> int:
        comp = self.computations.get(cond_name, [])
        # find constant feeding a LT/LE compare
        consts: Dict[str, int] = {}
        for ins in comp:
            cm = re.match(r"constant\((\d+)\)", ins.op + "(" + ins.args)
            if ins.op == "constant":
                vm = re.search(r"constant\((\d+)\)", ins.line)
                if vm:
                    consts[ins.name] = int(vm.group(1))
        # compare may live in a nested fusion computation
        for ins in comp:
            target = None
            if ins.op == "compare":
                target = ins
            elif ins.op == "fusion":
                called = self._called(ins, "calls")
                if called and any(i.op == "compare"
                                  for i in self.computations.get(called, [])):
                    target = ins
            if target is None:
                continue
            for am in re.finditer(r"%([\w.\-]+)", target.args):
                if am.group(1) in consts:
                    return max(1, consts[am.group(1)])
        # fall back: constants anywhere in the condition
        if consts:
            return max(1, max(consts.values()))
        return 1

    def _collective_corrected_bytes(self, ins: Instr, raw: float) -> float:
        """Charge f32 collective payloads at bf16 size (the jax-level dtype
        of grads/activations; CPU promotes them to f32 — see to_dict)."""
        f32b = 0
        total = 0
        for name in self._operand_names(ins):
            sz = self.sizes.get(name, 0)
            total += sz
            # operand dtype from its producing instruction's type string
            prod = self._producer_type(name)
            if prod and prod.startswith(("f32", "f64", "(f32")):
                f32b += sz
        if total == 0:
            # operands typed inline
            f32b = raw if "f32[" in ins.args.split(")")[0] else 0
            total = raw
        return raw - 0.5 * f32b * (raw / total if total else 1.0)

    def _producer_type(self, name: str) -> Optional[str]:
        return self.types.get(name)

    def _operand_names(self, ins: Instr) -> List[str]:
        depth, end = 1, len(ins.args)
        for i, ch in enumerate(ins.args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return re.findall(r"%([\w.\-]+)", ins.args[:end])

    # pure dtype/layout-change fusions: the TPU pipeline consumes bf16 and
    # arbitrary dot layouts natively (no materialized converts/transposes)
    _CONVERT_ONLY = {"convert", "reduce-precision", "parameter", "constant",
                     "bitcast", "copy", "transpose"}

    def _instr_bytes(self, ins: Instr) -> Tuple[float, float]:
        """(HBM traffic, CPU-dtype-artifact traffic) for one instruction.

        dynamic-slice reads only the slice (result size); a root
        dynamic-update-slice writes only the update region (read+write of
        the slice); fusions look through to their parameters' access
        patterns (a param consumed only by dynamic-slice/gather is charged
        at the sliced size), matching XLA bytes-accessed semantics.

        TPU dtype model: the CPU pipeline promotes bf16 operands to f32
        (whole-buffer convert round-trips); the MXU consumes bf16 natively,
        so convert-only fusions are charged as artifacts, and a fusion whose
        root converts a dynamic-update-slice is charged as the DUS.
        """
        if ins.op == "dynamic-slice":
            return 2.0 * shape_bytes(ins.type_str), 0.0
        if ins.op == "dynamic-update-slice":
            ops = self._operand_names(ins)
            upd = self.sizes.get(ops[1], 0) if len(ops) > 1 else 0
            return 3.0 * upd, 0.0  # read region + read update + write region
        if ins.op == "fusion":
            called = self._called(ins, "calls")
            comp = self.computations.get(called or "", [])
            if not comp:
                return (self._operand_bytes(ins)
                        + shape_bytes(ins.type_str)), 0.0
            by_name = {i.name: i for i in comp}
            uses: Dict[str, List[Instr]] = {}
            for i2 in comp:
                for ref in self._operand_names(i2):
                    uses.setdefault(ref, []).append(i2)
            ops_inside = {i2.op for i2 in comp}
            root = next((i2 for i2 in comp if i2.is_root), comp[-1])
            # pure dtype-conversion fusion: free on TPU, tracked as artifact
            if ops_inside <= self._CONVERT_ONLY:
                art = self._operand_bytes(ins) + shape_bytes(ins.type_str)
                return 0.0, art
            total = 0.0
            art = 0.0
            for i2 in comp:
                if i2.op != "parameter":
                    continue
                u = uses.get(i2.name, [])
                if u and all(x.op in ("dynamic-slice", "gather") for x in u):
                    total += sum(shape_bytes(x.type_str) for x in u)
                else:
                    total += shape_bytes(i2.type_str)
            # a convert-wrapped DUS root is the DUS (dtype roundtrip = CPU
            # artifact; on TPU the buffer stays bf16 and updates in place)
            dus = root
            if root.op == "convert":
                rops = self._operand_names(root)
                if rops and rops[0] in by_name \
                        and by_name[rops[0]].op == "dynamic-update-slice":
                    art += shape_bytes(root.type_str) * 2.0
                    dus = by_name[rops[0]]
            if dus.op == "dynamic-update-slice":
                ops = self._operand_names(dus)
                upd_t = (by_name[ops[1]].type_str if len(ops) > 1
                         and ops[1] in by_name else "")
                ub = shape_bytes(upd_t) if upd_t else shape_bytes(dus.type_str)
                # subtract the pass-through buffer param (aliased in place)
                if ops and ops[0] in by_name \
                        and by_name[ops[0]].op == "parameter":
                    total -= shape_bytes(by_name[ops[0]].type_str)
                else:
                    # buffer came through converts: drop the biggest param
                    big = max((shape_bytes(i2.type_str) for i2 in comp
                               if i2.op == "parameter"), default=0)
                    total -= big
                total += 2.0 * ub
            else:
                total += shape_bytes(root.type_str)
            return max(total, 0.0), art
        return (self._operand_bytes(ins) + shape_bytes(ins.type_str)), 0.0

    def _dot_bytes(self, ins: Instr) -> Tuple[float, float]:
        """Dot traffic with jax-level operand dtypes: operands reached via
        convert/transpose-only fusions are charged at the fusion's *input*
        (bf16) size — the MXU reads bf16 weights directly."""
        total = 0.0
        art = 0.0
        for name in self._operand_names(ins):
            sz = self.sizes.get(name, 0)
            prod = self._producers.get(name)
            if prod is not None and prod.op == "fusion":
                called = self._called(prod, "calls")
                comp = self.computations.get(called or "", [])
                if comp and {i.op for i in comp} <= self._CONVERT_ONLY:
                    inp = sum(shape_bytes(i.type_str) for i in comp
                              if i.op == "parameter")
                    art += max(0.0, sz - inp)
                    sz = min(sz, inp)
            elif prod is not None and prod.op == "convert":
                srcs = self._operand_names(prod)
                inp = sum(self.sizes.get(s, 0) for s in srcs)
                if 0 < inp < sz:
                    art += sz - inp
                    sz = inp
            total += sz
        return total + shape_bytes(ins.type_str), art

    def _dot_flops(self, ins: Instr) -> float:
        # result elements x contracted size x 2
        out_elems = 0
        for m in _SHAPE_RE.finditer(ins.type_str):
            n = 1
            dims = m.group(2)
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            out_elems += n
        lcm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
        lhs_name = re.search(r"%([\w.\-]+)", ins.args)
        K = 1
        if lcm and lhs_name:
            # reconstruct lhs dims from the defining instruction
            lhs_ins = None
            for comp in self.computations.values():
                for i2 in comp:
                    if i2.name == lhs_name.group(1):
                        lhs_ins = i2
                        break
            if lhs_ins is not None:
                sm = _SHAPE_RE.search(lhs_ins.type_str)
                if sm and sm.group(2):
                    dims = [int(d) for d in sm.group(2).split(",")]
                    for idx in (lcm.group(1).split(",")
                                if lcm.group(1) else []):
                        K *= dims[int(idx)]
        return 2.0 * out_elems * K

    # -- main walk ----------------------------------------------------------
    def cost(self, comp_name: Optional[str] = None,
             mult: float = 1.0, acc: Optional[HloCost] = None) -> HloCost:
        acc = acc if acc is not None else HloCost()
        comp = self.computations.get(comp_name or self.entry or "", [])
        for ins in comp:
            if ins.op in _FREE_OPS:
                continue
            if ins.op == "while":
                body = self._called(ins, "body")
                cond = self._called(ins, "condition")
                trips = self._trip_count(cond) if cond else 1
                acc.loop_trip_counts.append(trips)
                if body:
                    self.cost(body, mult * trips, acc)
                continue
            if ins.op == "conditional":
                for key in ("true_computation", "false_computation"):
                    c = self._called(ins, key)
                    if c:
                        self.cost(c, mult, acc)
                continue
            if ins.op in ("call", "async-start"):
                c = self._called(ins, "to_apply") or self._called(ins, "calls")
                if c:
                    self.cost(c, mult, acc)
                continue
            base = None
            for cname in _COLLECTIVES:
                if ins.op == cname or ins.op == cname + "-start":
                    base = cname
                    break
            if base:
                raw = self._operand_bytes(ins)
                corrected = self._collective_corrected_bytes(ins, raw)
                acc.collective_operand_bytes[base] = (
                    acc.collective_operand_bytes.get(base, 0.0)
                    + corrected * mult)
                acc.collective_operand_bytes_raw[base] = (
                    acc.collective_operand_bytes_raw.get(base, 0.0)
                    + raw * mult)
                acc.collective_result_bytes[base] = (
                    acc.collective_result_bytes.get(base, 0.0)
                    + shape_bytes(ins.type_str) * mult)
                acc.collective_counts[base] = (
                    acc.collective_counts.get(base, 0.0) + mult)
                acc.bytes_accessed += 2.0 * corrected * mult
                continue
            if ins.op.endswith("-done"):
                continue
            if ins.op == "dot":
                acc.dot_flops += self._dot_flops(ins) * mult
                b, art = self._dot_bytes(ins)
            else:
                b, art = self._instr_bytes(ins)
            acc.bytes_accessed += b * mult
            acc.bytes_cpu_dtype_artifacts += art * mult
        return acc


def analyze_hlo(text: str) -> HloCost:
    return HloModule(text).cost()
