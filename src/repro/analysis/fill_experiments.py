"""Fill the <!-- ROOFLINE_TABLE --> marker in EXPERIMENTS.md from the
dry-run artifacts (single + multi-pod summary)."""
from __future__ import annotations

import sys
from pathlib import Path

from repro.analysis.report import load_records, markdown_table, roofline_row

ROOT = Path(__file__).resolve().parents[3]
MARKER = "<!-- ROOFLINE_TABLE -->"


def build_tables(art: Path) -> str:
    single = [roofline_row(r) for r in load_records(art, "single")]
    multi = [roofline_row(r) for r in load_records(art, "multi")]
    out = ["### Single pod (16x16 = 256 chips)\n\n",
           markdown_table(single), "\n",
           "### Multi-pod (2x16x16 = 512 chips)\n\n",
           markdown_table(multi)]
    return "".join(out)


def main() -> int:
    art = ROOT / "artifacts" / "dryrun"
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    if MARKER not in text:
        print("marker not found", file=sys.stderr)
        return 1
    table = build_tables(art)
    exp.write_text(text.replace(MARKER, table))
    print(f"filled roofline tables ({len(table)} chars)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
