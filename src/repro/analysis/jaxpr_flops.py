"""Exact matmul-FLOP counting from jaxprs.

``compiled.cost_analysis()`` counts while-loop bodies once and sees the CPU
backend's *decomposed* ragged_dot (dense over groups), so it is unusable for
roofline math on scanned/MoE models. The jaxpr is the ground truth for the
math actually specified: scan lengths are static, ragged_dot is 2*m*k*n,
and shard_map bodies are per-shard (multiplied back by mesh size).

Counted: dot_general, ragged_dot[_general]. Elementwise/transcendental ops
are excluded (<1% of LLM step FLOPs; documented in EXPERIMENTS.md).
Returns GLOBAL flops; divide by chip count for the ideal-parallel
per-device figure (replicated-compute caveats documented per arch).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _dot_general_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval.shape, eqn.invars[1].aval.shape
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    K = _prod(lhs[i] for i in lc)
    B = _prod(lhs[i] for i in lb)
    M = _prod(lhs[i] for i in range(len(lhs)) if i not in set(lc) | set(lb))
    N = _prod(rhs[i] for i in range(len(rhs)) if i not in set(rc) | set(rb))
    return 2.0 * B * M * N * K


def _ragged_dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval.shape, eqn.invars[1].aval.shape
    # simple form: lhs [m,k], rhs [g,k,n] -> each lhs row hits one group
    m, k = lhs[0], lhs[1]
    n = rhs[-1]
    return 2.0 * m * k * n


def count_flops(jaxpr, mult: float = 1.0) -> float:
    """Recursively count matmul FLOPs of a (Closed)Jaxpr."""
    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += mult * _dot_general_flops(eqn)
        elif prim in ("ragged_dot", "ragged_dot_general"):
            total += mult * _ragged_dot_flops(eqn)
        elif prim == "scan":
            total += count_flops(eqn.params["jaxpr"],
                                 mult * eqn.params["length"])
        elif prim == "while":
            # we never emit raw while; count body once (conservative)
            total += count_flops(eqn.params["body_jaxpr"], mult)
        elif prim == "cond":
            branches = eqn.params["branches"]
            total += max(count_flops(b, mult) for b in branches)
        elif prim == "shard_map":
            mesh = eqn.params.get("mesh")
            size = getattr(mesh, "size", 1)
            total += count_flops(eqn.params["jaxpr"], mult * size)
        else:
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                if key in eqn.params:
                    total += count_flops(eqn.params[key], mult)
                    break
    return total


def flops_of(fn, *abstract_args) -> float:
    jaxpr = jax.make_jaxpr(fn)(*abstract_args)
    return count_flops(jaxpr)
