"""Operator DAG + Algorithm 1 (pipeline dependency discovery).

Queries are parsed into a DAG of relational + inference operators. The
dependency-discovery algorithm labels edges (data vs control dependency)
and produces a DFS-based topological execution order, prioritizing
high-cost operators (paper §5.2, Algorithm 1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple


@dataclass
class Node:
    op_id: str
    kind: str                     # scan | filter | join | groupby | window
    #                             # | predict | embed | sink
    fn: Optional[Callable] = None
    cost_hint: float = 1.0        # relative cost estimate for prioritization
    device: str = "host"          # host | tpu | api  (set by the cost model)
    meta: Dict[str, Any] = field(default_factory=dict)


@dataclass
class Edge:
    src: str
    dst: str
    label: str = "data"           # data | control (Algorithm 1 lines 6-12)


class Dag:
    def __init__(self):
        self.nodes: Dict[str, Node] = {}
        self.edges: List[Edge] = []

    def add(self, node: Node, deps: Tuple[str, ...] = (),
            control_deps: Tuple[str, ...] = ()) -> Node:
        if node.op_id in self.nodes:
            raise ValueError(f"duplicate op {node.op_id}")
        self.nodes[node.op_id] = node
        for d in deps:
            self.edges.append(Edge(d, node.op_id, "data"))
        for d in control_deps:
            self.edges.append(Edge(d, node.op_id, "control"))
        return node

    # -- Algorithm 1 -------------------------------------------------------
    def dependency_map(self) -> Dict[str, Set[str]]:
        """lines 3-5: D(v) = {u | (u, v) in E}."""
        dep: Dict[str, Set[str]] = {v: set() for v in self.nodes}
        for e in self.edges:
            dep[e.dst].add(e.src)
        return dep

    def label_edges(self) -> List[Edge]:
        """lines 6-12: classify edges. An edge is a *data* dependency when
        the upstream's output feeds the downstream's input; control
        dependencies only constrain ordering (e.g. barrier after DDL)."""
        for e in self.edges:
            if e.label not in ("data", "control"):
                e.label = "data"
        return self.edges

    def execution_order(self) -> List[str]:
        """lines 13-15: DFS topological sort; among ready nodes the
        higher-cost operator is scheduled first so long poles start early
        (critical-path prioritization)."""
        dep = self.dependency_map()
        order: List[str] = []
        visited: Set[str] = set()
        visiting: Set[str] = set()

        def dfs(v: str) -> None:
            if v in visited:
                return
            if v in visiting:
                raise ValueError(f"cycle through {v}")
            visiting.add(v)
            for u in sorted(dep[v],
                            key=lambda u: -self.nodes[u].cost_hint):
                dfs(u)
            visiting.discard(v)
            visited.add(v)
            order.append(v)

        roots = sorted(self.nodes,
                       key=lambda v: -self.nodes[v].cost_hint)
        for v in roots:
            dfs(v)
        return order

    def stages(self) -> List[List[str]]:
        """Wave decomposition: nodes whose deps are all satisfied run in
        the same stage (the unit of pipeline overlap)."""
        dep = self.dependency_map()
        done: Set[str] = set()
        waves: List[List[str]] = []
        remaining = set(self.nodes)
        while remaining:
            ready = sorted([v for v in remaining if dep[v] <= done],
                           key=lambda v: -self.nodes[v].cost_hint)
            if not ready:
                raise ValueError("cycle detected")
            waves.append(ready)
            done.update(ready)
            remaining -= set(ready)
        return waves

    def validate_topological(self, order: List[str]) -> bool:
        pos = {v: i for i, v in enumerate(order)}
        return all(pos[e.src] < pos[e.dst] for e in self.edges)
