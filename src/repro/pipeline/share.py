"""Pre-embedding with vector sharing (paper §5.1).

Embeddings are computed once per (table, column, content-fingerprint,
embedder-version) and stored as Mvec blocks; later queries referencing the
same data reuse them instead of re-embedding. The paper pairs this with
SIMD vectorization — our TPU analogue is the fused normalize+project
Pallas kernel (repro.kernels.fused_embed); on host we batch-vectorize with
numpy (SIMD via BLAS).
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.storage import mvec


def fingerprint(arr: np.ndarray) -> str:
    # Full-content hash: query results are served from this cache, so a
    # partial fingerprint would silently return stale embeddings after a
    # mid-buffer mutation. sha1 is ~1 GB/s — noise next to embedding.
    h = hashlib.sha1()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()[:16]


@dataclass
class ShareStats:
    hits: int = 0
    misses: int = 0
    embed_seconds: float = 0.0
    bytes_stored: int = 0


class VectorShareCache:
    """In-DB embedding cache: memory tier + optional Mvec disk tier."""

    def __init__(self, root: Optional[Path] = None,
                 capacity_bytes: int = 1 << 30):
        self.root = Path(root) if root else None
        if self.root:
            self.root.mkdir(parents=True, exist_ok=True)
        self.capacity = capacity_bytes
        self._mem: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self._used = 0
        self._lock = threading.Lock()
        self.stats = ShareStats()

    def _key(self, table: str, column: str, fp: str, version: str) -> str:
        return f"{table}.{column}.{version}.{fp}"

    def get_or_embed(self, table: str, column: str, data: np.ndarray,
                     embed_fn: Callable[[np.ndarray], np.ndarray],
                     version: str = "v1") -> np.ndarray:
        key = self._key(table, column, fingerprint(data), version)
        with self._lock:
            if key in self._mem:
                self.stats.hits += 1
                self._mem.move_to_end(key)
                return self._mem[key]
        if self.root and (self.root / f"{key}.mvec").exists():
            vec = mvec.decode((self.root / f"{key}.mvec").read_bytes())
            with self._lock:
                self.stats.hits += 1
                self._put(key, np.asarray(vec))
            return np.asarray(vec)
        t0 = time.time()
        vec = np.asarray(embed_fn(data))
        dt = time.time() - t0
        with self._lock:
            self.stats.misses += 1
            self.stats.embed_seconds += dt
            self._put(key, vec)
        if self.root:
            (self.root / f"{key}.mvec").write_bytes(mvec.encode(vec))
            self.stats.bytes_stored += vec.nbytes
        return vec

    def _put(self, key: str, vec: np.ndarray) -> None:
        if key in self._mem:
            self._used -= self._mem[key].nbytes
        self._mem[key] = vec
        self._mem.move_to_end(key)
        self._used += vec.nbytes
        while self._used > self.capacity and len(self._mem) > 1:
            _, old = self._mem.popitem(last=False)
            self._used -= old.nbytes

    @property
    def hit_rate(self) -> float:
        t = self.stats.hits + self.stats.misses
        return self.stats.hits / t if t else 0.0


def simd_normalize_embed(X: np.ndarray, W: np.ndarray,
                         mean: float = 0.0, scale: float = 1.0) -> np.ndarray:
    """Host reference of the fused normalize+project embedder (the Pallas
    kernel's oracle): y = tanh(((x - mean) * scale) @ W)."""
    Z = (X.astype(np.float32) - mean) * scale
    return np.tanh(Z @ W.astype(np.float32))
