"""Pre-embedding with vector sharing (paper §5.1).

Embeddings are computed once per (table, column, content-fingerprint,
embedder-version) and stored as Mvec blocks; later queries referencing the
same data reuse them instead of re-embedding. In cost-model terms this
zeroes Eq. 5's ExecTime term for warm rows — the trunk forward that
dominates ``C_op = ExecTime + TransCost`` becomes a fingerprint lookup
and gather — which is why both the optimizer's embed split and the
serving lanes (Eq. 11 row budgets, ``docs/serving.md``) consult this
cache before any backend runs. The *embedder-version* key is the trunk
identity (``ResolvedModel.trunk_fp``), so fine-tune deltas of one base
share their base's cached embeddings. The paper pairs sharing with SIMD
vectorization — our TPU analogue is the fused normalize+project Pallas
kernel (repro.kernels.fused_embed); on host we batch-vectorize with
numpy (SIMD via BLAS), including the one-pass murmur-style row
fingerprints ``get_many``/``put_many`` ride.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.storage import mvec


def fingerprint(arr: np.ndarray) -> str:
    # Full-content hash: query results are served from this cache, so a
    # partial fingerprint would silently return stale embeddings after a
    # mid-buffer mutation. sha1 is ~1 GB/s — noise next to embedding.
    h = hashlib.sha1()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()[:16]


# Word-wise murmur3-style hash (rotate-multiply rounds + avalanche
# finalizer). Plain FNV is not enough here: float rows concentrate
# entropy in a word's *high* bits (sign/exponent), and multiply-only
# mixing never diffuses high bits downward, so one-hot rows collide.
# One 64-bit fingerprint per row matches the chunk-level convention
# (``fingerprint`` keeps 64 bits of sha1); collisions are birthday-
# bounded at ~n^2 / 2^65 over distinct rows.
_SEED = np.uint64(0xCBF29CE484222325)
_C1 = np.uint64(0x87C37B91114253D5)
_C2 = np.uint64(0x4CF5AD432745937F)
_MIX1 = np.uint64(0xFF51AFD7ED558CCD)
_MIX2 = np.uint64(0xC4CEB9FE1A85EC53)


def _rotl(x: np.ndarray, r: int) -> np.ndarray:
    return (x << np.uint64(r)) | (x >> np.uint64(64 - r))


def fingerprint_rows(arr: np.ndarray) -> np.ndarray:
    """Per-row content fingerprints of a whole chunk in one vectorized
    pass: ``(n,)`` uint64. The naive form — one ``hashlib`` call per
    row — dominates small-batch serving cost; here the hash state is an
    n-vector and the loop runs over the *words per row* (a handful), so
    the work is O(row_bytes) numpy ops instead of O(n) Python calls."""
    A = np.ascontiguousarray(arr)
    n = len(A)
    if n == 0:
        return np.zeros(0, np.uint64)
    row_bytes = A.view(np.uint8).reshape(n, -1)
    nb = row_bytes.shape[1]
    pad = (-nb) % 8
    if pad:                              # zero-pad rows to whole words
        padded = np.zeros((n, nb + pad), np.uint8)
        padded[:, :nb] = row_bytes
        row_bytes = padded
    # words-first layout: each loop step reads one contiguous n-vector
    words = np.ascontiguousarray(
        np.ascontiguousarray(row_bytes).view(np.uint64).T)
    # row width/dtype participate so e.g. float32 and float64 views of
    # the same bytes can never alias
    salt = np.uint64(hash((str(A.dtype), nb)) & 0xFFFFFFFFFFFFFFFF)
    with np.errstate(over="ignore"):
        h = np.full(n, _SEED ^ salt, np.uint64)
        for w in words:
            k = _rotl(w * _C1, 31) * _C2
            h = _rotl(h ^ k, 27) * np.uint64(5) + np.uint64(0x52DCE729)
        # final avalanche: residual structure must not survive into the
        # sorted-lookup key space
        h ^= h >> np.uint64(33)
        h *= _MIX1
        h ^= h >> np.uint64(29)
        h *= _MIX2
        h ^= h >> np.uint64(32)
    return h


class _RowBlock:
    """Row-granular store for one (table, column, version) key space:
    embeddings live in one contiguous matrix keyed by a parallel
    fingerprint vector, so a batched lookup is one ``searchsorted`` over
    the sorted fingerprints plus one fancy-index gather — no per-row
    Python. The sort order is rebuilt lazily after inserts (inserts are
    the cold path; lookups are the serving hot path)."""

    __slots__ = ("E", "fps", "used", "_sorted", "_order")

    def __init__(self, width: int, dtype, cap: int = 256):
        self.E = np.empty((cap, width), dtype)
        self.fps = np.empty(cap, np.uint64)
        self.used = 0
        self._sorted: Optional[np.ndarray] = None
        self._order: Optional[np.ndarray] = None

    @property
    def nbytes(self) -> int:
        return self.used * (self.E.shape[1] * self.E.itemsize + 8)

    def lookup(self, q: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(row indices into E, found mask) for fingerprints ``q``."""
        if self.used == 0:
            return np.zeros(len(q), np.int64), np.zeros(len(q), bool)
        if self._sorted is None:
            self._order = np.argsort(self.fps[:self.used])
            self._sorted = self.fps[:self.used][self._order]
        pos = np.searchsorted(self._sorted, q)
        pos[pos == self.used] = 0            # clamp; mask rejects below
        found = self._sorted[pos] == q
        return self._order[pos], found

    def put(self, fps: np.ndarray, rows: np.ndarray) -> int:
        """Insert rows whose fingerprints aren't present; returns bytes
        added. Duplicates (in-call or vs stored) insert once."""
        _, present = self.lookup(fps)
        uniq, first = np.unique(fps[~present], return_index=True)
        sel = np.flatnonzero(~present)[first]
        if len(sel) == 0:
            return 0
        need = self.used + len(sel)
        if need > len(self.E):
            cap = max(need, 2 * len(self.E))
            grown = np.empty((cap, self.E.shape[1]), self.E.dtype)
            grown[:self.used] = self.E[:self.used]
            self.E = grown
            gfps = np.empty(cap, np.uint64)
            gfps[:self.used] = self.fps[:self.used]
            self.fps = gfps
        before = self.nbytes
        self.E[self.used:need] = rows[sel]
        self.fps[self.used:need] = fps[sel]
        self.used = need
        self._sorted = self._order = None    # re-sort lazily
        return self.nbytes - before

    def drop_oldest(self, keep_frac: float = 0.5) -> int:
        """Evict the oldest (insertion-order) rows, keeping the newest
        ``keep_frac``; the buffers are reallocated so freed memory is
        actually returned. Returns bytes freed."""
        keep = max(int(self.used * keep_frac), 1)
        start = self.used - keep
        if start <= 0:
            return 0
        before = self.nbytes
        self.E = self.E[start:self.used].copy()
        self.fps = self.fps[start:self.used].copy()
        self.used = keep
        self._sorted = self._order = None
        return before - self.nbytes


@dataclass
class ShareStats:
    hits: int = 0
    misses: int = 0
    embed_seconds: float = 0.0
    bytes_stored: int = 0


class VectorShareCache:
    """In-DB embedding cache: memory tier + optional Mvec disk tier."""

    def __init__(self, root: Optional[Path] = None,
                 capacity_bytes: int = 1 << 30):
        self.root = Path(root) if root else None
        if self.root:
            self.root.mkdir(parents=True, exist_ok=True)
        self.capacity = capacity_bytes
        self._mem: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self._used = 0
        # row tier: (table, column, version) -> _RowBlock, LRU over
        # whole blocks (rows inside a block age out together — the
        # serving path shares one block per trunk lane)
        self._rows: "OrderedDict[str, _RowBlock]" = OrderedDict()
        self._rows_used = 0
        self._lock = threading.Lock()
        self.stats = ShareStats()

    def _key(self, table: str, column: str, fp: str, version: str) -> str:
        return f"{table}.{column}.{version}.{fp}"

    def get_or_embed(self, table: str, column: str, data: np.ndarray,
                     embed_fn: Callable[[np.ndarray], np.ndarray],
                     version: str = "v1") -> np.ndarray:
        key = self._key(table, column, fingerprint(data), version)
        with self._lock:
            if key in self._mem:
                self.stats.hits += 1
                self._mem.move_to_end(key)
                return self._mem[key]
        if self.root and (self.root / f"{key}.mvec").exists():
            vec = mvec.decode((self.root / f"{key}.mvec").read_bytes())
            with self._lock:
                self.stats.hits += 1
                self._put(key, np.asarray(vec))
            return np.asarray(vec)
        t0 = time.time()
        vec = np.asarray(embed_fn(data))
        dt = time.time() - t0
        with self._lock:
            self.stats.misses += 1
            self.stats.embed_seconds += dt
            self._put(key, vec)
        if self.root:
            (self.root / f"{key}.mvec").write_bytes(mvec.encode(vec))
            self.stats.bytes_stored += vec.nbytes
        return vec

    def _put(self, key: str, vec: np.ndarray) -> None:
        if key in self._mem:
            self._used -= self._mem[key].nbytes
        self._mem[key] = vec
        self._mem.move_to_end(key)
        self._used += vec.nbytes
        # capacity bounds the *whole* cache: chunk tier + row tier
        while (self._used + self._rows_used > self.capacity
               and len(self._mem) > 1):
            _, old = self._mem.popitem(last=False)
            self._used -= old.nbytes

    # -- batched row-granular tier (serving hot path) ----------------------
    def get_many(self, table: str, column: str, rows: np.ndarray,
                 version: str = "v1"
                 ) -> Tuple[np.ndarray, Optional[np.ndarray], np.ndarray]:
        """Batched row-granular lookup: fingerprint the whole chunk in
        one vectorized pass and gather every cached row in one
        ``searchsorted`` + fancy index — no per-row Python anywhere.

        Returns ``(keys, found, miss)``: ``keys`` (uint64 fingerprints)
        identify rows for :meth:`put_many`; ``found`` is an ``(n, width)``
        array whose *hit* rows are filled — rows flagged by ``miss`` hold
        unspecified data and must be overwritten by the caller (one plain
        gather is ~20x cheaper than a masked scatter on the all-hit warm
        path). ``found`` is ``None`` when this key space has no cached
        rows yet; ``miss[i]`` is True when row i must be computed.
        Hit/miss stats are counted per *row* — the serving analogue of
        the chunk-level counts ``get_or_embed`` keeps.
        """
        keys = fingerprint_rows(np.asarray(rows))
        n = len(keys)
        with self._lock:
            block = self._rows.get(self._blockkey(table, column, version))
            if block is None or block.used == 0:
                self.stats.misses += n
                return keys, None, np.ones(n, bool)
            self._rows.move_to_end(self._blockkey(table, column, version))
            idx, hit = block.lookup(keys)
            miss = ~hit
            found = block.E[idx]         # miss rows: clamped idx, garbage
            self.stats.hits += int(hit.sum())
            self.stats.misses += int(miss.sum())
        return keys, found, miss

    def put_many(self, table: str, column: str, keys: np.ndarray,
                 rows: np.ndarray, version: str = "v1") -> None:
        """Write computed rows back under keys from :meth:`get_many`."""
        rows = np.asarray(rows)
        keys = np.asarray(keys, np.uint64)
        if len(keys) == 0:
            return
        if len(keys) != len(rows):
            raise ValueError(f"{len(keys)} keys for {len(rows)} rows")
        bk = self._blockkey(table, column, version)
        with self._lock:
            block = self._rows.get(bk)
            if block is None:
                block = _RowBlock(rows.shape[1], rows.dtype,
                                  cap=max(256, len(rows)))
                self._rows[bk] = block
            self._rows.move_to_end(bk)
            self._rows_used += block.put(keys, rows)
            while (self._rows_used + self._used > self.capacity
                   and len(self._rows) > 1):
                _, old = self._rows.popitem(last=False)
                self._rows_used -= old.nbytes
            # a lone block must not grow unbounded (it would also starve
            # the chunk tier forever): shed its oldest rows until the
            # combined usage fits
            while self._rows_used + self._used > self.capacity:
                freed = block.drop_oldest()
                if freed == 0:
                    break
                self._rows_used -= freed

    def get_row(self, table: str, column: str, row: np.ndarray,
                version: str = "v1") -> Optional[np.ndarray]:
        """Single-row lookup: thin wrapper over the batched API."""
        _, found, miss = self.get_many(table, column,
                                       np.asarray(row)[None], version)
        return None if (found is None or miss[0]) else found[0]

    def put_row(self, table: str, column: str, row: np.ndarray,
                emb: np.ndarray, version: str = "v1") -> None:
        """Single-row insert: thin wrapper over the batched API."""
        row = np.asarray(row)[None]
        self.put_many(table, column, fingerprint_rows(row),
                      np.asarray(emb)[None], version)

    @staticmethod
    def _blockkey(table: str, column: str, version: str) -> str:
        return f"{table}.{column}.{version}"

    @property
    def hit_rate(self) -> float:
        t = self.stats.hits + self.stats.misses
        return self.stats.hits / t if t else 0.0


def simd_normalize_embed(X: np.ndarray, W: np.ndarray,
                         mean: float = 0.0, scale: float = 1.0) -> np.ndarray:
    """Host reference of the fused normalize+project embedder (the Pallas
    kernel's oracle): y = tanh(((x - mean) * scale) @ W)."""
    Z = (X.astype(np.float32) - mean) * scale
    return np.tanh(Z @ W.astype(np.float32))
