"""Pre-embedding with vector sharing (paper §5.1).

Embeddings are computed once per (table, column, content-fingerprint,
embedder-version) and stored as Mvec blocks; later queries referencing the
same data reuse them instead of re-embedding. In cost-model terms this
zeroes Eq. 5's ExecTime term for warm rows — the trunk forward that
dominates ``C_op = ExecTime + TransCost`` becomes a fingerprint lookup
and gather — which is why both the optimizer's embed split and the
serving lanes (Eq. 11 row budgets, ``docs/serving.md``) consult this
cache before any backend runs. The *embedder-version* key is the trunk
identity (``ResolvedModel.trunk_fp``), so fine-tune deltas of one base
share their base's cached embeddings. The paper pairs sharing with SIMD
vectorization — our TPU analogue is the fused normalize+project Pallas
kernel (repro.kernels.fused_embed); on host we batch-vectorize with
numpy (SIMD via BLAS), including the one-pass murmur-style row
fingerprints ``get_many``/``put_many`` ride.
"""
from __future__ import annotations

import hashlib
import threading
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path
from typing import (Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np

from repro.storage import mvec


def fingerprint(arr: np.ndarray) -> str:
    # Full-content hash: query results are served from this cache, so a
    # partial fingerprint would silently return stale embeddings after a
    # mid-buffer mutation. sha1 is ~1 GB/s — noise next to embedding.
    h = hashlib.sha1()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()[:16]


# Word-wise murmur3-style hash (rotate-multiply rounds + avalanche
# finalizer). Plain FNV is not enough here: float rows concentrate
# entropy in a word's *high* bits (sign/exponent), and multiply-only
# mixing never diffuses high bits downward, so one-hot rows collide.
# One 64-bit fingerprint per row matches the chunk-level convention
# (``fingerprint`` keeps 64 bits of sha1); collisions are birthday-
# bounded at ~n^2 / 2^65 over distinct rows.
_SEED = np.uint64(0xCBF29CE484222325)
_C1 = np.uint64(0x87C37B91114253D5)
_C2 = np.uint64(0x4CF5AD432745937F)
_MIX1 = np.uint64(0xFF51AFD7ED558CCD)
_MIX2 = np.uint64(0xC4CEB9FE1A85EC53)


def _rotl(x: np.ndarray, r: int) -> np.ndarray:
    return (x << np.uint64(r)) | (x >> np.uint64(64 - r))


def fingerprint_rows(arr: np.ndarray) -> np.ndarray:
    """Per-row content fingerprints of a whole chunk in one vectorized
    pass: ``(n,)`` uint64. The naive form — one ``hashlib`` call per
    row — dominates small-batch serving cost; here the hash state is an
    n-vector and the loop runs over the *words per row* (a handful), so
    the work is O(row_bytes) numpy ops instead of O(n) Python calls."""
    A = np.ascontiguousarray(arr)
    n = len(A)
    if n == 0:
        return np.zeros(0, np.uint64)
    row_bytes = A.view(np.uint8).reshape(n, -1)
    nb = row_bytes.shape[1]
    pad = (-nb) % 8
    if pad:                              # zero-pad rows to whole words
        padded = np.zeros((n, nb + pad), np.uint8)
        padded[:, :nb] = row_bytes
        row_bytes = padded
    # words-first layout: each loop step reads one contiguous n-vector
    words = np.ascontiguousarray(
        np.ascontiguousarray(row_bytes).view(np.uint64).T)
    # row width/dtype participate so e.g. float32 and float64 views of
    # the same bytes can never alias
    salt = np.uint64(hash((str(A.dtype), nb)) & 0xFFFFFFFFFFFFFFFF)
    with np.errstate(over="ignore"):
        h = np.full(n, _SEED ^ salt, np.uint64)
        for w in words:
            k = _rotl(w * _C1, 31) * _C2
            h = _rotl(h ^ k, 27) * np.uint64(5) + np.uint64(0x52DCE729)
        # final avalanche: residual structure must not survive into the
        # sorted-lookup key space
        h ^= h >> np.uint64(33)
        h *= _MIX1
        h ^= h >> np.uint64(29)
        h *= _MIX2
        h ^= h >> np.uint64(32)
    return h


class _RowBlock:
    """Row-granular store for one (table, column, version) key space:
    embeddings live in one contiguous matrix keyed by a parallel
    fingerprint vector, so a batched lookup is one ``searchsorted`` over
    the sorted fingerprints plus one fancy-index gather — no per-row
    Python. The sort order is rebuilt lazily after inserts (inserts are
    the cold path; lookups are the serving hot path)."""

    __slots__ = ("E", "fps", "used", "_sorted", "_order")

    def __init__(self, width: int, dtype, cap: int = 256):
        self.E = np.empty((cap, width), dtype)
        self.fps = np.empty(cap, np.uint64)
        self.used = 0
        self._sorted: Optional[np.ndarray] = None
        self._order: Optional[np.ndarray] = None

    @property
    def nbytes(self) -> int:
        return self.used * (self.E.shape[1] * self.E.itemsize + 8)

    def lookup(self, q: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(row indices into E, found mask) for fingerprints ``q``."""
        if self.used == 0:
            return np.zeros(len(q), np.int64), np.zeros(len(q), bool)
        if self._sorted is None:
            self._order = np.argsort(self.fps[:self.used])
            self._sorted = self.fps[:self.used][self._order]
        pos = np.searchsorted(self._sorted, q)
        pos[pos == self.used] = 0            # clamp; mask rejects below
        found = self._sorted[pos] == q
        return self._order[pos], found

    def put(self, fps: np.ndarray, rows: np.ndarray) -> int:
        """Insert rows whose fingerprints aren't present; returns bytes
        added. Duplicates (in-call or vs stored) insert once."""
        _, present = self.lookup(fps)
        uniq, first = np.unique(fps[~present], return_index=True)
        sel = np.flatnonzero(~present)[first]
        if len(sel) == 0:
            return 0
        need = self.used + len(sel)
        if need > len(self.E):
            cap = max(need, 2 * len(self.E))
            grown = np.empty((cap, self.E.shape[1]), self.E.dtype)
            grown[:self.used] = self.E[:self.used]
            self.E = grown
            gfps = np.empty(cap, np.uint64)
            gfps[:self.used] = self.fps[:self.used]
            self.fps = gfps
        before = self.nbytes
        self.E[self.used:need] = rows[sel]
        self.fps[self.used:need] = fps[sel]
        self.used = need
        self._sorted = self._order = None    # re-sort lazily
        return self.nbytes - before

    def drop_oldest(self, keep_frac: float = 0.5) -> int:
        """Evict the oldest (insertion-order) rows, keeping the newest
        ``keep_frac``; the buffers are reallocated so freed memory is
        actually returned. Returns bytes freed."""
        keep = max(int(self.used * keep_frac), 1)
        start = self.used - keep
        if start <= 0:
            return 0
        before = self.nbytes
        self.E = self.E[start:self.used].copy()
        self.fps = self.fps[start:self.used].copy()
        self.used = keep
        self._sorted = self._order = None
        return before - self.nbytes


@dataclass
class ShareStats:
    hits: int = 0
    misses: int = 0
    embed_seconds: float = 0.0
    bytes_stored: int = 0


def _no_idx() -> np.ndarray:
    return np.zeros(0, np.int64)


def _no_dist() -> np.ndarray:
    return np.zeros(0, np.float32)


@dataclass
class TierLookup:
    """Result of one batch-granular cache-tier lookup.

    ``keys`` are the uint64 row fingerprints (reusable by
    :meth:`CacheTier.insert_many`); ``found`` is an ``(n, width)`` array
    whose *hit* rows are filled — rows flagged by ``miss`` hold
    unspecified data and must be overwritten by the caller (``None``
    when nothing hit). ``approx_idx`` lists the hit rows that were
    served *approximately* (nearest cached neighbor, not byte-equal),
    with their input-space distances in ``approx_dist``; ``audit_idx``
    is the subset the tier asks the caller to recompute exactly and
    report back via ``record_audit`` so false accepts are counted and
    the reuse radius stays honest.
    """

    keys: np.ndarray
    found: Optional[np.ndarray]
    miss: np.ndarray
    approx_idx: np.ndarray = field(default_factory=_no_idx)
    approx_dist: np.ndarray = field(default_factory=_no_dist)
    audit_idx: np.ndarray = field(default_factory=_no_idx)

    @property
    def hits(self) -> int:
        return int(len(self.miss) - self.miss.sum())


@runtime_checkable
class CacheTier(Protocol):
    """The one share-cache surface every tier speaks (and
    :class:`CacheChain` composes): batch-granular lookup and insert
    plus a ``stats`` counter object. ``VectorShareCache`` implements it
    with exact fingerprint equality; ``AnnShareTier`` with calibrated
    nearest-neighbor reuse. ``keys`` may carry precomputed fingerprints
    so chained tiers don't re-hash the same rows."""

    stats: object

    def lookup_many(self, table: str, column: str, rows: np.ndarray,
                    version: str = "v1", *,
                    keys: Optional[np.ndarray] = None) -> TierLookup: ...

    def insert_many(self, table: str, column: str, keys: np.ndarray,
                    rows: np.ndarray, embs: np.ndarray,
                    version: str = "v1") -> None: ...


class VectorShareCache:
    """In-DB embedding cache: memory tier + optional Mvec disk tier."""

    def __init__(self, root: Optional[Path] = None,
                 capacity_bytes: int = 1 << 30):
        self.root = Path(root) if root else None
        if self.root:
            self.root.mkdir(parents=True, exist_ok=True)
        self.capacity = capacity_bytes
        self._mem: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self._used = 0
        # row tier: (table, column, version) -> _RowBlock, LRU over
        # whole blocks (rows inside a block age out together — the
        # serving path shares one block per trunk lane)
        self._rows: "OrderedDict[str, _RowBlock]" = OrderedDict()
        self._rows_used = 0
        self._lock = threading.Lock()
        self.stats = ShareStats()

    def _key(self, table: str, column: str, fp: str, version: str) -> str:
        return f"{table}.{column}.{version}.{fp}"

    def get_or_embed(self, table: str, column: str, data: np.ndarray,
                     embed_fn: Callable[[np.ndarray], np.ndarray],
                     version: str = "v1") -> np.ndarray:
        key = self._key(table, column, fingerprint(data), version)
        with self._lock:
            if key in self._mem:
                self.stats.hits += 1
                self._mem.move_to_end(key)
                return self._mem[key]
        if self.root and (self.root / f"{key}.mvec").exists():
            vec = mvec.decode((self.root / f"{key}.mvec").read_bytes())
            with self._lock:
                self.stats.hits += 1
                self._put(key, np.asarray(vec))
            return np.asarray(vec)
        t0 = time.time()
        vec = np.asarray(embed_fn(data))
        dt = time.time() - t0
        with self._lock:
            self.stats.misses += 1
            self.stats.embed_seconds += dt
            self._put(key, vec)
        if self.root:
            (self.root / f"{key}.mvec").write_bytes(mvec.encode(vec))
            self.stats.bytes_stored += vec.nbytes
        return vec

    def _put(self, key: str, vec: np.ndarray) -> None:
        if key in self._mem:
            self._used -= self._mem[key].nbytes
        self._mem[key] = vec
        self._mem.move_to_end(key)
        self._used += vec.nbytes
        # capacity bounds the *whole* cache: chunk tier + row tier
        while (self._used + self._rows_used > self.capacity
               and len(self._mem) > 1):
            _, old = self._mem.popitem(last=False)
            self._used -= old.nbytes

    # -- batched row-granular tier (serving hot path) ----------------------
    def get_many(self, table: str, column: str, rows: np.ndarray,
                 version: str = "v1"
                 ) -> Tuple[np.ndarray, Optional[np.ndarray], np.ndarray]:
        """Batched row-granular lookup: fingerprint the whole chunk in
        one vectorized pass and gather every cached row in one
        ``searchsorted`` + fancy index — no per-row Python anywhere.

        Returns ``(keys, found, miss)``: ``keys`` (uint64 fingerprints)
        identify rows for :meth:`put_many`; ``found`` is an ``(n, width)``
        array whose *hit* rows are filled — rows flagged by ``miss`` hold
        unspecified data and must be overwritten by the caller (one plain
        gather is ~20x cheaper than a masked scatter on the all-hit warm
        path). ``found`` is ``None`` when this key space has no cached
        rows yet; ``miss[i]`` is True when row i must be computed.
        Hit/miss stats are counted per *row* — the serving analogue of
        the chunk-level counts ``get_or_embed`` keeps.
        """
        keys = fingerprint_rows(np.asarray(rows))
        n = len(keys)
        with self._lock:
            block = self._rows.get(self._blockkey(table, column, version))
            if block is None or block.used == 0:
                self.stats.misses += n
                return keys, None, np.ones(n, bool)
            self._rows.move_to_end(self._blockkey(table, column, version))
            idx, hit = block.lookup(keys)
            miss = ~hit
            found = block.E[idx]         # miss rows: clamped idx, garbage
            self.stats.hits += int(hit.sum())
            self.stats.misses += int(miss.sum())
        return keys, found, miss

    def put_many(self, table: str, column: str, keys: np.ndarray,
                 rows: np.ndarray, version: str = "v1") -> None:
        """Write computed rows back under keys from :meth:`get_many`."""
        rows = np.asarray(rows)
        keys = np.asarray(keys, np.uint64)
        if len(keys) == 0:
            return
        if len(keys) != len(rows):
            raise ValueError(f"{len(keys)} keys for {len(rows)} rows")
        bk = self._blockkey(table, column, version)
        with self._lock:
            block = self._rows.get(bk)
            if block is None:
                block = _RowBlock(rows.shape[1], rows.dtype,
                                  cap=max(256, len(rows)))
                self._rows[bk] = block
            self._rows.move_to_end(bk)
            self._rows_used += block.put(keys, rows)
            while (self._rows_used + self._used > self.capacity
                   and len(self._rows) > 1):
                _, old = self._rows.popitem(last=False)
                self._rows_used -= old.nbytes
            # a lone block must not grow unbounded (it would also starve
            # the chunk tier forever): shed its oldest rows until the
            # combined usage fits
            while self._rows_used + self._used > self.capacity:
                freed = block.drop_oldest()
                if freed == 0:
                    break
                self._rows_used -= freed

    # -- CacheTier protocol -------------------------------------------------
    def lookup_many(self, table: str, column: str, rows: np.ndarray,
                    version: str = "v1", *,
                    keys: Optional[np.ndarray] = None) -> TierLookup:
        """:class:`CacheTier` lookup: exact fingerprint equality. With
        precomputed ``keys`` the rows are not re-hashed (the chain path
        fingerprints once for all tiers)."""
        if keys is None:
            k, found, miss = self.get_many(table, column, rows, version)
            return TierLookup(k, found, miss)
        keys = np.asarray(keys, np.uint64)
        n = len(keys)
        bk = self._blockkey(table, column, version)
        with self._lock:
            block = self._rows.get(bk)
            if block is None or block.used == 0:
                self.stats.misses += n
                return TierLookup(keys, None, np.ones(n, bool))
            self._rows.move_to_end(bk)
            idx, hit = block.lookup(keys)
            miss = ~hit
            found = block.E[idx]
            self.stats.hits += int(hit.sum())
            self.stats.misses += int(miss.sum())
        return TierLookup(keys, found, miss)

    def insert_many(self, table: str, column: str, keys: np.ndarray,
                    rows: np.ndarray, embs: np.ndarray,
                    version: str = "v1") -> None:
        """:class:`CacheTier` insert. The exact tier keys purely by
        fingerprint, so the raw ``rows`` are unused here (the ANN tier
        needs them to index input space)."""
        del rows
        self.put_many(table, column, keys, embs, version)

    def get_row(self, table: str, column: str, row: np.ndarray,
                version: str = "v1") -> Optional[np.ndarray]:
        """Single-row lookup. Deprecated: use :meth:`lookup_many` (or
        the batched :meth:`get_many`) — per-row calls forfeit the
        vectorized fingerprint/gather path."""
        warnings.warn("VectorShareCache.get_row is deprecated; use "
                      "lookup_many/get_many", DeprecationWarning,
                      stacklevel=2)
        _, found, miss = self.get_many(table, column,
                                       np.asarray(row)[None], version)
        return None if (found is None or miss[0]) else found[0]

    def put_row(self, table: str, column: str, row: np.ndarray,
                emb: np.ndarray, version: str = "v1") -> None:
        """Single-row insert. Deprecated: use :meth:`insert_many` (or
        the batched :meth:`put_many`)."""
        warnings.warn("VectorShareCache.put_row is deprecated; use "
                      "insert_many/put_many", DeprecationWarning,
                      stacklevel=2)
        row = np.asarray(row)[None]
        self.put_many(table, column, fingerprint_rows(row),
                      np.asarray(emb)[None], version)

    @staticmethod
    def _blockkey(table: str, column: str, version: str) -> str:
        return f"{table}.{column}.{version}"

    @property
    def hit_rate(self) -> float:
        t = self.stats.hits + self.stats.misses
        return self.stats.hits / t if t else 0.0


# ---------------------------------------------------------------------------
# Approximate tier: IVF-flat ANN index + calibrated-radius embedding reuse
# ---------------------------------------------------------------------------


class IvfFlatIndex:
    """Pure-numpy IVF-flat ANN index (FAISS-style, no dependency).

    Below ``train_min`` stored vectors the index brute-forces (exact
    nearest neighbor); past it, a few Lloyd rounds of k-means train
    ``nlist`` coarse centroids and vectors bucket into inverted lists
    kept in CSR layout (one ``argsort`` — ids sorted by list, plus a
    starts vector). A query probes the ``nprobe`` nearest lists only.
    Appends assign against the existing centroids; the index retrains
    when it has grown ``retrain_growth``x since the last training, so
    amortized maintenance stays O(n log n). ``search1`` is fully
    vectorized across the query batch — the serving hot path must not
    pay per-row Python any more than the exact tier does."""

    def __init__(self, nlist: int = 16, nprobe: int = 4,
                 train_min: int = 64, retrain_growth: float = 2.0,
                 seed: int = 0):
        self.nlist = max(int(nlist), 1)
        self.nprobe = max(int(nprobe), 1)
        self.train_min = max(int(train_min), 2)
        self.retrain_growth = float(retrain_growth)
        self._rng = np.random.default_rng(seed)
        self.V: Optional[np.ndarray] = None      # (cap, d) float32
        self.used = 0
        self._centroids: Optional[np.ndarray] = None
        self._assign: Optional[np.ndarray] = None
        self._order: Optional[np.ndarray] = None   # CSR ids by list
        self._starts: Optional[np.ndarray] = None
        self._Vord: Optional[np.ndarray] = None    # V[order] slab
        self._vn_ord: Optional[np.ndarray] = None  # its row norms^2
        self._listed = 0                           # rows covered by CSR
        self._trained_at = 0                       # size at last k-means

    def __len__(self) -> int:
        return self.used

    @property
    def nbytes(self) -> int:
        return 0 if self.V is None else self.used * self.V.shape[1] * 4

    def add(self, vecs: np.ndarray) -> None:
        vecs = np.ascontiguousarray(np.asarray(vecs, np.float32))
        if vecs.ndim != 2 or len(vecs) == 0:
            return
        if self.V is None:
            cap = max(256, len(vecs))
            self.V = np.empty((cap, vecs.shape[1]), np.float32)
        need = self.used + len(vecs)
        if need > len(self.V):
            cap = max(need, 2 * len(self.V))
            grown = np.empty((cap, self.V.shape[1]), np.float32)
            grown[:self.used] = self.V[:self.used]
            self.V = grown
        self.V[self.used:need] = vecs
        self.used = need

    @staticmethod
    def _sq_dists(X: np.ndarray, C: np.ndarray) -> np.ndarray:
        # ||x-c||^2 via the dot trick: one GEMM instead of an
        # (n, m, d) broadcast temp
        d = (np.einsum("ij,ij->i", X, X)[:, None]
             - 2.0 * (X @ C.T)
             + np.einsum("ij,ij->i", C, C)[None, :])
        return np.maximum(d, 0.0)

    def _train(self) -> None:
        V = self.V[:self.used]
        nc = min(self.nlist, max(1, self.used // 8))
        pick = self._rng.choice(self.used, nc, replace=False)
        C = V[pick].copy()
        for _ in range(4):
            a = self._sq_dists(V, C).argmin(1)
            for j in range(nc):
                m = a == j
                if m.any():
                    C[j] = V[m].mean(0)
        self._centroids = C
        self._assign = self._sq_dists(V, C).argmin(1)
        self._rebuild_csr()
        self._trained_at = self.used

    def _rebuild_csr(self) -> None:
        self._order = np.argsort(self._assign, kind="stable")
        counts = np.bincount(self._assign,
                             minlength=len(self._centroids))
        self._starts = np.concatenate(
            [[0], np.cumsum(counts)]).astype(np.int64)
        # contiguous per-list slab: search scores each probed list with
        # one GEMM against it instead of gathering ragged candidates
        self._Vord = np.ascontiguousarray(self.V[self._order])
        self._vn_ord = np.einsum("ij,ij->i", self._Vord, self._Vord)
        self._listed = self.used

    def _ensure_built(self) -> None:
        if self.used < self.train_min:
            self._centroids = None
            return
        if (self._centroids is None
                or self.used >= self.retrain_growth
                * max(self._trained_at, 1)):
            self._train()
        elif self._listed < self.used:
            new = self.V[self._listed:self.used]
            a = self._sq_dists(new, self._centroids).argmin(1)
            self._assign = np.concatenate(
                [self._assign[:self._listed], a])
            self._rebuild_csr()

    def _brute1(self, Q: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        d = self._sq_dists(Q, self.V[:self.used])
        idx = d.argmin(1).astype(np.int64)
        diff = Q - self.V[:self.used][idx]     # exact winner distance
        return np.sqrt(np.einsum("ij,ij->i", diff, diff)), idx

    def search1(self, Q: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Nearest stored vector per query row: ``(dist, idx)`` with
        L2 distances; ``idx`` is -1 (dist inf) where nothing was found.
        Queries are bucketed by probed list and each list is scored
        with one GEMM against its contiguous slab (dot trick), merged
        into the running per-query minimum — no ragged megagather of
        candidate rows and no global sort over the candidate set."""
        Q = np.ascontiguousarray(np.asarray(Q, np.float32))
        nq = len(Q)
        if nq == 0 or self.used == 0:
            return (np.full(nq, np.inf, np.float32),
                    np.full(nq, -1, np.int64))
        self._ensure_built()
        if self._centroids is None:
            return self._brute1(Q)
        C = self._centroids
        npr = min(self.nprobe, len(C))
        dc = self._sq_dists(Q, C)
        probe = np.argpartition(dc, npr - 1, axis=1)[:, :npr]
        starts, order = self._starts, self._order
        qn = np.einsum("ij,ij->i", Q, Q)
        best = np.full(nq, np.inf, np.float32)
        idx = np.full(nq, -1, np.int64)
        # group (query, list) pairs by list: one stable sort of nq*npr
        # small ints, then a contiguous query batch per probed list
        qlist = np.repeat(np.arange(nq, dtype=np.int64), npr)
        lsort = np.argsort(probe.reshape(-1), kind="stable")
        lflat = probe.reshape(-1)[lsort]
        bounds = np.searchsorted(lflat, np.arange(len(C) + 1))
        scored_any = False
        for li in range(len(C)):
            lo, hi = int(bounds[li]), int(bounds[li + 1])
            s, e = int(starts[li]), int(starts[li + 1])
            if lo == hi or s == e:
                continue
            scored_any = True
            qs = qlist[lsort[lo:hi]]        # unique: one probe per list
            dl = (qn[qs, None]
                  - 2.0 * (Q[qs] @ self._Vord[s:e].T)
                  + self._vn_ord[None, s:e])
            j = dl.argmin(1)
            dmin = dl[np.arange(len(qs)), j]
            upd = dmin < best[qs]
            best[qs[upd]] = dmin[upd]
            idx[qs[upd]] = order[s + j[upd]]
        if not scored_any:
            return self._brute1(Q)
        # the dot trick cancels catastrophically for near-duplicates
        # (the exact regime the reuse radius gates on): recompute the
        # winner's distance from the actual difference vector
        fin = idx >= 0
        if fin.any():
            diff = Q[fin] - self.V[:self.used][idx[fin]]
            best[fin] = np.sqrt(np.einsum("ij,ij->i", diff, diff))
        return best, idx


@dataclass
class AnnConfig:
    """Approximate-tier knobs. The contract is *error-bounded reuse*:
    a row may be served a cached neighbor's embedding only when the
    input-space distance is within ``max_dist``. When ``max_dist`` is
    None the radius is calibrated online as
    ``error_bound / (safety * lip_hat)`` where ``lip_hat`` is the
    largest observed ``||Δembedding|| / ||Δrow||`` ratio over inserted
    (row, embedding) pairs — an empirical local Lipschitz estimate that
    sharpens exactly when near-duplicate traffic exists. ``audit_rate``
    of approx hits are recomputed exactly by the caller; audits whose
    error exceeds ``error_bound`` count as false accepts and tighten
    the radius."""

    error_bound: float = 0.05
    max_dist: Optional[float] = None
    safety: float = 1.5
    audit_rate: float = 0.05
    nlist: int = 16
    nprobe: int = 4
    min_train: int = 64
    retrain_growth: float = 2.0
    calib_sample: int = 64
    seed: int = 0


@dataclass
class AnnStats:
    approx_hits: int = 0
    misses: int = 0
    inserts: int = 0
    audits: int = 0
    false_accepts: int = 0
    bytes_stored: int = 0

    @property
    def hits(self) -> int:
        return self.approx_hits


class _AnnBlock:
    """Backing store for one (table, column, version) key space of the
    ANN tier: raw input rows ``R`` (distance space), their embeddings
    ``E`` (what gets served), parallel fingerprints for dedup, the IVF
    index over ``R``, and the running Lipschitz estimate."""

    __slots__ = ("R", "E", "fps", "used", "index", "lip")

    def __init__(self, in_width: int, out_width: int, cfg: AnnConfig):
        self.R = np.empty((256, in_width), np.float32)
        self.E = np.empty((256, out_width), np.float32)
        self.fps = np.empty(256, np.uint64)
        self.used = 0
        self.index = IvfFlatIndex(cfg.nlist, cfg.nprobe, cfg.min_train,
                                  cfg.retrain_growth, cfg.seed)
        self.lip = 0.0

    @property
    def nbytes(self) -> int:
        per = self.R.shape[1] * 4 + self.E.shape[1] * 4 + 8
        return self.used * per + self.index.nbytes

    def put(self, fps: np.ndarray, rows: np.ndarray,
            embs: np.ndarray) -> int:
        """Insert rows whose fingerprints aren't stored yet (dedup
        in-call and vs stored); returns bytes added. New rows feed the
        IVF index incrementally."""
        fresh = ~np.isin(fps, self.fps[:self.used])
        uniq, first = np.unique(fps[fresh], return_index=True)
        sel = np.flatnonzero(fresh)[first]
        if len(sel) == 0:
            return 0
        need = self.used + len(sel)
        if need > len(self.R):
            cap = max(need, 2 * len(self.R))
            for name in ("R", "E"):
                old = getattr(self, name)
                grown = np.empty((cap, old.shape[1]), np.float32)
                grown[:self.used] = old[:self.used]
                setattr(self, name, grown)
            gfps = np.empty(cap, np.uint64)
            gfps[:self.used] = self.fps[:self.used]
            self.fps = gfps
        before = self.nbytes
        self.R[self.used:need] = rows[sel]
        self.E[self.used:need] = embs[sel]
        self.fps[self.used:need] = fps[sel]
        self.used = need
        self.index.add(rows[sel])
        return self.nbytes - before


class AnnShareTier:
    """Approximate :class:`CacheTier`: rows within a calibrated
    input-space distance of a cached row reuse that row's embedding.

    Opt-in (``EngineConfig.cache_tiers`` must name it) and
    error-bounded: until enough (row, embedding) pairs have calibrated
    a Lipschitz estimate — or the caller pins ``max_dist`` — the radius
    is 0 and every lookup misses, so the tier can never serve wild
    guesses cold. Composes behind the exact tier in a
    :class:`CacheChain`; byte-capped with whole-block LRU like the
    exact tier."""

    def __init__(self, config: Optional[AnnConfig] = None,
                 capacity_bytes: int = 1 << 30):
        self.cfg = config or AnnConfig()
        self.capacity = capacity_bytes
        self._blocks: "OrderedDict[str, _AnnBlock]" = OrderedDict()
        self._used = 0
        self._lock = threading.Lock()
        self._audit_rng = np.random.default_rng(self.cfg.seed + 1)
        self._calib_rng = np.random.default_rng(self.cfg.seed + 2)
        self.stats = AnnStats()

    @staticmethod
    def _blockkey(table: str, column: str, version: str) -> str:
        return f"{table}.{column}.{version}"

    def _radius_of(self, block: Optional[_AnnBlock]) -> float:
        if self.cfg.max_dist is not None:
            return float(self.cfg.max_dist)
        if block is None or block.lip <= 0.0:
            return 0.0
        return self.cfg.error_bound / (self.cfg.safety * block.lip)

    def radius(self, table: str, column: str,
               version: str = "v1") -> float:
        """Current reuse radius for a key space (0 = not calibrated)."""
        with self._lock:
            return self._radius_of(
                self._blocks.get(self._blockkey(table, column, version)))

    def lookup_many(self, table: str, column: str, rows: np.ndarray,
                    version: str = "v1", *,
                    keys: Optional[np.ndarray] = None) -> TierLookup:
        rows = np.asarray(rows)
        X = rows.reshape(len(rows), -1).astype(np.float32, copy=False)
        if keys is None:
            keys = fingerprint_rows(rows)
        n = len(X)
        miss_all = TierLookup(keys, None, np.ones(n, bool))
        with self._lock:
            bk = self._blockkey(table, column, version)
            block = self._blocks.get(bk)
            radius = self._radius_of(block)
            if (n == 0 or block is None or block.used == 0
                    or radius <= 0.0
                    or X.shape[1] != block.R.shape[1]):
                self.stats.misses += n
                return miss_all
            self._blocks.move_to_end(bk)
            dist, idx = block.index.search1(X)
            hit = (idx >= 0) & (dist <= radius)
            hidx = np.flatnonzero(hit)
            if len(hidx) == 0:
                self.stats.misses += n
                return miss_all
            found = np.zeros((n, block.E.shape[1]), np.float32)
            found[hidx] = block.E[idx[hidx]]
            audit_idx = _no_idx()
            if self.cfg.audit_rate > 0.0:
                draw = self._audit_rng.random(len(hidx))
                audit_idx = hidx[draw < self.cfg.audit_rate]
            self.stats.approx_hits += len(hidx)
            self.stats.misses += n - len(hidx)
        return TierLookup(keys, found, ~hit, hidx,
                          dist[hidx].astype(np.float32), audit_idx)

    def insert_many(self, table: str, column: str, keys: np.ndarray,
                    rows: np.ndarray, embs: np.ndarray,
                    version: str = "v1") -> None:
        rows = np.asarray(rows)
        X = rows.reshape(len(rows), -1).astype(np.float32, copy=False)
        E = np.asarray(embs, np.float32).reshape(len(rows), -1)
        keys = np.asarray(keys, np.uint64)
        if len(X) == 0:
            return
        bk = self._blockkey(table, column, version)
        with self._lock:
            block = self._blocks.get(bk)
            if block is None:
                block = _AnnBlock(X.shape[1], E.shape[1], self.cfg)
                self._blocks[bk] = block
            elif (X.shape[1] != block.R.shape[1]
                  or E.shape[1] != block.E.shape[1]):
                return                       # width changed: ignore
            self._blocks.move_to_end(bk)
            # calibrate BEFORE inserting: each sampled new row's nearest
            # *existing* neighbor gives an observed ||dE||/||dR|| ratio
            if block.used and self.cfg.max_dist is None:
                s = min(len(X), self.cfg.calib_sample)
                sel = (np.arange(len(X)) if s == len(X) else
                       self._calib_rng.choice(len(X), s, replace=False))
                d, i = block.index.search1(X[sel])
                ok = (i >= 0) & (d > 1e-9) & np.isfinite(d)
                if ok.any():
                    de = np.linalg.norm(E[sel][ok] - block.E[i[ok]],
                                        axis=1)
                    block.lip = max(block.lip,
                                    float((de / d[ok]).max()))
            added = block.put(keys, X, E)
            self._used += added
            self.stats.inserts += len(X)
            self.stats.bytes_stored += max(added, 0)
            while self._used > self.capacity and len(self._blocks) > 1:
                _, old = self._blocks.popitem(last=False)
                self._used -= old.nbytes

    def record_audit(self, table: str, column: str, version: str,
                     dists: np.ndarray, errors: np.ndarray) -> None:
        """Caller reports exact recomputations of audited approx hits:
        errors above ``error_bound`` count as false accepts and raise
        the Lipschitz estimate, shrinking the calibrated radius."""
        dists = np.asarray(dists, np.float64)
        errors = np.asarray(errors, np.float64)
        with self._lock:
            self.stats.audits += len(errors)
            bad = errors > self.cfg.error_bound
            self.stats.false_accepts += int(bad.sum())
            block = self._blocks.get(
                self._blockkey(table, column, version))
            if block is not None and bad.any():
                ok = bad & (dists > 1e-9)
                if ok.any():
                    block.lip = max(block.lip,
                                    float((errors[ok] / dists[ok]).max()))


class CacheChain:
    """Compose :class:`CacheTier`s into one cache: lookups consult
    tiers in order (exact first), each tier serving only the residual
    misses of the previous one; inserts broadcast to every tier. Also
    carries the chunk-style ``get_or_embed`` entry point the analytics
    embed nodes use, which runs the full audit protocol: audited
    approx hits are recomputed exactly, compared, reported back via
    ``record_audit``, and served exact."""

    def __init__(self, tiers: Sequence[CacheTier]):
        if not tiers:
            raise ValueError("CacheChain needs at least one tier")
        self.tiers: List[CacheTier] = list(tiers)
        self.computed_rows = 0     # rows embed_fn actually computed

    def lookup_many(self, table: str, column: str, rows: np.ndarray,
                    version: str = "v1", *,
                    keys: Optional[np.ndarray] = None) -> TierLookup:
        rows = np.asarray(rows)
        out = self.tiers[0].lookup_many(table, column, rows, version,
                                        keys=keys)
        for tier in self.tiers[1:]:
            if not out.miss.any():
                break
            ridx = np.flatnonzero(out.miss)
            sub = tier.lookup_many(table, column, rows[ridx], version,
                                   keys=out.keys[ridx])
            hit_sub = np.flatnonzero(~sub.miss)
            if len(hit_sub) == 0:
                continue
            if out.found is None:
                out.found = np.zeros((len(rows), sub.found.shape[1]),
                                     sub.found.dtype)
            gidx = ridx[hit_sub]
            out.found[gidx] = sub.found[hit_sub]
            out.miss[gidx] = False
            out.approx_idx = np.concatenate(
                [out.approx_idx, ridx[sub.approx_idx]])
            out.approx_dist = np.concatenate(
                [out.approx_dist, sub.approx_dist])
            out.audit_idx = np.concatenate(
                [out.audit_idx, ridx[sub.audit_idx]])
        return out

    def insert_many(self, table: str, column: str, keys: np.ndarray,
                    rows: np.ndarray, embs: np.ndarray,
                    version: str = "v1") -> None:
        for tier in self.tiers:
            tier.insert_many(table, column, keys, rows, embs, version)

    def record_audit(self, table: str, column: str, version: str,
                     dists: np.ndarray, errors: np.ndarray) -> None:
        for tier in self.tiers:
            fn = getattr(tier, "record_audit", None)
            if fn is not None:
                fn(table, column, version, dists, errors)

    @property
    def ann(self) -> Optional[AnnShareTier]:
        for tier in self.tiers:
            if isinstance(tier, AnnShareTier):
                return tier
        return None

    def get_or_embed(self, table: str, column: str, data: np.ndarray,
                     embed_fn: Callable[[np.ndarray], np.ndarray],
                     version: str = "v1") -> np.ndarray:
        """Row-granular replacement for the chunk-level
        ``VectorShareCache.get_or_embed``: hit rows gather from the
        chain, miss rows embed once per distinct fingerprint
        (single-flight within the call), and audited approx hits are
        recomputed, compared against the bound, and refreshed exact."""
        rows = np.asarray(data)
        n = len(rows)
        if n == 0:
            return np.asarray(embed_fn(rows))
        tl = self.lookup_many(table, column, rows, version)
        need = tl.miss.copy()
        if len(tl.audit_idx):
            need[tl.audit_idx] = True
        if not need.any():
            return tl.found
        cidx = np.flatnonzero(need)
        uniq, first = np.unique(tl.keys[cidx], return_index=True)
        comp_idx = cidx[first]
        computed = np.asarray(embed_fn(rows[comp_idx]))
        self.computed_rows += len(comp_idx)
        E = tl.found
        if E is None:
            E = np.zeros((n, computed.shape[1]), computed.dtype)
        if len(tl.audit_idx):
            exact = computed[np.searchsorted(uniq, tl.keys[tl.audit_idx])]
            errs = np.linalg.norm(
                E[tl.audit_idx].astype(np.float64) - exact, axis=1)
            order = np.argsort(tl.approx_idx, kind="stable")
            loc = order[np.searchsorted(tl.approx_idx[order],
                                        tl.audit_idx)]
            self.record_audit(table, column, version,
                              tl.approx_dist[loc], errs)
        E[cidx] = computed[np.searchsorted(uniq, tl.keys[cidx])]
        self.insert_many(table, column, tl.keys[comp_idx],
                         rows[comp_idx], computed, version)
        return E


def simd_normalize_embed(X: np.ndarray, W: np.ndarray,
                         mean: float = 0.0, scale: float = 1.0) -> np.ndarray:
    """Host reference of the fused normalize+project embedder (the Pallas
    kernel's oracle): y = tanh(((x - mean) * scale) @ W)."""
    Z = (X.astype(np.float32) - mean) * scale
    return np.tanh(Z @ W.astype(np.float32))
