"""Pre-embedding with vector sharing (paper §5.1).

Embeddings are computed once per (table, column, content-fingerprint,
embedder-version) and stored as Mvec blocks; later queries referencing the
same data reuse them instead of re-embedding. The paper pairs this with
SIMD vectorization — our TPU analogue is the fused normalize+project
Pallas kernel (repro.kernels.fused_embed); on host we batch-vectorize with
numpy (SIMD via BLAS).
"""
from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.storage import mvec


def fingerprint(arr: np.ndarray) -> str:
    h = hashlib.sha1()
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes()[:1 << 16])
    h.update(np.ascontiguousarray(arr).tobytes()[-(1 << 12):])
    return h.hexdigest()[:16]


@dataclass
class ShareStats:
    hits: int = 0
    misses: int = 0
    embed_seconds: float = 0.0
    bytes_stored: int = 0


class VectorShareCache:
    """In-DB embedding cache: memory tier + optional Mvec disk tier."""

    def __init__(self, root: Optional[Path] = None,
                 capacity_bytes: int = 1 << 30):
        self.root = Path(root) if root else None
        if self.root:
            self.root.mkdir(parents=True, exist_ok=True)
        self.capacity = capacity_bytes
        self._mem: Dict[str, np.ndarray] = {}
        self._order: list = []
        self._lock = threading.Lock()
        self.stats = ShareStats()

    def _key(self, table: str, column: str, fp: str, version: str) -> str:
        return f"{table}.{column}.{version}.{fp}"

    def get_or_embed(self, table: str, column: str, data: np.ndarray,
                     embed_fn: Callable[[np.ndarray], np.ndarray],
                     version: str = "v1") -> np.ndarray:
        key = self._key(table, column, fingerprint(data), version)
        with self._lock:
            if key in self._mem:
                self.stats.hits += 1
                return self._mem[key]
        if self.root and (self.root / f"{key}.mvec").exists():
            vec = mvec.decode((self.root / f"{key}.mvec").read_bytes())
            with self._lock:
                self.stats.hits += 1
                self._put(key, np.asarray(vec))
            return np.asarray(vec)
        t0 = time.time()
        vec = np.asarray(embed_fn(data))
        dt = time.time() - t0
        with self._lock:
            self.stats.misses += 1
            self.stats.embed_seconds += dt
            self._put(key, vec)
        if self.root:
            (self.root / f"{key}.mvec").write_bytes(mvec.encode(vec))
            self.stats.bytes_stored += vec.nbytes
        return vec

    def _put(self, key: str, vec: np.ndarray) -> None:
        self._mem[key] = vec
        self._order.append(key)
        used = sum(v.nbytes for v in self._mem.values())
        while used > self.capacity and len(self._order) > 1:
            old = self._order.pop(0)
            used -= self._mem.pop(old, np.empty(0)).nbytes

    @property
    def hit_rate(self) -> float:
        t = self.stats.hits + self.stats.misses
        return self.stats.hits / t if t else 0.0


def simd_normalize_embed(X: np.ndarray, W: np.ndarray,
                         mean: float = 0.0, scale: float = 1.0) -> np.ndarray:
    """Host reference of the fused normalize+project embedder (the Pallas
    kernel's oracle): y = tanh(((x - mean) * scale) @ W)."""
    Z = (X.astype(np.float32) - mean) * scale
    return np.tanh(Z @ W.astype(np.float32))
