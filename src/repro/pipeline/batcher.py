"""Batch inference as a window function (paper §5.2 'Batch Inferences').

``WindowBatcher`` reproduces the kernel-side mechanics the paper adds to
PostgreSQL's window function: (1) window data aggregation — rows are
copied into an intermediate state until the window fills; (2) batch
inference execution — the filled window is converted to tensors in
parallel and run as one batch; (3) cleanup + result caching — results are
re-associated with row ids and raw rows released.

``ContinuousBatcher`` is the serving-engine version: an admission queue
with cost-model-selected batch size and waiting-time bound. It runs
either as a one-shot loop (``run(total)``) or as a long-lived service
(``start()`` / ``submit()`` / ``result()`` / ``stop()``) whose worker
thread coalesces queued requests into batches and publishes results
through a condition variable — the serving-path sibling of the
window-function batcher.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.pipeline.cost import OpProfile, choose_batch_size


@dataclass
class BatcherStats:
    batches: int = 0
    rows: int = 0
    infer_seconds: float = 0.0
    convert_seconds: float = 0.0

    @property
    def rows_per_second(self) -> float:
        t = self.infer_seconds + self.convert_seconds
        return self.rows / t if t else 0.0


class WindowBatcher:
    """Window-function-style batcher over a row stream."""

    def __init__(self, infer_fn: Callable[[np.ndarray], np.ndarray],
                 batch_size: int = 16, convert_workers: int = 4,
                 convert_fn: Optional[Callable[[Any], np.ndarray]] = None):
        self.infer_fn = infer_fn
        self.batch_size = max(1, batch_size)
        self.convert_fn = convert_fn or (lambda r: np.asarray(r, np.float32))
        self._pool = (ThreadPoolExecutor(convert_workers)
                      if convert_workers > 1 else None)
        self._window: List[Any] = []
        self._ids: List[int] = []
        self._results: Dict[int, Any] = {}
        self.stats = BatcherStats()

    # (1) window data aggregation
    def add(self, row_id: int, row: Any) -> None:
        self._window.append(row)
        self._ids.append(row_id)
        if len(self._window) >= self.batch_size:
            self._flush()

    # (2) batch inference execution
    def _flush(self) -> None:
        if not self._window:
            return
        t0 = time.time()
        if self._pool:
            tensors = list(self._pool.map(self.convert_fn, self._window))
        else:
            tensors = [self.convert_fn(r) for r in self._window]
        x = np.stack(tensors)
        t1 = time.time()
        out = self.infer_fn(x)
        t2 = time.time()
        # (3) result caching + cleanup
        for rid, o in zip(self._ids, np.asarray(out)):
            self._results[rid] = o
        self.stats.batches += 1
        self.stats.rows += len(self._ids)
        self.stats.convert_seconds += t1 - t0
        self.stats.infer_seconds += t2 - t1
        self._window.clear()
        self._ids.clear()

    def finish(self) -> Dict[int, Any]:
        self._flush()
        return self._results


def run_batched(rows: Sequence[Any],
                infer_fn: Callable[[np.ndarray], np.ndarray],
                batch_size: int = 16, **kw) -> List[Any]:
    b = WindowBatcher(infer_fn, batch_size=batch_size, **kw)
    for i, r in enumerate(rows):
        b.add(i, r)
    res = b.finish()
    return [res[i] for i in range(len(rows))]


# ---------------------------------------------------------------------------
# Serving-engine continuous batcher
# ---------------------------------------------------------------------------

@dataclass
class Request:
    req_id: int
    payload: Any
    arrival: float = field(default_factory=time.time)


class _Failure:
    """Sentinel wrapping a step_fn exception so result() can re-raise."""

    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error


class ContinuousBatcher:
    """Admission queue -> cost-model batch size -> batched step loop.

    Two usage modes:

    - one-shot: ``submit()`` requests, then ``run(total)`` serves exactly
      ``total`` of them on the calling thread and returns their results;
    - service: ``start()`` spawns a worker thread, concurrent producers
      ``submit()`` and block on ``result(req_id)`` (a condition variable
      wakes them as batches complete), ``stop(drain=True)`` serves what
      is still queued before joining the worker.

    ``batch_size`` is chosen by the cost model (Eq. 11) and measured in
    payload units: by default one request = one unit, but a ``size_of``
    hook lets multi-row payloads count their rows so coalesced serving
    batches match the cost-model-sized row budget rather than a request
    count. Duplicate ``req_id`` submissions raise (a silent overwrite
    would drop one requester's result).
    """

    def __init__(self, step_fn: Callable[[List[Any]], List[Any]],
                 profile: Optional[OpProfile] = None, device: str = "tpu",
                 max_wait_s: float = 0.01, idle_wait_s: float = 0.1,
                 mem_cap_bytes: float = 2e9,
                 batch_size: Optional[int] = None,
                 size_of: Optional[Callable[[Any], int]] = None,
                 hw: Optional[Dict[str, Any]] = None,
                 telemetry_window: int = 10000):
        self.step_fn = step_fn
        if batch_size is not None:
            self.batch_size = max(1, int(batch_size))
        else:
            if profile is None:
                raise ValueError("need an OpProfile or explicit batch_size")
            self.batch_size = choose_batch_size(profile, device,
                                                mem_cap_bytes=mem_cap_bytes,
                                                hw=hw)
        self.max_wait_s = max_wait_s
        self.idle_wait_s = idle_wait_s
        self.size_of = size_of or (lambda _p: 1)
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._cv = threading.Condition()
        self._results: Dict[int, Any] = {}
        self._latency_of: Dict[int, float] = {}
        self._submitted: Set[int] = set()
        self._pending = 0                    # submitted but not yet served
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # telemetry is windowed so a long-running service doesn't grow
        # without bound; per-request state is evicted by result()
        self.latencies: "deque[float]" = deque(maxlen=telemetry_window)
        self.batch_sizes: "deque[int]" = deque(maxlen=telemetry_window)

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> int:
        with self._cv:
            if req.req_id in self._submitted:
                raise ValueError(f"duplicate req_id {req.req_id!r}")
            if self._stop.is_set():
                raise RuntimeError("batcher is stopped")
            self._submitted.add(req.req_id)
            self._pending += 1
            # enqueue under the cv so the stop check and the put are
            # atomic w.r.t. stop(drain=False)'s queue drain — a request
            # can be admitted or rejected, never accepted-then-orphaned
            self._q.put(req)
        return req.req_id

    def _collect(self, limit: Optional[int] = None) -> List[Request]:
        # Block on the first request (bounded by idle_wait_s) so an empty
        # queue parks the thread in the OS wait instead of busy-spinning.
        try:
            batch = [self._q.get(timeout=self.idle_wait_s)]
        except queue.Empty:
            return []
        units = self.size_of(batch[0].payload)
        deadline = time.time() + self.max_wait_s
        while units < self.batch_size and (limit is None
                                           or len(batch) < limit):
            timeout = deadline - time.time()
            if timeout <= 0:
                break
            try:
                req = self._q.get(timeout=timeout)
            except queue.Empty:
                break
            batch.append(req)
            units += self.size_of(req.payload)
        return batch

    # -- serving -----------------------------------------------------------
    def _serve(self, batch: List[Request]) -> Optional[Exception]:
        """Run one step and publish its results; a step error is stored
        per request (surfaced by ``result()``) and returned."""
        err: Optional[Exception] = None
        try:
            outs: List[Any] = list(self.step_fn([r.payload
                                                 for r in batch]))
            if len(outs) != len(batch):
                raise RuntimeError(
                    f"step_fn returned {len(outs)} results for "
                    f"{len(batch)} requests")
        except Exception as e:      # surfaced via result() / run()
            err = e
            outs = [_Failure(e)] * len(batch)
        now = time.time()
        with self._cv:
            for r, o in zip(batch, outs):
                self._results[r.req_id] = o
                self._latency_of[r.req_id] = now - r.arrival
                self.latencies.append(now - r.arrival)
            self._pending -= len(batch)
            self.batch_sizes.append(len(batch))
            self._cv.notify_all()
        return err

    def run(self, total: int) -> Dict[int, Any]:
        """Serve exactly ``total`` queued requests on the calling thread
        and raise on the first step error (one-shot mode has no
        ``result()`` call to surface failures through). Collection is
        capped at the remaining count so a batch never crosses the
        ``total`` boundary (no overcounting when ``total`` is not a
        batch multiple)."""
        served = 0
        while served < total:
            batch = self._collect(limit=total - served)
            if not batch:
                continue
            err = self._serve(batch)
            if err is not None:
                raise err
            served += len(batch)
        return dict(self._results)

    # -- service lifecycle -------------------------------------------------
    def start(self) -> "ContinuousBatcher":
        if self._thread is not None:
            raise RuntimeError("batcher already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while True:
            batch = self._collect()
            if batch:
                self._serve(batch)
            elif self._stop.is_set() and self._q.empty():
                # drain contract: only exit once the queue is empty
                return

    def result(self, req_id: int, timeout: Optional[float] = None, *,
               evict: bool = True) -> Any:
        """Block until ``req_id`` has been served and return its output
        (re-raising the step error if its batch failed). With ``evict``
        (default) the request's stored result and bookkeeping are
        released — each result is retrievable once, which is what keeps
        a long-running service's memory bounded."""
        with self._cv:
            if req_id not in self._submitted:
                raise KeyError(f"unknown req_id {req_id!r}")
            ok = self._cv.wait_for(lambda: req_id in self._results,
                                   timeout=timeout)
            if not ok:
                raise TimeoutError(f"req_id {req_id!r} not served in time")
            if evict:
                out = self._results.pop(req_id)
                self._latency_of.pop(req_id, None)
                self._submitted.discard(req_id)
            else:
                out = self._results[req_id]
        if isinstance(out, _Failure):
            raise out.error
        return out

    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> Dict[int, Any]:
        """Shut the worker down. With ``drain`` (default) every queued
        request is served first; otherwise unserved requests are dropped
        and their ``result()`` calls fail.

        ``timeout`` bounds the worker join: a worker that has not exited
        within it (a step function wedged in a backend call) raises
        TimeoutError instead of hanging the caller forever. The worker
        reference is kept so a later ``stop()`` can retry the join once
        the step returns."""
        # _stop is set inside the cv block so submit()'s check-and-put
        # is atomic against it: a request is either rejected, failed
        # here (drain=False), or guaranteed served by the drain
        with self._cv:
            if not drain:
                dropped = []
                while True:
                    try:
                        dropped.append(self._q.get_nowait())
                    except queue.Empty:
                        break
                for r in dropped:
                    self._results[r.req_id] = _Failure(
                        RuntimeError("batcher stopped before serving "
                                     f"req_id {r.req_id!r}"))
                self._pending -= len(dropped)
            self._stop.set()
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"batcher worker did not join within {timeout}s; "
                    "its step function is still running")
            self._thread = None
        elif drain:
            # never started: no worker owns the drain, so serve the
            # queue inline — stop() must not orphan admitted requests
            while not self._q.empty():
                batch = self._collect()
                if batch:
                    self._serve(batch)
        return dict(self._results)

    def latency(self, req_id: int) -> float:
        """Queue-to-completion latency of a served request (seconds)."""
        with self._cv:
            return self._latency_of[req_id]

    def evict(self, req_id: int) -> None:
        """Release a served request's stored result and bookkeeping."""
        with self._cv:
            self._results.pop(req_id, None)
            self._latency_of.pop(req_id, None)
            self._submitted.discard(req_id)

    def reset_telemetry(self) -> None:
        """Clear the windowed telemetry (latency + batch-size deques).
        Served-request bookkeeping is untouched — this only re-bases the
        window so e.g. percentiles computed after a warmup phase don't
        mix pre- and post-warmup samples."""
        with self._cv:
            self.latencies.clear()
            self.batch_sizes.clear()

    def telemetry(self) -> Tuple[List[float], List[int]]:
        """Consistent snapshot of (latencies, batch sizes) — the live
        deques mutate under the worker thread, so readers must not
        iterate them directly."""
        with self._cv:
            return list(self.latencies), list(self.batch_sizes)

    @property
    def pending(self) -> int:
        with self._cv:
            return self._pending
