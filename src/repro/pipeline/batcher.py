"""Batch inference as a window function (paper §5.2 'Batch Inferences').

``WindowBatcher`` reproduces the kernel-side mechanics the paper adds to
PostgreSQL's window function: (1) window data aggregation — rows are
copied into an intermediate state until the window fills; (2) batch
inference execution — the filled window is converted to tensors in
parallel and run as one batch; (3) cleanup + result caching — results are
re-associated with row ids and raw rows released.

``ContinuousBatcher`` is the serving-engine version: an admission queue
with cost-model-selected batch size and waiting-time bound. It runs
either as a one-shot loop (``run(total)``) or as a long-lived service
(``start()`` / ``submit()`` / ``result()`` / ``stop()``) whose worker
thread coalesces queued requests into batches and publishes results
through a condition variable — the serving-path sibling of the
window-function batcher.

With an :class:`~repro.pipeline.admission.AdmissionPolicy` attached the
batcher is the production-hardened serving lane: priority-class queues
with depth caps and backpressure (typed ``Rejected``), weighted lane
draining, deadline-aware dynamic Eq. 11 row budgets
(:class:`~repro.pipeline.cost.DynamicBudget`), capped-backoff retries
for transient step failures, and a circuit breaker that sheds traffic
after repeated batch failures until a supervisor resets it. Without a
policy it behaves exactly as before: one FIFO, unbounded admission,
no retries, static budget.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.pipeline.admission import (AdmissionPolicy, CircuitOpen,
                                      LaneBreaker, Rejected, RequestError,
                                      PRIORITIES, validate_priority)
from repro.pipeline.cost import DynamicBudget, OpProfile, choose_batch_size


@dataclass
class BatcherStats:
    batches: int = 0
    rows: int = 0
    infer_seconds: float = 0.0
    convert_seconds: float = 0.0

    @property
    def rows_per_second(self) -> float:
        t = self.infer_seconds + self.convert_seconds
        return self.rows / t if t else 0.0


class WindowBatcher:
    """Window-function-style batcher over a row stream."""

    def __init__(self, infer_fn: Callable[[np.ndarray], np.ndarray],
                 batch_size: int = 16, convert_workers: int = 4,
                 convert_fn: Optional[Callable[[Any], np.ndarray]] = None):
        self.infer_fn = infer_fn
        self.batch_size = max(1, batch_size)
        self.convert_fn = convert_fn or (lambda r: np.asarray(r, np.float32))
        self._pool = (ThreadPoolExecutor(convert_workers)
                      if convert_workers > 1 else None)
        self._window: List[Any] = []
        self._ids: List[int] = []
        self._results: Dict[int, Any] = {}
        self.stats = BatcherStats()

    # (1) window data aggregation
    def add(self, row_id: int, row: Any) -> None:
        self._window.append(row)
        self._ids.append(row_id)
        if len(self._window) >= self.batch_size:
            self._flush()

    # (2) batch inference execution
    def _flush(self) -> None:
        if not self._window:
            return
        t0 = time.time()
        if self._pool:
            tensors = list(self._pool.map(self.convert_fn, self._window))
        else:
            tensors = [self.convert_fn(r) for r in self._window]
        x = np.stack(tensors)
        t1 = time.time()
        out = self.infer_fn(x)
        t2 = time.time()
        # (3) result caching + cleanup
        for rid, o in zip(self._ids, np.asarray(out)):
            self._results[rid] = o
        self.stats.batches += 1
        self.stats.rows += len(self._ids)
        self.stats.convert_seconds += t1 - t0
        self.stats.infer_seconds += t2 - t1
        self._window.clear()
        self._ids.clear()

    def finish(self) -> Dict[int, Any]:
        self._flush()
        return self._results


def run_batched(rows: Sequence[Any],
                infer_fn: Callable[[np.ndarray], np.ndarray],
                batch_size: int = 16, **kw) -> List[Any]:
    b = WindowBatcher(infer_fn, batch_size=batch_size, **kw)
    for i, r in enumerate(rows):
        b.add(i, r)
    res = b.finish()
    return [res[i] for i in range(len(rows))]


# ---------------------------------------------------------------------------
# Serving-engine continuous batcher
# ---------------------------------------------------------------------------

@dataclass
class Request:
    req_id: int
    payload: Any
    arrival: float = field(default_factory=time.time)
    # SLO dimensions (ignored unless the batcher carries an
    # AdmissionPolicy): priority class for weighted draining + caps, and
    # an optional completion deadline relative to arrival (seconds) that
    # feeds the dynamic row budget and the deadline-miss counter
    priority: str = "batch"
    deadline_s: Optional[float] = None


class _Failure:
    """Sentinel wrapping a step_fn exception so result() can re-raise."""

    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error


class ContinuousBatcher:
    """Admission queue -> cost-model batch size -> batched step loop.

    Two usage modes:

    - one-shot: ``submit()`` requests, then ``run(total)`` serves exactly
      ``total`` of them on the calling thread and returns their results;
    - service: ``start()`` spawns a worker thread, concurrent producers
      ``submit()`` and block on ``result(req_id)`` (a condition variable
      wakes them as batches complete), ``stop(drain=True)`` serves what
      is still queued before joining the worker.

    ``batch_size`` is chosen by the cost model (Eq. 11) and measured in
    payload units: by default one request = one unit, but a ``size_of``
    hook lets multi-row payloads count their rows so coalesced serving
    batches match the cost-model-sized row budget rather than a request
    count. Duplicate ``req_id`` submissions raise (a silent overwrite
    would drop one requester's result).

    ``policy`` (an :class:`AdmissionPolicy`) turns on the production
    hardening: queue-depth caps with reject/block backpressure, weighted
    priority draining, the deadline-aware :class:`DynamicBudget` in
    place of the static row budget, retry-with-backoff on step failures,
    and the lane circuit breaker. ``name`` labels this lane in every
    typed error so operators can tell *which* lane pushed back.
    """

    def __init__(self, step_fn: Callable[[List[Any]], List[Any]],
                 profile: Optional[OpProfile] = None, device: str = "tpu",
                 max_wait_s: float = 0.01, idle_wait_s: float = 0.1,
                 mem_cap_bytes: float = 2e9,
                 batch_size: Optional[int] = None,
                 size_of: Optional[Callable[[Any], int]] = None,
                 hw: Optional[Dict[str, Any]] = None,
                 telemetry_window: int = 10000,
                 name: str = "",
                 policy: Optional[AdmissionPolicy] = None):
        self.step_fn = step_fn
        if batch_size is not None:
            self.batch_size = max(1, int(batch_size))
        else:
            if profile is None:
                raise ValueError("need an OpProfile or explicit batch_size")
            self.batch_size = choose_batch_size(profile, device,
                                                mem_cap_bytes=mem_cap_bytes,
                                                hw=hw)
        self.max_wait_s = max_wait_s
        self.idle_wait_s = idle_wait_s
        self.size_of = size_of or (lambda _p: 1)
        self.name = name
        self.policy = policy
        # admission state: per-priority FIFO deques drained by weighted
        # round-robin; all guarded by the one condition variable
        self._queues: Dict[str, "deque[Request]"] = {
            p: deque() for p in PRIORITIES}
        self._credits: Dict[str, int] = {p: 0 for p in PRIORITIES}
        self._queued_units = 0
        self._queued_units_by: Dict[str, int] = {p: 0 for p in PRIORITIES}
        self._queued_reqs = 0
        self._cv = threading.Condition()
        self._results: Dict[int, Any] = {}
        self._latency_of: Dict[int, float] = {}
        self._submitted: Set[int] = set()
        self._pending = 0                    # submitted but not yet served
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # SLO machinery (active only with a policy): dynamic Eq. 11
        # budget + the windowed tightest admitted deadline it tracks,
        # and the lane circuit breaker
        self.budget: Optional[DynamicBudget] = None
        self.breaker: Optional[LaneBreaker] = None
        if policy is not None:
            self.budget = DynamicBudget(
                base_rows=self.batch_size,
                min_rows=policy.min_batch_rows,
                shrink_at=policy.shrink_at, grow_at=policy.grow_at)
            if policy.breaker_threshold > 0:
                self.breaker = LaneBreaker(
                    threshold=policy.breaker_threshold,
                    cooldown_s=policy.breaker_cooldown_s)
        self._deadline_window: "deque[float]" = deque(maxlen=256)
        # robustness counters (read via health())
        self.rejected = 0
        self.rejected_by_priority: Dict[str, int] = {
            p: 0 for p in PRIORITIES}
        self.retries = 0
        self.failed_batches = 0
        self.deadline_misses = 0
        self.deadlines_admitted = 0
        self.breaker_resets = 0
        # telemetry is windowed so a long-running service doesn't grow
        # without bound; per-request state is evicted by result()
        self.latencies: "deque[float]" = deque(maxlen=telemetry_window)
        self.batch_sizes: "deque[int]" = deque(maxlen=telemetry_window)
        self.lat_by_priority: Dict[str, "deque[float]"] = {
            p: deque(maxlen=telemetry_window) for p in PRIORITIES}

    def _label(self) -> str:
        return f"lane {self.name!r}" if self.name else "batcher"

    # -- admission ---------------------------------------------------------
    def _has_room_locked(self, priority: str, units: int) -> bool:
        if self.policy is None:
            return True
        pol = self.policy
        if self._queued_units + units > pol.max_queue_rows:
            return False
        return (self._queued_units_by[priority] + units
                <= pol.cap_of(priority))

    def _reject_locked(self, req: Request, units: int,
                       reason: str) -> None:
        self.rejected += 1
        self.rejected_by_priority[req.priority] += 1
        cap = (self.policy.cap_of(req.priority) if self.policy else 0)
        raise Rejected(
            f"{self._label()} rejected req_id {req.req_id!r} "
            f"({req.priority}, {units} units): {reason} "
            f"(queued {self._queued_units} units, cap {cap})",
            lane=self.name, priority=req.priority,
            queued_units=self._queued_units, cap=cap, reason=reason)

    def submit(self, req: Request) -> int:
        """Admit one request, or push back.

        Raises ``RuntimeError`` after ``stop()`` (the worker is gone —
        enqueueing would orphan the request), :class:`CircuitOpen` while
        the lane breaker is open, and :class:`Rejected` when the queue
        caps push back (immediately under the ``reject`` policy, after
        ``block_timeout_s`` of waiting for drain under ``block``)."""
        validate_priority(req.priority)
        units = self.size_of(req.payload)
        with self._cv:
            if req.req_id in self._submitted:
                raise ValueError(f"duplicate req_id {req.req_id!r}")
            self._check_stopped_locked(req)
            if not self._has_room_locked(req.priority, units):
                if self.policy is not None and self.policy.mode == "block":
                    ok = self._cv.wait_for(
                        lambda: (self._stop.is_set()
                                 or (self.breaker is not None
                                     and self.breaker.open)
                                 or self._has_room_locked(req.priority,
                                                          units)),
                        timeout=self.policy.block_timeout_s)
                    self._check_stopped_locked(req)
                    if not ok or not self._has_room_locked(req.priority,
                                                           units):
                        self._reject_locked(req, units, "block_timeout")
                else:
                    self._reject_locked(req, units, "queue_full")
            if req.req_id in self._submitted:   # re-check after blocking
                raise ValueError(f"duplicate req_id {req.req_id!r}")
            self._submitted.add(req.req_id)
            self._pending += 1
            # enqueue under the cv so the stop check and the put are
            # atomic w.r.t. stop(drain=False)'s queue drain — a request
            # can be admitted or rejected, never accepted-then-orphaned
            self._queues[req.priority].append(req)
            self._queued_units += units
            self._queued_units_by[req.priority] += units
            self._queued_reqs += 1
            if req.deadline_s is not None and req.deadline_s > 0:
                self._deadline_window.append(float(req.deadline_s))
                self.deadlines_admitted += 1
            self._cv.notify_all()
        return req.req_id

    def _check_stopped_locked(self, req: Request) -> None:
        if self._stop.is_set():
            raise RuntimeError(
                f"{self._label()} stopped: no worker will serve "
                f"req_id {req.req_id!r}")
        if self.breaker is not None and self.breaker.open:
            raise CircuitOpen(
                f"{self._label()} circuit breaker open after "
                f"{self.breaker.failures} consecutive batch failures; "
                "shedding until the supervisor resets it",
                lane=self.name, priority=req.priority,
                failures=self.breaker.failures)

    # -- weighted draining -------------------------------------------------
    def _pop_locked(self) -> Request:
        """Pop the next request under weighted round-robin: each class
        spends ``weight`` credits per cycle while others wait, so
        interactive traffic drains first without starving best-effort.
        Caller holds the cv and has checked a request is queued."""
        while True:
            for p in PRIORITIES:
                if self._queues[p] and self._credits[p] > 0:
                    self._credits[p] -= 1
                    req = self._queues[p].popleft()
                    units = self.size_of(req.payload)
                    self._queued_units -= units
                    self._queued_units_by[p] -= units
                    self._queued_reqs -= 1
                    return req
            # every queued class is out of credits: start a new cycle
            for p in PRIORITIES:
                self._credits[p] = (self.policy.weight_of(p)
                                    if self.policy else
                                    {"interactive": 8, "batch": 3,
                                     "best_effort": 1}[p])

    def _target_units(self) -> int:
        return self.budget.current if self.budget is not None \
            else self.batch_size

    def _collect(self, limit: Optional[int] = None) -> List[Request]:
        # Block on the first request (bounded by idle_wait_s) so an empty
        # queue parks the thread in the OS wait instead of busy-spinning.
        with self._cv:
            self._cv.wait_for(
                lambda: self._queued_reqs > 0 or self._stop.is_set(),
                timeout=self.idle_wait_s)
            if self._queued_reqs == 0:
                return []
            batch = [self._pop_locked()]
            units = self.size_of(batch[0].payload)
            target = self._target_units()
            deadline = time.time() + self.max_wait_s
            while units < target and (limit is None
                                      or len(batch) < limit):
                timeout = deadline - time.time()
                if timeout <= 0:
                    break
                if self._queued_reqs == 0:
                    self._cv.wait_for(lambda: self._queued_reqs > 0
                                      or self._stop.is_set(),
                                      timeout=timeout)
                if self._queued_reqs == 0:
                    break
                req = self._pop_locked()
                batch.append(req)
                units += self.size_of(req.payload)
            # popping freed queue room: wake block-mode submitters
            self._cv.notify_all()
        return batch

    # -- serving -----------------------------------------------------------
    def _run_step(self, batch: List[Request]
                  ) -> Tuple[List[Any], Optional[Exception], int]:
        """Execute the step with the policy's retry budget. Returns
        (outputs, final error or None, attempts made)."""
        payloads = [r.payload for r in batch]
        retry_limit = self.policy.retry_limit if self.policy else 0
        attempt = 0
        while True:
            attempt += 1
            try:
                outs: List[Any] = list(self.step_fn(payloads))
                if len(outs) != len(batch):
                    raise RuntimeError(
                        f"step_fn returned {len(outs)} results for "
                        f"{len(batch)} requests")
                return outs, None, attempt
            except Exception as e:      # surfaced via result() / run()
                if attempt > retry_limit:
                    return [], e, attempt
                with self._cv:
                    self.retries += 1
                # capped exponential backoff: transient backend hiccups
                # (a preempted device, a flaky remote) get a beat to
                # clear before the batch retries
                time.sleep(self.policy.backoff_s(attempt))

    def _serve(self, batch: List[Request]) -> Optional[Exception]:
        """Run one step (with retries) and publish its results; a step
        error is attributed to exactly the requests in this batch — it
        is stored per request as a typed :class:`RequestError` (surfaced
        by ``result()``), returned raw (for ``run()``), and the lane
        worker survives to serve the next batch."""
        outs, err, attempts = self._run_step(batch)
        now = time.time()
        if err is not None:
            wrapped = RequestError(
                f"{self._label()} batch of {len(batch)} request(s) "
                f"failed after {attempts} attempt(s): {err!r}",
                lane=self.name, attempts=attempts,
                req_ids=[r.req_id for r in batch])
            wrapped.__cause__ = err
            outs = [_Failure(wrapped)] * len(batch)
        with self._cv:
            for r, o in zip(batch, outs):
                self._results[r.req_id] = o
                lat = now - r.arrival
                self._latency_of[r.req_id] = lat
                self.latencies.append(lat)
                self.lat_by_priority[r.priority].append(lat)
                if (r.deadline_s is not None and r.deadline_s > 0
                        and lat > r.deadline_s):
                    self.deadline_misses += 1
            self._pending -= len(batch)
            self.batch_sizes.append(len(batch))
            if err is not None:
                self.failed_batches += 1
                if self.breaker is not None \
                        and self.breaker.record_failure(now):
                    self._drain_queues_locked(CircuitOpen(
                        f"{self._label()} circuit breaker tripped after "
                        f"{self.breaker.failures} consecutive batch "
                        "failures; queued requests shed",
                        lane=self.name, failures=self.breaker.failures))
            elif self.breaker is not None:
                self.breaker.record_success()
            if self.budget is not None:
                self.budget.update(self._windowed_p95_locked(),
                                   self._tightest_deadline_locked(),
                                   self._queued_units)
            self._cv.notify_all()
        return err

    def _windowed_p95_locked(self) -> Optional[float]:
        if len(self.latencies) < 5:
            return None
        return float(np.percentile(list(self.latencies), 95))

    def _tightest_deadline_locked(self) -> Optional[float]:
        return min(self._deadline_window) if self._deadline_window \
            else None

    def _drain_queues_locked(self, error: BaseException) -> None:
        """Fail every queued request with ``error`` (caller holds cv)."""
        for p in PRIORITIES:
            q = self._queues[p]
            while q:
                r = q.popleft()
                self._results[r.req_id] = _Failure(error)
                self._pending -= 1
        self._queued_units = 0
        self._queued_units_by = {p: 0 for p in PRIORITIES}
        self._queued_reqs = 0

    def run(self, total: int) -> Dict[int, Any]:
        """Serve exactly ``total`` queued requests on the calling thread
        and raise on the first step error (one-shot mode has no
        ``result()`` call to surface failures through). Collection is
        capped at the remaining count so a batch never crosses the
        ``total`` boundary (no overcounting when ``total`` is not a
        batch multiple)."""
        served = 0
        while served < total:
            batch = self._collect(limit=total - served)
            if not batch:
                continue
            err = self._serve(batch)
            if err is not None:
                raise err
            served += len(batch)
        return dict(self._results)

    # -- service lifecycle -------------------------------------------------
    def start(self) -> "ContinuousBatcher":
        if self._thread is not None:
            raise RuntimeError("batcher already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while True:
            batch = self._collect()
            if batch:
                self._serve(batch)
            elif self._stop.is_set() and self.queued_units == 0:
                # drain contract: only exit once the queues are empty
                return

    def result(self, req_id: int, timeout: Optional[float] = None, *,
               evict: bool = True) -> Any:
        """Block until ``req_id`` has been served and return its output
        (re-raising the step error if its batch failed). With ``evict``
        (default) the request's stored result and bookkeeping are
        released — each result is retrievable once, which is what keeps
        a long-running service's memory bounded."""
        with self._cv:
            if req_id not in self._submitted:
                raise KeyError(f"unknown req_id {req_id!r}")
            ok = self._cv.wait_for(lambda: req_id in self._results,
                                   timeout=timeout)
            if not ok:
                raise TimeoutError(f"req_id {req_id!r} not served in time")
            if evict:
                out = self._results.pop(req_id)
                self._latency_of.pop(req_id, None)
                self._submitted.discard(req_id)
            else:
                out = self._results[req_id]
        if isinstance(out, _Failure):
            raise out.error
        return out

    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> Dict[int, Any]:
        """Shut the worker down. With ``drain`` (default) every queued
        request is served first; otherwise unserved requests are dropped
        and their ``result()`` calls fail.

        ``timeout`` bounds the worker join: a worker that has not exited
        within it (a step function wedged in a backend call) raises
        TimeoutError instead of hanging the caller forever. The worker
        reference is kept so a later ``stop()`` can retry the join once
        the step returns."""
        # _stop is set inside the cv block so submit()'s check-and-put
        # is atomic against it: a request is either rejected, failed
        # here (drain=False), or guaranteed served by the drain
        with self._cv:
            if not drain:
                for p in PRIORITIES:
                    q = self._queues[p]
                    while q:
                        r = q.popleft()
                        self._results[r.req_id] = _Failure(RuntimeError(
                            f"{self._label()} stopped before serving "
                            f"req_id {r.req_id!r}"))
                        self._pending -= 1
                self._queued_units = 0
                self._queued_units_by = {p: 0 for p in PRIORITIES}
                self._queued_reqs = 0
            self._stop.set()
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"{self._label()} worker did not join within "
                    f"{timeout}s; its step function is still running")
            self._thread = None
        elif drain:
            # never started: no worker owns the drain, so serve the
            # queue inline — stop() must not orphan admitted requests
            while self.queued_units > 0 or self._queued_reqs > 0:
                batch = self._collect()
                if batch:
                    self._serve(batch)
        return dict(self._results)

    def latency(self, req_id: int) -> float:
        """Queue-to-completion latency of a served request (seconds)."""
        with self._cv:
            return self._latency_of[req_id]

    def evict(self, req_id: int) -> None:
        """Release a served request's stored result and bookkeeping."""
        with self._cv:
            self._results.pop(req_id, None)
            self._latency_of.pop(req_id, None)
            self._submitted.discard(req_id)

    def reset_telemetry(self) -> None:
        """Clear the windowed telemetry (latency + batch-size deques,
        per-priority windows) and the robustness counters. Served-request
        bookkeeping and breaker *state* are untouched — this only
        re-bases the windows so e.g. percentiles computed after a warmup
        phase don't mix pre- and post-warmup samples."""
        with self._cv:
            self.latencies.clear()
            self.batch_sizes.clear()
            for d in self.lat_by_priority.values():
                d.clear()
            self.rejected = 0
            self.rejected_by_priority = {p: 0 for p in PRIORITIES}
            self.retries = 0
            self.failed_batches = 0
            self.deadline_misses = 0
            self.deadlines_admitted = 0

    def telemetry(self) -> Tuple[List[float], List[int]]:
        """Consistent snapshot of (latencies, batch sizes) — the live
        deques mutate under the worker thread, so readers must not
        iterate them directly."""
        with self._cv:
            return list(self.latencies), list(self.batch_sizes)

    @property
    def pending(self) -> int:
        with self._cv:
            return self._pending

    @property
    def queued_units(self) -> int:
        """Queued-but-unserved work, in ``size_of`` units."""
        with self._cv:
            return self._queued_units

    @property
    def current_batch_rows(self) -> int:
        """The row budget the next batch will target (dynamic when a
        policy is attached, else the static Eq. 11 choice)."""
        with self._cv:
            return self._target_units()

    def reset_breaker(self, *, force: bool = False) -> bool:
        """Close an open breaker (the supervisor path). Unless ``force``,
        only resets after the policy's cooldown has elapsed. Returns
        True when the breaker was actually closed."""
        with self._cv:
            if self.breaker is None or not self.breaker.open:
                return False
            if not force and not self.breaker.cooled_down(time.time()):
                return False
            self.breaker.reset()
            self.breaker_resets += 1
            self._cv.notify_all()
            return True

    def telemetry_by_priority(self) -> Dict[str, List[float]]:
        """Consistent snapshot of per-priority-class latencies."""
        with self._cv:
            return {p: list(d) for p, d in self.lat_by_priority.items()}

    def health(self) -> Dict[str, Any]:
        """Snapshot of the lane's robustness counters and SLO state."""
        with self._cv:
            return {
                "name": self.name,
                "queued_units": self._queued_units,
                "queued_by_priority": dict(self._queued_units_by),
                "rejected": self.rejected,
                "rejected_by_priority": dict(self.rejected_by_priority),
                "retries": self.retries,
                "failed_batches": self.failed_batches,
                "deadline_misses": self.deadline_misses,
                "deadlines_admitted": self.deadlines_admitted,
                "breaker_open": (self.breaker.open
                                 if self.breaker else False),
                "breaker_trips": (self.breaker.trips
                                  if self.breaker else 0),
                "breaker_resets": self.breaker_resets,
                "batch_rows": self._target_units(),
                "budget_shrinks": (self.budget.shrinks
                                   if self.budget else 0),
                "budget_grows": (self.budget.grows
                                 if self.budget else 0),
            }
