"""Batch inference as a window function (paper §5.2 'Batch Inferences').

``WindowBatcher`` reproduces the kernel-side mechanics the paper adds to
PostgreSQL's window function: (1) window data aggregation — rows are
copied into an intermediate state until the window fills; (2) batch
inference execution — the filled window is converted to tensors in
parallel and run as one batch; (3) cleanup + result caching — results are
re-associated with row ids and raw rows released.

``ContinuousBatcher`` is the serving-engine version: an admission queue
with cost-model-selected batch size and waiting-time bound.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.pipeline.cost import OpProfile, choose_batch_size


@dataclass
class BatcherStats:
    batches: int = 0
    rows: int = 0
    infer_seconds: float = 0.0
    convert_seconds: float = 0.0

    @property
    def rows_per_second(self) -> float:
        t = self.infer_seconds + self.convert_seconds
        return self.rows / t if t else 0.0


class WindowBatcher:
    """Window-function-style batcher over a row stream."""

    def __init__(self, infer_fn: Callable[[np.ndarray], np.ndarray],
                 batch_size: int = 16, convert_workers: int = 4,
                 convert_fn: Optional[Callable[[Any], np.ndarray]] = None):
        self.infer_fn = infer_fn
        self.batch_size = max(1, batch_size)
        self.convert_fn = convert_fn or (lambda r: np.asarray(r, np.float32))
        self._pool = (ThreadPoolExecutor(convert_workers)
                      if convert_workers > 1 else None)
        self._window: List[Any] = []
        self._ids: List[int] = []
        self._results: Dict[int, Any] = {}
        self.stats = BatcherStats()

    # (1) window data aggregation
    def add(self, row_id: int, row: Any) -> None:
        self._window.append(row)
        self._ids.append(row_id)
        if len(self._window) >= self.batch_size:
            self._flush()

    # (2) batch inference execution
    def _flush(self) -> None:
        if not self._window:
            return
        t0 = time.time()
        if self._pool:
            tensors = list(self._pool.map(self.convert_fn, self._window))
        else:
            tensors = [self.convert_fn(r) for r in self._window]
        x = np.stack(tensors)
        t1 = time.time()
        out = self.infer_fn(x)
        t2 = time.time()
        # (3) result caching + cleanup
        for rid, o in zip(self._ids, np.asarray(out)):
            self._results[rid] = o
        self.stats.batches += 1
        self.stats.rows += len(self._ids)
        self.stats.convert_seconds += t1 - t0
        self.stats.infer_seconds += t2 - t1
        self._window.clear()
        self._ids.clear()

    def finish(self) -> Dict[int, Any]:
        self._flush()
        return self._results


def run_batched(rows: Sequence[Any],
                infer_fn: Callable[[np.ndarray], np.ndarray],
                batch_size: int = 16, **kw) -> List[Any]:
    b = WindowBatcher(infer_fn, batch_size=batch_size, **kw)
    for i, r in enumerate(rows):
        b.add(i, r)
    res = b.finish()
    return [res[i] for i in range(len(rows))]


# ---------------------------------------------------------------------------
# Serving-engine continuous batcher
# ---------------------------------------------------------------------------

@dataclass
class Request:
    req_id: int
    payload: Any
    arrival: float = field(default_factory=time.time)


class ContinuousBatcher:
    """Admission queue -> cost-model batch size -> batched step loop."""

    def __init__(self, step_fn: Callable[[List[Any]], List[Any]],
                 profile: OpProfile, device: str = "tpu",
                 max_wait_s: float = 0.01, idle_wait_s: float = 0.1,
                 mem_cap_bytes: float = 2e9):
        self.step_fn = step_fn
        self.batch_size = choose_batch_size(profile, device,
                                            mem_cap_bytes=mem_cap_bytes)
        self.max_wait_s = max_wait_s
        self.idle_wait_s = idle_wait_s
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._results: Dict[int, Any] = {}
        self._done = threading.Event()
        self.latencies: List[float] = []

    def submit(self, req: Request) -> None:
        self._q.put(req)

    def _collect(self) -> List[Request]:
        # Block on the first request (bounded by idle_wait_s) so an empty
        # queue parks the thread in the OS wait instead of busy-spinning.
        try:
            batch = [self._q.get(timeout=self.idle_wait_s)]
        except queue.Empty:
            return []
        deadline = time.time() + self.max_wait_s
        while len(batch) < self.batch_size:
            timeout = deadline - time.time()
            if timeout <= 0:
                break
            try:
                batch.append(self._q.get(timeout=timeout))
            except queue.Empty:
                break
        return batch

    def run(self, total: int) -> Dict[int, Any]:
        served = 0
        while served < total:
            batch = self._collect()
            if not batch:
                continue
            outs = self.step_fn([r.payload for r in batch])
            now = time.time()
            for r, o in zip(batch, outs):
                self._results[r.req_id] = o
                self.latencies.append(now - r.arrival)
            served += len(batch)
        return self._results
