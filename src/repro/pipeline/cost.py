"""Operator cost model + device placement (paper §5.2, Eq. 5-11),
re-derived for the TPU target.

Equation map (each implemented here by name):

- **Eq. 5** — operator cost ``C_op = ExecTime + TransCost``
  (:func:`op_cost`); for remote models the cost collapses to the
  endpoint's end-to-end latency (:func:`exec_time`'s ``api`` branch).
- **Eq. 6** — ``ExecTime = max(FLOPs/FLOPS(dev), bytes/MemBW) * nrows``
  roofline (:func:`exec_time`).
- **Eq. 7** — ``TransCost = ModelSize/MemBW + ModelSize/AccelBW +
  Latency`` (:func:`trans_cost`); staged once per resolved task, never
  per chunk, and *delta-aware*: a fine-tune sharing a resident base
  trunk only moves its delta layers (:func:`delta_staged_profile`).
- **Eq. 9** — host placement pays only the memory-bus load
  (:func:`trans_cost`'s host branch).
- **Eq. 10** — device decision rule ``argmin C_op``
  (:func:`choose_device`, :func:`place_dag`).
- **Eq. 11** — batch-size selection: argmax throughput s.t. memory cap
  and latency bound (:func:`choose_batch_size`); :func:`split_profile`
  sizes the serving embed and head stages separately.

Devices: 'host' (CPU relational ops + small models), 'tpu' (v5e chip),
'api' (remote endpoint). See ``docs/architecture.md`` for where each
decision lands in the dataflow.

Hardware numbers come in two flavours: the static spec-sheet defaults
below (``DEFAULT_HW``), and *measured* :class:`HardwareProfile` entries
produced by :func:`calibrate`, which times the live execution backend
(per-row throughput + launch latency from a two-point linear fit, link
bandwidth from a staging transfer) so Eq. 10/11 decisions reflect the
machine actually running the query. Every cost function takes an
optional ``hw`` mapping of device name -> HardwareProfile that overrides
the defaults.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

# hardware constants (host numbers measured-order-of-magnitude; TPU per brief)
HOST_FLOPS = 5e10          # ~50 GFLOP/s effective numpy single-core
HOST_MEM_BW = 2e10         # bytes/s host memory effective
TPU_FLOPS = 197e12         # bf16 peak per chip
TPU_HBM_BW = 819e9
HOST_TO_TPU_BW = 5e9       # PCIe/infeed-equivalent bytes/s
TPU_LAUNCH_LATENCY = 5e-5  # dispatch overhead per call (s)


@dataclass(frozen=True)
class HardwareProfile:
    """Per-device throughput/latency numbers the cost model consumes.

    ``flops_per_s``/``mem_bw`` bound ExecTime (Eq. 6 roofline);
    ``link_bw`` is the host<->device staging path and ``launch_latency_s``
    the per-call dispatch overhead (both enter TransCost, Eq. 7).
    ``measured`` marks profiles produced by :func:`calibrate`.
    """
    name: str
    flops_per_s: float
    mem_bw: float
    link_bw: float = float("inf")
    launch_latency_s: float = 0.0
    measured: bool = False
    # mesh dimension: how many devices the profile's throughput numbers
    # aggregate over. ``flops_per_s``/``mem_bw`` are *mesh-aggregate*
    # (what Eq. 6/11 see for a batch split across the mesh);
    # ``device_flops_per_s`` is the measured single-device rate, so the
    # scaling efficiency is device_flops_per_s * device_count vs
    # flops_per_s. 0.0 means "not separately measured" and reads as the
    # aggregate divided evenly.
    device_count: int = 1
    device_flops_per_s: float = 0.0

    @property
    def per_device_flops(self) -> float:
        return (self.device_flops_per_s
                or self.flops_per_s / max(self.device_count, 1))


DEFAULT_HW: Dict[str, HardwareProfile] = {
    "host": HardwareProfile("host", HOST_FLOPS, HOST_MEM_BW),
    "tpu": HardwareProfile("tpu", TPU_FLOPS, TPU_HBM_BW,
                           link_bw=HOST_TO_TPU_BW,
                           launch_latency_s=TPU_LAUNCH_LATENCY),
}


def _hw_for(device: str,
            hw: Optional[Dict[str, HardwareProfile]] = None) -> HardwareProfile:
    table = dict(DEFAULT_HW)
    if hw:
        table.update(hw)
    return table.get(device, table["host"])


@dataclass(frozen=True)
class OpProfile:
    """Static profile of one operator instance."""
    flops_per_row: float = 0.0
    bytes_per_row: float = 0.0
    model_bytes: float = 0.0       # weights to stage (0 for relational ops)
    api_latency_s: float = 0.0     # >0 => remote model
    # on-disk bytes a cold resolve reads (compressed deltas / deduped
    # pages make this < model_bytes; 0 = uncompressed, same as
    # model_bytes). The Eq. 7/9 host mem-read term charges these bytes —
    # decompression happens at memory speed — while the host->device
    # link still moves the full dequantized model_bytes.
    stored_model_bytes: float = 0.0

    @property
    def cold_read_bytes(self) -> float:
        return self.stored_model_bytes or self.model_bytes


def exec_time(p: OpProfile, nrows: int, device: str,
              hw: Optional[Dict[str, HardwareProfile]] = None) -> float:
    if device == "api":
        return p.api_latency_s  # end-to-end response latency (Eq. 5 note)
    h = _hw_for(device, hw)
    flops = p.flops_per_row * nrows
    byts = p.bytes_per_row * nrows
    return max(flops / h.flops_per_s, byts / h.mem_bw)


def trans_cost(p: OpProfile, nrows: int, device: str,
               hw: Optional[Dict[str, HardwareProfile]] = None) -> float:
    if device == "api":
        return 0.0
    host = _hw_for("host", hw)
    if device == "host":
        return p.cold_read_bytes / host.mem_bw  # Eq. 9
    h = _hw_for(device, hw)
    # read (possibly compressed) weights from host storage, then stage
    # the full model + batch over the host<->device link (Eq. 7)
    batch_bytes = p.bytes_per_row * nrows
    return (p.cold_read_bytes / host.mem_bw
            + (p.model_bytes + batch_bytes) / h.link_bw
            + h.launch_latency_s)


def op_cost(p: OpProfile, nrows: int, device: str,
            hw: Optional[Dict[str, HardwareProfile]] = None) -> float:
    return exec_time(p, nrows, device, hw) + trans_cost(p, nrows, device, hw)


def choose_device(p: OpProfile, nrows: int,
                  devices=("host", "tpu"),
                  hw: Optional[Dict[str, HardwareProfile]] = None) -> str:
    """Eq. 10 generalized over the available device set."""
    cand = list(devices)
    if p.api_latency_s > 0:
        cand.append("api")
    return min(cand, key=lambda d: op_cost(p, nrows, d, hw))


def place_dag(dag, profiles: Dict[str, OpProfile], nrows_hint: int = 1024,
              devices=("host", "tpu"),
              hw: Optional[Dict[str, HardwareProfile]] = None
              ) -> Dict[str, str]:
    """Plan-time device placement (Eq. 10) over an operator DAG.

    Annotates each ``Node.device`` in place and returns the placement map.
    This is a *planning* pass — `PipelineExecutor` is a pure runtime and
    only reads the annotations (`repro.engine` calls this while lowering a
    logical plan; callers building DAGs by hand call it directly).
    """
    placement = {}
    for op_id, node in dag.nodes.items():
        prof = profiles.get(op_id)
        if node.kind in ("predict", "embed") and prof is not None:
            placement[op_id] = choose_device(prof, nrows_hint, devices, hw)
        else:
            placement[op_id] = "host"
        node.device = placement[op_id]
    return placement


# ---------------------------------------------------------------------------
# Batch-size selection (Eq. 11)
# ---------------------------------------------------------------------------

def batch_cost(p: OpProfile, batch: int, device: str,
               *, fixed_overhead_s: float = 2e-4,
               hw: Optional[Dict[str, HardwareProfile]] = None
               ) -> Dict[str, float]:
    t = op_cost(p, batch, device, hw) + fixed_overhead_s
    return {"latency_s": t, "throughput": batch / t,
            "mem_bytes": p.bytes_per_row * batch + p.model_bytes}


def choose_batch_size(p: OpProfile, device: str, *,
                      candidates=(1, 2, 4, 8, 16, 32, 64, 128),
                      mem_cap_bytes: float = 2e9,
                      latency_bound_s: Optional[float] = None,
                      hw: Optional[Dict[str, HardwareProfile]] = None) -> int:
    """argmax throughput s.t. memory cap + optional latency bound. The
    paper's observed sweet spot (8-32) falls out of the overhead/memory
    trade-off rather than being hard-coded."""
    best, best_tp = candidates[0], -1.0
    for b in candidates:
        c = batch_cost(p, b, device, hw=hw)
        if c["mem_bytes"] > mem_cap_bytes:
            continue
        if latency_bound_s and c["latency_s"] > latency_bound_s:
            continue
        if c["throughput"] > best_tp:
            best, best_tp = b, c["throughput"]
    return best


@dataclass
class DynamicBudget:
    """Eq. 11 made adaptive for SLO-aware serving lanes.

    ``base_rows`` is the static Eq. 11 optimum (:func:`choose_batch_size`
    picked it for peak throughput). Under deadline pressure a lane
    trades that throughput for tail latency: when the windowed p95 of
    request latency approaches the **tightest admitted deadline**, the
    row budget halves (down to ``min_rows``) so batches complete — and
    queued requests start — sooner; when the pressure clears or the lane
    goes idle the budget doubles back toward the Eq. 11 optimum.

    The controller is pure state + arithmetic (no clocks, no threads):
    the owning batcher calls :meth:`update` after each served batch with
    its measured p95 and the tightest deadline currently admitted, and
    reads :attr:`current` when sizing the next batch.
    """
    base_rows: int
    min_rows: int = 8
    shrink_at: float = 0.8      # p95/deadline ratio that triggers shrink
    grow_at: float = 0.4        # ratio below which the budget regrows
    current: int = 0
    shrinks: int = 0
    grows: int = 0

    def __post_init__(self):
        self.base_rows = max(int(self.base_rows), 1)
        self.min_rows = max(min(int(self.min_rows), self.base_rows), 1)
        if not self.current:
            self.current = self.base_rows

    def update(self, p95_s: Optional[float],
               tightest_deadline_s: Optional[float],
               queued_units: int = 0) -> int:
        """One control step; returns the new row budget.

        ``p95_s`` is the lane's windowed tail latency (None = no samples
        yet), ``tightest_deadline_s`` the smallest relative deadline
        among recently admitted requests (None = nobody asked for one),
        ``queued_units`` the backlog depth (0 = idle, which always
        regrows — an idle lane should re-enter traffic at full Eq. 11
        throughput)."""
        if tightest_deadline_s is None or tightest_deadline_s <= 0:
            return self._grow()          # no SLO pressure: run at optimum
        if queued_units == 0:
            return self._grow()          # idle: regrow toward base
        if p95_s is None:
            return self.current
        ratio = p95_s / tightest_deadline_s
        if ratio > self.shrink_at:
            if self.current > self.min_rows:
                self.current = max(self.current // 2, self.min_rows)
                self.shrinks += 1
        elif ratio < self.grow_at:
            self._grow()
        return self.current

    def _grow(self) -> int:
        if self.current < self.base_rows:
            self.current = min(self.current * 2, self.base_rows)
            self.grows += 1
        return self.current


def profile_for_model(n_params: float, bytes_per_row: float,
                      flops_per_row: Optional[float] = None,
                      dtype_bytes: int = 4,
                      stored_bytes: Optional[float] = None) -> OpProfile:
    """``stored_bytes`` is the on-disk size a cold resolve actually reads
    (compressed deltas, deduped pages); omit it for uncompressed models."""
    return OpProfile(
        flops_per_row=flops_per_row if flops_per_row else 2.0 * n_params,
        bytes_per_row=bytes_per_row,
        model_bytes=n_params * dtype_bytes,
        stored_model_bytes=float(stored_bytes or 0.0))


def split_profile(p: OpProfile, head_dim: int,
                  dtype_bytes: int = 4) -> Tuple[OpProfile, OpProfile]:
    """Split a full-predict profile into (embed, head) stage profiles so
    Eq. 11 sizes the serving row budgets separately: the trunk keeps the
    model's FLOPs and staged weight bytes; the head is an O(head_dim)
    readout over already-computed embeddings with (next to) no weights
    to stage, so its budget lands on much larger batches."""
    head_dim = max(int(head_dim), 1)
    head_flops = 2.0 * head_dim
    head = OpProfile(flops_per_row=head_flops,
                     bytes_per_row=float(head_dim * dtype_bytes),
                     model_bytes=float(head_dim * dtype_bytes))
    embed = OpProfile(
        flops_per_row=max(p.flops_per_row - head_flops, 1.0),
        bytes_per_row=p.bytes_per_row,
        model_bytes=p.model_bytes,
        api_latency_s=p.api_latency_s,
        stored_model_bytes=p.stored_model_bytes)
    return embed, head


def delta_staged_profile(p: OpProfile, delta_bytes: float) -> OpProfile:
    """Eq. 7 staging for a fine-tune whose base trunk is already resident
    (resolved by another task, so its weights are warm in the layer cache
    and staged on device under the shared trunk identity): only the delta
    layers still have to move, so TransCost's ModelSize term shrinks to
    ``delta_bytes``. ExecTime is untouched — the composed model does the
    same math as a fully-materialized one."""
    return OpProfile(flops_per_row=p.flops_per_row,
                     bytes_per_row=p.bytes_per_row,
                     model_bytes=max(float(delta_bytes), 0.0),
                     api_latency_s=p.api_latency_s)


# ---------------------------------------------------------------------------
# Calibration: measure the live backend instead of trusting the spec sheet
# ---------------------------------------------------------------------------

def calibrate(backend, device: str = "host", *,
              dim: int = 32, width: int = 64,
              rows=(256, 2048), repeats: int = 3,
              seed: int = 0) -> HardwareProfile:
    """Measure a :class:`HardwareProfile` from a live execution backend.

    Runs a synthetic ``tanh(X @ W)`` embedder (the dominant inference
    shape) through ``backend.run_infer`` at a small and a large row count
    and linear-fits ``t(n) = launch + n * per_row``: the slope gives the
    effective per-row FLOP/byte throughput, the intercept the per-call
    launch latency — the numbers Eq. 10/11 actually need, including every
    real overhead (batching loops, jit dispatch, padding) that spec-sheet
    constants miss. Link bandwidth is measured from a staging transfer
    when the backend exposes one (``measure_link_bandwidth``).

    Mesh backends (``backend.device_count > 1``) are measured twice: the
    main fit runs through the mesh (so ``flops_per_s``/``mem_bw`` are the
    *aggregate* rates Eq. 11 sizes row budgets against), and a fresh
    single-device probe (``backend.per_device_probe()``) supplies the
    per-device rate recorded in ``device_flops_per_s``.
    """
    per_row, launch = _fit_per_row(backend, device, dim=dim, width=width,
                                   rows=rows, repeats=repeats, seed=seed)
    flops_per_row = 2.0 * dim * width + width      # matmul + tanh
    bytes_per_row = 4.0 * (dim + width)
    link_bw = DEFAULT_HW.get(device, DEFAULT_HW["host"]).link_bw
    measure_link = getattr(backend, "measure_link_bandwidth", None)
    if measure_link is not None:
        link_bw = measure_link()
    n_dev = int(getattr(backend, "device_count", 1))
    device_flops = 0.0
    probe_fn = getattr(backend, "per_device_probe", None)
    if n_dev > 1 and probe_fn is not None:
        dev_per_row, _ = _fit_per_row(probe_fn(), device, dim=dim,
                                      width=width, rows=rows,
                                      repeats=repeats, seed=seed)
        device_flops = flops_per_row / dev_per_row
    return HardwareProfile(
        name=device,
        flops_per_s=flops_per_row / per_row,
        mem_bw=bytes_per_row / per_row,
        link_bw=link_bw,
        launch_latency_s=launch,
        measured=True,
        device_count=n_dev,
        device_flops_per_s=device_flops)


def _fit_per_row(backend, device: str, *, dim: int, width: int, rows,
                 repeats: int, seed: int) -> Tuple[float, float]:
    """Two-point linear fit of the backend's embed time: (per-row
    seconds, launch latency)."""
    import numpy as np

    from repro.pipeline.backend import InferSpec  # lazy import: cycle
    from repro.pipeline.batcher import BatcherStats
    from repro.core.zoo import ZooModel

    rng = np.random.default_rng(seed)
    W = (rng.standard_normal((dim, width)).astype(np.float32)
         / np.sqrt(dim))
    zm = ZooModel(name=f"__calib_{device}", source_family="gauss", W=W,
                  mode="linear")
    version = f"__calib_{device}@{dim}x{width}"
    model = _CalibModel(zm)
    spec = InferSpec(kind="embed", task="__calib__", col="x", out="f",
                     table="__calib__", version=version, model=model,
                     batch_size=32, share=None, stats=BatcherStats())
    backend.stage(version, zm)
    times = []
    for n in rows:
        X = rng.standard_normal((n, dim)).astype(np.float32)
        batch = {"x": X}
        backend.run_infer(spec, batch)          # warmup: compile + stage
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            backend.run_infer(spec, batch)
            best = min(best, time.perf_counter() - t0)
        times.append(best)
    n0, n1 = int(rows[0]), int(rows[-1])
    t0_, t1_ = times[0], times[-1]
    per_row = max((t1_ - t0_) / max(n1 - n0, 1), 1e-12)
    launch = max(t0_ - n0 * per_row, 0.0)
    return per_row, launch


class _CalibModel:
    """ResolvedModel-shaped shim around a raw ZooModel for calibration."""

    def __init__(self, zm):
        self.zoo_model = zm
        self.features = zm.features
        self.head = lambda F: F.mean(axis=1)


# ---------------------------------------------------------------------------
# On-disk calibration memo: share probe results across processes and runs
# ---------------------------------------------------------------------------

def profile_memo_fingerprint(parts) -> str:
    """Host/backend/device-count identity of one calibration memo entry.

    The key *is* the staleness guard: jax-flavoured backends embed the
    jax version and live device count, host-only ones the cpu count, so
    an upgrade or a different device topology simply misses the memo and
    re-probes. Backends that never touch jax deliberately don't import
    it here — spawned numpy workers stay jax-free."""
    import os
    import platform
    toks = [platform.node() or "host"]
    toks += [str(p) for p in parts if p is not None]
    if any("jax" in t for t in toks[1:]):
        try:
            import jax
            toks.append(f"jax={jax.__version__}")
            toks.append(f"jaxdev={jax.device_count()}")
        except Exception:  # pragma: no cover - jax import failure
            toks.append("jax=unavailable")
    else:
        toks.append(f"cpus={os.cpu_count()}")
    return "|".join(toks)


def load_profile_memo(path) -> Dict[str, HardwareProfile]:
    """Read an on-disk calibration memo ({fingerprint: profile fields}).
    Unreadable files and schema-drifted entries read as empty/stale —
    the caller just re-probes."""
    import json
    from pathlib import Path
    try:
        raw = json.loads(Path(path).read_text())
    except (OSError, ValueError):
        return {}
    if not isinstance(raw, dict):
        return {}
    out: Dict[str, HardwareProfile] = {}
    for fp, fields in raw.items():
        try:
            out[fp] = HardwareProfile(**fields)
        except TypeError:
            continue                       # schema drift: treat as stale
    return out


def store_profile_memo(path, fingerprint: str, prof: HardwareProfile) -> None:
    """Merge one measured profile into the on-disk memo. Atomic replace;
    concurrent workers race benignly (last writer wins with equivalent
    measurements for the same fingerprint)."""
    import dataclasses as _dc
    import json
    import os
    from pathlib import Path
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    memo = {fp: _dc.asdict(p) for fp, p in load_profile_memo(path).items()}
    memo[fingerprint] = _dc.asdict(prof)
    tmp = path.with_name(f"{path.name}.tmp{os.getpid()}")
    tmp.write_text(json.dumps(memo, indent=1, sort_keys=True))
    tmp.replace(path)
