"""Operator cost model + device placement (paper §5.2, Eq. 5-10),
re-derived for the TPU target.

C_op = ExecTime_op + TransCost_op
  ExecTime  = ModelFLOPS / FLOPS(device) * nrows
  TransCost = ModelSize/MemBW + ModelSize/AccelBW + Latency

Devices: 'host' (CPU relational ops + small models), 'tpu' (v5e chip),
'api' (remote endpoint; cost = end-to-end latency, Eq. 5 note). The
decision rule (Eq. 10) picks argmin cost. Batch-size selection (Eq. 11)
maximizes throughput subject to a memory cap and a latency bound.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

# hardware constants (host numbers measured-order-of-magnitude; TPU per brief)
HOST_FLOPS = 5e10          # ~50 GFLOP/s effective numpy single-core
HOST_MEM_BW = 2e10         # bytes/s host memory effective
TPU_FLOPS = 197e12         # bf16 peak per chip
TPU_HBM_BW = 819e9
HOST_TO_TPU_BW = 5e9       # PCIe/infeed-equivalent bytes/s
TPU_LAUNCH_LATENCY = 5e-5  # dispatch overhead per call (s)


@dataclass(frozen=True)
class OpProfile:
    """Static profile of one operator instance."""
    flops_per_row: float = 0.0
    bytes_per_row: float = 0.0
    model_bytes: float = 0.0       # weights to stage (0 for relational ops)
    api_latency_s: float = 0.0     # >0 => remote model


def exec_time(p: OpProfile, nrows: int, device: str) -> float:
    if device == "api":
        return p.api_latency_s  # end-to-end response latency (Eq. 5 note)
    flops = p.flops_per_row * nrows
    byts = p.bytes_per_row * nrows
    if device == "tpu":
        return max(flops / TPU_FLOPS, byts / TPU_HBM_BW)
    return max(flops / HOST_FLOPS, byts / HOST_MEM_BW)


def trans_cost(p: OpProfile, nrows: int, device: str) -> float:
    if device == "api":
        return 0.0
    if device == "tpu":
        # stage weights + move batch over the host<->device link (Eq. 7)
        batch_bytes = p.bytes_per_row * nrows
        return (p.model_bytes / HOST_MEM_BW
                + (p.model_bytes + batch_bytes) / HOST_TO_TPU_BW
                + TPU_LAUNCH_LATENCY)
    return p.model_bytes / HOST_MEM_BW  # Eq. 9


def op_cost(p: OpProfile, nrows: int, device: str) -> float:
    return exec_time(p, nrows, device) + trans_cost(p, nrows, device)


def choose_device(p: OpProfile, nrows: int,
                  devices=("host", "tpu")) -> str:
    """Eq. 10 generalized over the available device set."""
    cand = list(devices)
    if p.api_latency_s > 0:
        cand.append("api")
    return min(cand, key=lambda d: op_cost(p, nrows, d))


def place_dag(dag, profiles: Dict[str, OpProfile], nrows_hint: int = 1024,
              devices=("host", "tpu")) -> Dict[str, str]:
    """Plan-time device placement (Eq. 10) over an operator DAG.

    Annotates each ``Node.device`` in place and returns the placement map.
    This is a *planning* pass — `PipelineExecutor` is a pure runtime and
    only reads the annotations (`repro.engine` calls this while lowering a
    logical plan; callers building DAGs by hand call it directly).
    """
    placement = {}
    for op_id, node in dag.nodes.items():
        prof = profiles.get(op_id)
        if node.kind in ("predict", "embed") and prof is not None:
            placement[op_id] = choose_device(prof, nrows_hint, devices)
        else:
            placement[op_id] = "host"
        node.device = placement[op_id]
    return placement


# ---------------------------------------------------------------------------
# Batch-size selection (Eq. 11)
# ---------------------------------------------------------------------------

def batch_cost(p: OpProfile, batch: int, device: str,
               *, fixed_overhead_s: float = 2e-4) -> Dict[str, float]:
    t = op_cost(p, batch, device) + fixed_overhead_s
    return {"latency_s": t, "throughput": batch / t,
            "mem_bytes": p.bytes_per_row * batch + p.model_bytes}


def choose_batch_size(p: OpProfile, device: str, *,
                      candidates=(1, 2, 4, 8, 16, 32, 64, 128),
                      mem_cap_bytes: float = 2e9,
                      latency_bound_s: Optional[float] = None) -> int:
    """argmax throughput s.t. memory cap + optional latency bound. The
    paper's observed sweet spot (8-32) falls out of the overhead/memory
    trade-off rather than being hard-coded."""
    best, best_tp = candidates[0], -1.0
    for b in candidates:
        c = batch_cost(p, b, device)
        if c["mem_bytes"] > mem_cap_bytes:
            continue
        if latency_bound_s and c["latency_s"] > latency_bound_s:
            continue
        if c["throughput"] > best_tp:
            best, best_tp = b, c["throughput"]
    return best


def profile_for_model(n_params: float, bytes_per_row: float,
                      flops_per_row: Optional[float] = None,
                      dtype_bytes: int = 4) -> OpProfile:
    return OpProfile(
        flops_per_row=flops_per_row if flops_per_row else 2.0 * n_params,
        bytes_per_row=bytes_per_row,
        model_bytes=n_params * dtype_bytes)
