from repro.pipeline.admission import (AdmissionPolicy, CircuitOpen,
                                      LaneBreaker, Rejected, RequestError,
                                      BATCH, BEST_EFFORT, INTERACTIVE,
                                      PRIORITIES, validate_priority)
from repro.pipeline.backend import (ExecutionBackend, InferSpec, JaxBackend,
                                    NumpyBackend, StagedModel,
                                    default_host_backend, make_backends)
from repro.pipeline.batcher import (BatcherStats, ContinuousBatcher, Request,
                                    WindowBatcher, run_batched)
from repro.pipeline.cost import (DEFAULT_HW, DynamicBudget, HardwareProfile,
                                 OpProfile, batch_cost, calibrate,
                                 choose_batch_size, choose_device,
                                 delta_staged_profile, op_cost, place_dag,
                                 profile_for_model, split_profile)
from repro.pipeline.dag import Dag, Edge, Node
from repro.pipeline.operators import (Batch, aggregate, batch_len,
                                      concat_batches, filter_op, groupby_agg,
                                      groupby_aggs, iter_chunks, join, scan,
                                      slice_batch, window_op)
from repro.pipeline.scheduler import ExecStats, PipelineExecutor
from repro.pipeline.share import (AnnConfig, AnnShareTier, AnnStats,
                                  CacheChain, CacheTier, IvfFlatIndex,
                                  ShareStats, TierLookup, VectorShareCache,
                                  fingerprint, fingerprint_rows,
                                  simd_normalize_embed)

__all__ = [
    "AdmissionPolicy", "CircuitOpen", "LaneBreaker", "Rejected",
    "RequestError", "BATCH", "BEST_EFFORT", "INTERACTIVE", "PRIORITIES",
    "validate_priority", "DynamicBudget",
    "ExecutionBackend", "InferSpec", "JaxBackend", "NumpyBackend",
    "StagedModel", "default_host_backend", "make_backends",
    "BatcherStats", "ContinuousBatcher", "Request", "WindowBatcher",
    "run_batched", "DEFAULT_HW", "HardwareProfile", "OpProfile",
    "batch_cost", "calibrate", "choose_batch_size", "choose_device",
    "delta_staged_profile", "op_cost", "place_dag", "profile_for_model",
    "split_profile",
    "Dag", "Edge", "Node",
    "Batch", "aggregate", "batch_len", "concat_batches", "filter_op",
    "groupby_agg", "groupby_aggs", "iter_chunks", "join", "scan",
    "slice_batch", "window_op", "ExecStats", "PipelineExecutor",
    "AnnConfig", "AnnShareTier", "AnnStats", "CacheChain", "CacheTier",
    "IvfFlatIndex", "TierLookup",
    "ShareStats", "VectorShareCache", "fingerprint", "fingerprint_rows",
    "simd_normalize_embed",
]
