"""Columnar operators for the batch inference pipeline.

A *batch* is a dict of equal-length numpy columns. Relational operators
(scan/filter/join/groupby/window) run on host; ``predict`` nodes run the
resolved task model on the device the cost model chose; ``embed`` nodes
materialize shared pre-embeddings (paper §5.1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np

Batch = Dict[str, np.ndarray]


def batch_len(b: Batch) -> int:
    return len(next(iter(b.values()))) if b else 0


def concat_batches(bs: Sequence[Batch]) -> Batch:
    keys = bs[0].keys()
    return {k: np.concatenate([b[k] for b in bs]) for k in keys}


def slice_batch(b: Batch, lo: int, hi: int) -> Batch:
    return {k: v[lo:hi] for k, v in b.items()}


def iter_chunks(b: Batch, size: int) -> Iterator[Batch]:
    n = batch_len(b)
    for lo in range(0, n, size):
        yield slice_batch(b, lo, min(lo + size, n))


# -- relational ops -----------------------------------------------------------

def scan(table: Batch) -> Batch:
    return table


def filter_op(b: Batch, pred: Callable[[Batch], np.ndarray]) -> Batch:
    mask = pred(b)
    return {k: v[mask] for k, v in b.items()}


def join(left: Batch, right: Batch, on: str,
         suffix: str = "_r") -> Batch:
    """Sort-merge inner join on an integer/str key column.

    Fully vectorized (argsort + searchsorted + repeat): no per-row
    interpreter iterations, so the host-relational path the pipeline
    overlaps with device inference scales to large build/probe sides.
    Output ordering matches the classic hash join: probe (left) rows in
    order, ties expanded in right-side row order (stable sort).
    """
    lk, rk = np.asarray(left[on]), np.asarray(right[on])
    order = np.argsort(rk, kind="stable")
    rs = rk[order]
    lo = np.searchsorted(rs, lk, side="left")
    hi = np.searchsorted(rs, lk, side="right")
    cnt = hi - lo
    li_a = np.repeat(np.arange(len(lk), dtype=np.int64), cnt)
    total = int(cnt.sum())
    if total:
        starts = np.repeat(lo, cnt)
        group_first = np.repeat(np.cumsum(cnt) - cnt, cnt)
        offs = np.arange(total, dtype=np.int64) - group_first
        ri_a = order[starts + offs]
    else:
        ri_a = np.zeros(0, np.int64)
    out = {k: v[li_a] for k, v in left.items()}
    for k, v in right.items():
        if k == on:
            continue
        out[k + suffix if k in out else k] = v[ri_a]
    return out


def groupby_agg(b: Batch, key: str, col: str,
                agg: str = "mean") -> Batch:
    keys, inv = np.unique(b[key], return_inverse=True)
    sums = np.zeros(len(keys), np.float64)
    cnts = np.zeros(len(keys), np.int64)
    np.add.at(sums, inv, b[col].astype(np.float64))
    np.add.at(cnts, inv, 1)
    if agg == "mean":
        vals = sums / np.maximum(cnts, 1)
    elif agg == "sum":
        vals = sums
    elif agg == "count":
        vals = cnts.astype(np.float64)
    else:
        raise ValueError(agg)
    return {key: keys, f"{agg}_{col}": vals}


def groupby_aggs(b: Batch, key: str,
                 specs: Sequence[tuple]) -> Batch:
    """Multi-aggregate group-by: ``specs`` is a sequence of
    ``(col, agg, out_name)`` with agg in mean|sum|count (count ignores
    ``col``; pass '*'). One pass over the group index serves all specs."""
    keys, inv = np.unique(b[key], return_inverse=True)
    cnts = np.zeros(len(keys), np.int64)
    np.add.at(cnts, inv, 1)
    out: Batch = {key: keys}
    for col, agg, name in specs:
        if agg == "count":
            out[name] = cnts.astype(np.float64)
            continue
        sums = np.zeros(len(keys), np.float64)
        np.add.at(sums, inv, b[col].astype(np.float64))
        if agg == "sum":
            out[name] = sums
        elif agg == "mean":
            out[name] = sums / np.maximum(cnts, 1)
        else:
            raise ValueError(agg)
    return out


def aggregate(b: Batch, specs: Sequence[tuple]) -> Batch:
    """Whole-table aggregates (no GROUP BY): one-row batch of
    ``(col, agg, out_name)`` results."""
    n = batch_len(b)
    out: Batch = {}
    for col, agg, name in specs:
        if agg == "count":
            out[name] = np.array([float(n)])
        elif agg == "sum":
            out[name] = np.array([float(b[col].sum()) if n else 0.0])
        elif agg == "mean":
            out[name] = np.array([float(b[col].mean()) if n else 0.0])
        else:
            raise ValueError(agg)
    return out


def window_op(b: Batch, col: str, size: int, fn: str = "mean") -> Batch:
    """Sliding window over a column (series tasks)."""
    x = b[col].astype(np.float64)
    if len(x) < size:
        return dict(b)
    c = np.convolve(x, np.ones(size) / size, mode="same")
    out = dict(b)
    out[f"{fn}{size}_{col}"] = c
    return out
