"""Execution backends: make ``Node.device`` annotations real.

The planner (Eq. 10) annotates inference nodes with a device; this module
supplies the *executors* those annotations dispatch to. A backend owns
three responsibilities for embed/predict operators:

- **staging** — weights move to the execution device once per resolved
  task (``stage`` at ``MorphingSession.resolve_task``), never per chunk,
  which is exactly the amortization the cost model's TransCost term
  (Eq. 7) assumes;
- **compiled forward** — :class:`JaxBackend` compiles each resolved
  ``ZooModel`` forward pass (all four modes: linear/radial/relu/proj1d)
  plus the score head into ``jax.jit``-compiled functions. The linear
  mode routes through the fused normalize+project+tanh Pallas kernel
  (``repro.kernels.fused_embed``): interpret mode on CPU, real Pallas on
  TPU;
- **shape bucketing** — ragged chunk row counts are padded to the next
  power of two and sliced on return, so a whole query triggers at most
  O(log n) compilations instead of one per distinct chunk length.
  ``compile_count`` exposes the number of distinct compiled shapes (jit
  caches per input shape) and ``on_compile`` is a hook for tests.

``PipelineExecutor`` holds a registry ``{device annotation -> backend}``
and routes each node through it; nodes without a native backend
implementation fall back to their lowered host closure (``node.fn``).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.zoo import adapt_input_width
from repro.pipeline.batcher import BatcherStats, WindowBatcher


@dataclass
class InferSpec:
    """Everything a backend needs to run one inference operator natively.

    Attached to ``Node.meta['infer']`` by plan lowering; ``kind`` is
    'embed' (features only, share-cached) or 'predict' (features + score
    head fused). ``stats`` is the shared per-task BatcherStats sink.
    """
    kind: str
    task: str
    col: str
    out: str
    table: str
    version: str
    model: Any                       # ResolvedModel (or shim): .features,
    #                                # .head, .zoo_model
    batch_size: int = 32
    share: Optional[Any] = None      # VectorShareCache
    stats: BatcherStats = field(default_factory=BatcherStats)


class ExecutionBackend:
    """Base backend: share-cache plumbing + node fallback dispatch."""

    name = "base"

    def __init__(self):
        # InferSpec.stats is shared across concurrent chunk runs of the
        # same node: accumulate under a lock (same race class as
        # ExecStats in the executor)
        self._stats_lock = threading.Lock()
        # chaos hook (duck-typed; see training.fault.FaultInjector):
        # fires at the top of run_infer when set, so tests and the
        # overload bench can inject errors/stalls without a flaky device
        self.fault_injector: Optional[Any] = None

    # -- staging ----------------------------------------------------------
    def stage(self, version: str, zoo_model) -> Any:
        """Move a resolved model's weights onto the execution device.
        Idempotent per version; called once at resolve time."""
        return zoo_model

    def unstage(self, version: str) -> bool:
        """Release staged device state for one trunk identity (the
        dispatch tier's scale-in path). Idempotent; returns True when
        something was actually evicted. Host backends keep no staged
        state, so the base implementation is a no-op."""
        return False

    # -- node dispatch ----------------------------------------------------
    def run_node(self, node, inputs: List[Any]) -> Any:
        spec = node.meta.get("infer") if node.meta else None
        if spec is not None and inputs:
            return self.run_infer(spec, inputs[0])
        if node.fn:
            return node.fn(*inputs)
        return inputs[0] if inputs else None

    def run_infer(self, spec: InferSpec, batch: Dict[str, np.ndarray]
                  ) -> Dict[str, np.ndarray]:
        fi = self.fault_injector
        if fi is not None:
            fi.on_infer(spec, len(batch.get(spec.col, ())))
        res = dict(batch)
        X = batch[spec.col]
        if spec.kind == "embed":
            if spec.share is not None and len(X):
                res[spec.out] = spec.share.get_or_embed(
                    spec.table, spec.col, np.asarray(X),
                    lambda A: self._features(spec, A),
                    version=spec.version)
            else:
                res[spec.out] = self._features(spec, X)
        else:  # full predict: features + score head
            res[spec.out] = self._predict(spec, X)
        return res

    def run_head(self, spec: InferSpec, F: np.ndarray) -> np.ndarray:
        """Head-only execution entry point: consume embeddings, produce
        scores in ``spec.batch_size``-row slices (the head stage's own
        Eq. 11 budget). Heads are O(rows * head_dim) host work (plan
        lowering keeps them as host closures too), so the base
        implementation is shared by every backend; stats land in
        ``spec.stats`` so serving telemetry can report head rows next to
        embed rows."""
        F = np.asarray(F, np.float32)
        if len(F) == 0:
            return np.zeros(0, np.float32)
        bs = max(1, spec.batch_size)
        t0 = time.perf_counter()
        outs = [np.asarray(spec.model.head(F[i:i + bs]))
                for i in range(0, len(F), bs)]
        dt = time.perf_counter() - t0
        st = spec.stats
        with self._stats_lock:
            st.batches += len(outs)
            st.rows += len(F)
            st.infer_seconds += dt
        return np.concatenate(outs) if len(outs) > 1 else outs[0]

    # -- to implement ------------------------------------------------------
    def _features(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def _predict(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        raise NotImplementedError


class NumpyBackend(ExecutionBackend):
    """Host reference path: the resolved model's numpy forward, batched in
    window-sized slices (paper §5.2 window-function batch inference).

    A columnar 2-D numeric input already *is* an aggregated window, so it
    runs as vectorized ``batch_size`` slices; ragged/object rows fall
    back to the row-at-a-time WindowBatcher (which owns the per-row
    tensor conversion the vectorized path skips)."""

    name = "numpy"

    def _batched(self, spec: InferSpec, X: np.ndarray,
                 fn: Callable[[np.ndarray], np.ndarray]) -> np.ndarray:
        if len(X) == 0:
            # empty chunk: keep the true output width so cross-chunk
            # concatenation stays shape-consistent
            return np.asarray(fn(X))
        Xa = np.asarray(X)
        if Xa.dtype != object and Xa.ndim >= 2:
            return self._batched_sliced(spec, Xa, fn)
        wb = WindowBatcher(fn, batch_size=spec.batch_size,
                           convert_workers=1)
        for i in range(len(X)):
            wb.add(i, X[i])
        res = wb.finish()
        st = spec.stats
        with self._stats_lock:
            st.batches += wb.stats.batches
            st.rows += wb.stats.rows
            st.infer_seconds += wb.stats.infer_seconds
            st.convert_seconds += wb.stats.convert_seconds
        return np.stack([np.asarray(res[i]) for i in range(len(X))])

    def _batched_sliced(self, spec: InferSpec, X: np.ndarray,
                        fn: Callable[[np.ndarray], np.ndarray]
                        ) -> np.ndarray:
        bs = max(1, spec.batch_size)
        t0 = time.perf_counter()
        outs = [np.asarray(fn(X[i:i + bs])) for i in range(0, len(X), bs)]
        dt = time.perf_counter() - t0
        st = spec.stats
        with self._stats_lock:
            st.batches += len(outs)
            st.rows += len(X)
            st.infer_seconds += dt
        return np.concatenate(outs) if len(outs) > 1 else outs[0]

    def _features(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        return self._batched(spec, X, spec.model.features)

    def _predict(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        return spec.model.head(self._batched(spec, X, spec.model.features))


@dataclass
class StagedModel:
    """One resolved model, staged: device-resident weights + jitted fns."""
    version: str
    mode: str
    in_dim: int
    out_dim: int
    features_fn: Callable            # [B, in_dim] -> [B, out_dim]
    predict_fn: Callable             # [B, in_dim] -> [B]
    seen_shapes: Set[Tuple[str, int]] = field(default_factory=set)


def _next_pow2(n: int) -> int:
    return 1 << (max(n, 1) - 1).bit_length()


class JaxBackend(ExecutionBackend):
    """jit-compiled device path with shape bucketing + one-time staging.

    ``interpret`` defaults to True off-TPU (kernels run in Pallas
    interpret mode under jit) and False on TPU. Whole chunks run as one
    device call — the bucketing supersedes host-side window batching, so
    ``batch_size`` annotations are telemetry-only on this backend.
    """

    name = "jax"

    def __init__(self, *, interpret: Optional[bool] = None,
                 min_bucket: int = 32, block_rows: int = 256):
        import jax  # deferred so numpy-only paths never pay the import

        super().__init__()
        self._jax = jax
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else bool(interpret))
        self.min_bucket = min_bucket
        self.block_rows = block_rows
        self._staged: Dict[str, StagedModel] = {}
        self._lock = threading.Lock()
        self.stage_count = 0             # actual device stagings performed
        self.on_compile: Optional[Callable[[str, Tuple[str, int]], None]] \
            = None

    # -- staging ----------------------------------------------------------
    def _put_weight(self, arr) -> Any:
        """Move one weight tensor onto the execution device(s). The mesh
        subclass overrides this to stage once per mesh with a replicated
        NamedSharding; the base class targets the default device."""
        jnp = self._jax.numpy
        return self._jax.device_put(jnp.asarray(arr, jnp.float32))

    def _raw_forward(self, zoo_model) -> Tuple[str, int, int,
                                               Callable, Tuple[Any, ...]]:
        """Build the uncompiled forward for one resolved model.

        Returns ``(mode, in_dim, out_dim, raw, weights)`` where
        ``raw(X, *weights)`` maps a [B, in_dim] batch to features and the
        weights are already device-resident (:meth:`_put_weight`).
        Weights are explicit arguments — not closure captures — so the
        mesh subclass can hand them to ``shard_map`` with replicated
        in_specs while the batch splits over the mesh.
        """
        jnp = self._jax.numpy
        from repro.kernels.fused_embed import fused_embed

        mode = zoo_model.mode
        W = self._put_weight(zoo_model.W)
        in_dim = int(zoo_model.W.shape[0])
        if mode == "radial":
            centers = self._put_weight(zoo_model.centers)
            inv_two_sig2 = 1.0 / (2.0 * float(zoo_model.sigma) ** 2)
            out_dim = int(zoo_model.centers.shape[0])

            def raw(X, centers):
                d2 = ((X[:, None, :] - centers[None]) ** 2).sum(-1)
                return jnp.exp(-d2 * inv_two_sig2)
            return mode, in_dim, out_dim, raw, (centers,)
        if mode == "relu":
            out_dim = int(zoo_model.W.shape[1])

            def raw(X, W):
                return jnp.maximum(X @ W, 0.0)
            return mode, in_dim, out_dim, raw, (W,)
        if mode == "proj1d":
            out_dim = 2 * int(zoo_model.W.shape[1])

            def raw(X, W):
                Z = X @ W
                return jnp.tanh(jnp.concatenate([Z, Z ** 2 - 1.0], axis=1))
            return mode, in_dim, out_dim, raw, (W,)
        # linear -> fused normalize+project+tanh Pallas kernel
        out_dim = int(zoo_model.W.shape[1])
        interpret = self.interpret
        block_rows = self.block_rows

        def raw(X, W):
            return fused_embed(X, W, block_rows=block_rows,
                               interpret=interpret)
        return mode, in_dim, out_dim, raw, (W,)

    def _compile_forward(self, raw: Callable,
                         weights: Tuple[Any, ...]) -> Tuple[Callable,
                                                            Callable]:
        """(features_fn, predict_fn) from the raw forward. Overridden by
        the mesh subclass to split the batch axis across devices."""
        jax, jnp = self._jax, self._jax.numpy
        return (jax.jit(lambda X: raw(X, *weights)),
                jax.jit(lambda X: raw(X, *weights)
                        .astype(jnp.float32).mean(axis=1)))

    def stage(self, version: str, zoo_model) -> StagedModel:
        with self._lock:
            if version in self._staged:
                return self._staged[version]
        mode, in_dim, out_dim, raw, weights = self._raw_forward(zoo_model)
        features_fn, predict_fn = self._compile_forward(raw, weights)
        staged = StagedModel(
            version=version, mode=mode, in_dim=in_dim, out_dim=out_dim,
            features_fn=features_fn, predict_fn=predict_fn)
        with self._lock:
            if version not in self._staged:   # lost race: first stage wins
                self._staged[version] = staged
                self.stage_count += 1
        return self._staged[version]

    def unstage(self, version: str) -> bool:
        """Drop the staged weights + compiled functions for one version.
        A later request for the same version late-stages transparently
        through :meth:`_staged_for` (paying Eq. 7 again, by design —
        this is the dispatch tier's scale-in path)."""
        with self._lock:
            return self._staged.pop(version, None) is not None

    @property
    def compile_count(self) -> int:
        """Distinct compiled (fn, bucket) shapes across staged models —
        jit compiles exactly once per new input shape."""
        with self._lock:
            return sum(len(s.seen_shapes) for s in self._staged.values())

    # -- bucketed execution ------------------------------------------------
    def _staged_for(self, spec: InferSpec) -> StagedModel:
        staged = self._staged.get(spec.version)
        if staged is None:                    # not staged at resolve: late
            staged = self.stage(spec.version, spec.model.zoo_model)
        return staged

    def _bucket_for(self, n: int) -> int:
        """Padded row count for an n-row chunk. The mesh subclass rounds
        up to a multiple of the mesh size so every device gets an equal
        slice of the batch axis (a power-of-two bucket already is one
        for power-of-two meshes, keeping compile telemetry identical)."""
        return max(_next_pow2(n), self.min_bucket)

    def _bucketed(self, staged: StagedModel, fn_key: str, fn: Callable,
                  X: np.ndarray, out_shape: Tuple[int, ...]) -> np.ndarray:
        n = len(X)
        if n == 0:
            return np.zeros(out_shape, np.float32)
        Xp = adapt_input_width(np.asarray(X, np.float32), staged.in_dim)
        d = staged.in_dim
        bucket = self._bucket_for(n)
        if bucket == n:                       # aligned chunk: no pad copy
            Xb = np.ascontiguousarray(Xp)
        else:
            Xb = np.zeros((bucket, d), np.float32)
            Xb[:n] = Xp
        key = (fn_key, bucket)
        with self._lock:
            new_shape = key not in staged.seen_shapes
            if new_shape:
                staged.seen_shapes.add(key)
        if new_shape and self.on_compile is not None:
            self.on_compile(staged.version, key)
        out = np.asarray(fn(Xb))
        return out[:n]

    def _features(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        staged = self._staged_for(spec)
        t0 = time.perf_counter()
        out = self._bucketed(staged, "features", staged.features_fn, X,
                             (0, staged.out_dim))
        dt = time.perf_counter() - t0
        st = spec.stats
        with self._stats_lock:
            st.batches += 1 if len(X) else 0
            st.rows += len(X)
            st.infer_seconds += dt
        return out

    def _predict(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        staged = self._staged_for(spec)
        t0 = time.perf_counter()
        # the staged predict_fn fuses the *mean* score head (what
        # ResolvedModel serves); a model carrying a custom head keeps
        # numpy-backend parity by running features on device + head on host
        if getattr(spec.model, "head_kind", "mean") == "mean":
            out = self._bucketed(staged, "predict", staged.predict_fn, X,
                                 (0,))
        else:
            F = self._bucketed(staged, "features", staged.features_fn, X,
                               (0, staged.out_dim))
            out = np.asarray(spec.model.head(F))
        dt = time.perf_counter() - t0
        st = spec.stats
        with self._stats_lock:
            st.batches += 1 if len(X) else 0
            st.rows += len(X)
            st.infer_seconds += dt
        return out

    # -- calibration hooks -------------------------------------------------
    def measure_link_bandwidth(self, nbytes: int = 8 << 20,
                               repeats: int = 3) -> float:
        """bytes/s of the host->device staging path (device_put)."""
        jax, jnp = self._jax, self._jax.numpy
        buf = np.ones(nbytes // 4, np.float32)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.device_put(buf).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return buf.nbytes / max(best, 1e-9)


class MeshJaxBackend(JaxBackend):
    """Data-parallel jit path over a :class:`jax.sharding.Mesh`.

    Staging moves each trunk's weights onto the mesh *once*, replicated
    under the serving rule table (``repro.distributed.sharding``:
    ``serving_rules`` maps every weight axis to ``None`` and the batch
    axis to ``"data"``); the compiled forward wraps the raw per-device
    function in ``shard_map``, so an embed chunk's rows split evenly
    across the mesh and each device runs the same kernels (including the
    Pallas fused-embed path) on its local shard. Shape bucketing rounds
    chunk rows up to a mesh-size multiple — for power-of-two meshes the
    existing power-of-two buckets already qualify, so compile telemetry
    matches the single-device backend.
    """

    name = "jax-mesh"

    def __init__(self, mesh=None, *, device_count: Optional[int] = None,
                 interpret: Optional[bool] = None, min_bucket: int = 32,
                 block_rows: int = 256):
        super().__init__(interpret=interpret, min_bucket=min_bucket,
                         block_rows=block_rows)
        jax = self._jax
        if mesh is None:
            from repro.launch.mesh import make_serving_mesh
            n = (len(jax.devices()) if device_count is None
                 else int(device_count))
            mesh = make_serving_mesh(n)
        self.mesh = mesh
        self.device_count = int(np.prod(list(mesh.shape.values())))

    # -- mesh staging + compilation ---------------------------------------
    def _put_weight(self, arr) -> Any:
        from repro.distributed.sharding import serving_weight_sharding
        jnp = self._jax.numpy
        a = jnp.asarray(arr, jnp.float32)
        return self._jax.device_put(
            a, serving_weight_sharding(self.mesh, a.ndim))

    def _compile_forward(self, raw, weights):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import serving_batch_sharding
        jax, jnp = self._jax, self._jax.numpy
        # batch rows split over "data"; weights replicated on every
        # device (they were staged that way) — check_rep off because the
        # Pallas fused-embed call defeats the replication checker
        sharded = shard_map(
            raw, mesh=self.mesh,
            in_specs=(P("data"),) + (P(),) * len(weights),
            out_specs=P("data"), check_rep=False)
        x_sharding = serving_batch_sharding(self.mesh)
        features_fn = jax.jit(lambda X: sharded(X, *weights),
                              in_shardings=x_sharding)
        predict_fn = jax.jit(
            lambda X: sharded(X, *weights)
            .astype(jnp.float32).mean(axis=1),
            in_shardings=x_sharding)
        return features_fn, predict_fn

    def _bucket_for(self, n: int) -> int:
        b = max(_next_pow2(n), self.min_bucket)
        nd = self.device_count
        return -(-b // nd) * nd

    # -- calibration hooks -------------------------------------------------
    def per_device_probe(self) -> JaxBackend:
        """A fresh single-device backend of the same flavour, so
        ``cost.calibrate`` can report the per-device rate next to the
        mesh-aggregate rate it measures through this backend."""
        return JaxBackend(interpret=self.interpret,
                          min_bucket=self.min_bucket,
                          block_rows=self.block_rows)


_HOST_BACKEND: Optional[NumpyBackend] = None


def default_host_backend() -> NumpyBackend:
    """Singleton numpy backend used by lowered ``node.fn`` closures so
    executors constructed without a registry keep working."""
    global _HOST_BACKEND
    if _HOST_BACKEND is None:
        _HOST_BACKEND = NumpyBackend()
    return _HOST_BACKEND


class BackendPool(Dict[str, ExecutionBackend]):
    """Placement-aware ``{device annotation -> backend}`` pool.

    A drop-in replacement for the plain registry dict ``make_backends``
    used to return (same mapping protocol, so planner/session/server
    lookups are untouched) that additionally owns the *mesh dimension*
    of placement: ``device_count`` is how many devices the accelerator
    annotation actually spans, and ``mesh`` is the live
    ``jax.sharding.Mesh`` when it spans more than one. Single-device
    pools (``device_count == 1``) carry no mesh and hold exactly the
    backends the old registry built — the parity-exact fallback path.
    """

    def __init__(self, mapping: Dict[str, ExecutionBackend], *,
                 kind: str = "auto", device_count: int = 1, mesh=None):
        super().__init__(mapping)
        self.kind = kind
        self.device_count = int(device_count)
        self.mesh = mesh

    def backend_for(self, device: str) -> ExecutionBackend:
        return self.get(device) or default_host_backend()

    def distinct(self) -> List[ExecutionBackend]:
        return list({id(b): b for b in self.values()}.values())

    def set_fault_injector(self, injector: Optional[Any]) -> None:
        """Thread a chaos hook (``training.fault.FaultInjector`` or
        ``None`` to clear) through every distinct backend in the pool."""
        for b in self.distinct():
            b.fault_injector = injector


def _mesh_jax_backend(device_count: int) -> Tuple[Optional[JaxBackend],
                                                  int, Any]:
    """(backend, effective device count, mesh) for an accelerator slot.

    ``device_count`` is clamped to the devices jax actually exposes
    (simulated host devices count via ``xla_force_host_platform_
    device_count``); a clamp to one device degrades to the plain
    single-device :class:`JaxBackend` — byte-identical to the
    pre-mesh path.
    """
    import jax
    n = max(1, min(int(device_count), len(jax.devices())))
    if n == 1:
        return JaxBackend(), 1, None
    b = MeshJaxBackend(device_count=n)
    return b, b.device_count, b.mesh


def make_backends(kind: str = "auto",
                  devices: Tuple[str, ...] = ("host", "tpu"),
                  device_count: int = 1) -> BackendPool:
    """Build the placement-aware backend pool.

    'auto'  -> host: numpy, tpu: jax (numpy fallback if jax is missing)
    'numpy' -> every device runs the host numpy path
    'jax'   -> every device runs the jitted path (CPU = interpret kernels)

    ``device_count > 1`` asks for a mesh: the jax-backed annotations are
    served by one :class:`MeshJaxBackend` spanning ``min(device_count,
    jax.device_count())`` devices. The numpy path has no devices to
    span, so a pure-numpy pool always reports ``device_count == 1``.
    """
    np_b = NumpyBackend()
    if kind == "numpy":
        return BackendPool({d: np_b for d in devices}, kind=kind)
    if kind == "jax":
        jb, n, mesh = _mesh_jax_backend(device_count)
        return BackendPool({d: jb for d in devices}, kind=kind,
                           device_count=n, mesh=mesh)
    if kind != "auto":
        raise ValueError(f"unknown backend kind {kind!r}")
    reg: Dict[str, ExecutionBackend] = {}
    n_eff, mesh = 1, None
    for d in devices:
        if d == "tpu":
            try:
                reg[d], n_eff, mesh = _mesh_jax_backend(device_count)
            except Exception:                 # jax unavailable: degrade
                reg[d] = np_b
        else:
            reg[d] = np_b
    return BackendPool(reg, kind=kind, device_count=n_eff, mesh=mesh)
