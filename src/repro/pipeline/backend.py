"""Execution backends: make ``Node.device`` annotations real.

The planner (Eq. 10) annotates inference nodes with a device; this module
supplies the *executors* those annotations dispatch to. A backend owns
three responsibilities for embed/predict operators:

- **staging** — weights move to the execution device once per resolved
  task (``stage`` at ``MorphingSession.resolve_task``), never per chunk,
  which is exactly the amortization the cost model's TransCost term
  (Eq. 7) assumes;
- **compiled forward** — :class:`JaxBackend` compiles each resolved
  ``ZooModel`` forward pass (all four modes: linear/radial/relu/proj1d)
  plus the score head into ``jax.jit``-compiled functions. The linear
  mode routes through the fused normalize+project+tanh Pallas kernel
  (``repro.kernels.fused_embed``): interpret mode on CPU, real Pallas on
  TPU;
- **shape bucketing** — ragged chunk row counts are padded to the next
  power of two and sliced on return, so a whole query triggers at most
  O(log n) compilations instead of one per distinct chunk length.
  ``compile_count`` exposes the number of distinct compiled shapes (jit
  caches per input shape) and ``on_compile`` is a hook for tests.

``PipelineExecutor`` holds a registry ``{device annotation -> backend}``
and routes each node through it; nodes without a native backend
implementation fall back to their lowered host closure (``node.fn``).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.zoo import adapt_input_width
from repro.pipeline.batcher import BatcherStats, WindowBatcher


@dataclass
class InferSpec:
    """Everything a backend needs to run one inference operator natively.

    Attached to ``Node.meta['infer']`` by plan lowering; ``kind`` is
    'embed' (features only, share-cached) or 'predict' (features + score
    head fused). ``stats`` is the shared per-task BatcherStats sink.
    """
    kind: str
    task: str
    col: str
    out: str
    table: str
    version: str
    model: Any                       # ResolvedModel (or shim): .features,
    #                                # .head, .zoo_model
    batch_size: int = 32
    share: Optional[Any] = None      # VectorShareCache
    stats: BatcherStats = field(default_factory=BatcherStats)


class ExecutionBackend:
    """Base backend: share-cache plumbing + node fallback dispatch."""

    name = "base"

    def __init__(self):
        # InferSpec.stats is shared across concurrent chunk runs of the
        # same node: accumulate under a lock (same race class as
        # ExecStats in the executor)
        self._stats_lock = threading.Lock()

    # -- staging ----------------------------------------------------------
    def stage(self, version: str, zoo_model) -> Any:
        """Move a resolved model's weights onto the execution device.
        Idempotent per version; called once at resolve time."""
        return zoo_model

    # -- node dispatch ----------------------------------------------------
    def run_node(self, node, inputs: List[Any]) -> Any:
        spec = node.meta.get("infer") if node.meta else None
        if spec is not None and inputs:
            return self.run_infer(spec, inputs[0])
        if node.fn:
            return node.fn(*inputs)
        return inputs[0] if inputs else None

    def run_infer(self, spec: InferSpec, batch: Dict[str, np.ndarray]
                  ) -> Dict[str, np.ndarray]:
        res = dict(batch)
        X = batch[spec.col]
        if spec.kind == "embed":
            if spec.share is not None and len(X):
                res[spec.out] = spec.share.get_or_embed(
                    spec.table, spec.col, np.asarray(X),
                    lambda A: self._features(spec, A),
                    version=spec.version)
            else:
                res[spec.out] = self._features(spec, X)
        else:  # full predict: features + score head
            res[spec.out] = self._predict(spec, X)
        return res

    def run_head(self, spec: InferSpec, F: np.ndarray) -> np.ndarray:
        """Head-only execution entry point: consume embeddings, produce
        scores in ``spec.batch_size``-row slices (the head stage's own
        Eq. 11 budget). Heads are O(rows * head_dim) host work (plan
        lowering keeps them as host closures too), so the base
        implementation is shared by every backend; stats land in
        ``spec.stats`` so serving telemetry can report head rows next to
        embed rows."""
        F = np.asarray(F, np.float32)
        if len(F) == 0:
            return np.zeros(0, np.float32)
        bs = max(1, spec.batch_size)
        t0 = time.perf_counter()
        outs = [np.asarray(spec.model.head(F[i:i + bs]))
                for i in range(0, len(F), bs)]
        dt = time.perf_counter() - t0
        st = spec.stats
        with self._stats_lock:
            st.batches += len(outs)
            st.rows += len(F)
            st.infer_seconds += dt
        return np.concatenate(outs) if len(outs) > 1 else outs[0]

    # -- to implement ------------------------------------------------------
    def _features(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def _predict(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        raise NotImplementedError


class NumpyBackend(ExecutionBackend):
    """Host reference path: the resolved model's numpy forward, batched in
    window-sized slices (paper §5.2 window-function batch inference).

    A columnar 2-D numeric input already *is* an aggregated window, so it
    runs as vectorized ``batch_size`` slices; ragged/object rows fall
    back to the row-at-a-time WindowBatcher (which owns the per-row
    tensor conversion the vectorized path skips)."""

    name = "numpy"

    def _batched(self, spec: InferSpec, X: np.ndarray,
                 fn: Callable[[np.ndarray], np.ndarray]) -> np.ndarray:
        if len(X) == 0:
            # empty chunk: keep the true output width so cross-chunk
            # concatenation stays shape-consistent
            return np.asarray(fn(X))
        Xa = np.asarray(X)
        if Xa.dtype != object and Xa.ndim >= 2:
            return self._batched_sliced(spec, Xa, fn)
        wb = WindowBatcher(fn, batch_size=spec.batch_size,
                           convert_workers=1)
        for i in range(len(X)):
            wb.add(i, X[i])
        res = wb.finish()
        st = spec.stats
        with self._stats_lock:
            st.batches += wb.stats.batches
            st.rows += wb.stats.rows
            st.infer_seconds += wb.stats.infer_seconds
            st.convert_seconds += wb.stats.convert_seconds
        return np.stack([np.asarray(res[i]) for i in range(len(X))])

    def _batched_sliced(self, spec: InferSpec, X: np.ndarray,
                        fn: Callable[[np.ndarray], np.ndarray]
                        ) -> np.ndarray:
        bs = max(1, spec.batch_size)
        t0 = time.perf_counter()
        outs = [np.asarray(fn(X[i:i + bs])) for i in range(0, len(X), bs)]
        dt = time.perf_counter() - t0
        st = spec.stats
        with self._stats_lock:
            st.batches += len(outs)
            st.rows += len(X)
            st.infer_seconds += dt
        return np.concatenate(outs) if len(outs) > 1 else outs[0]

    def _features(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        return self._batched(spec, X, spec.model.features)

    def _predict(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        return spec.model.head(self._batched(spec, X, spec.model.features))


@dataclass
class StagedModel:
    """One resolved model, staged: device-resident weights + jitted fns."""
    version: str
    mode: str
    in_dim: int
    out_dim: int
    features_fn: Callable            # [B, in_dim] -> [B, out_dim]
    predict_fn: Callable             # [B, in_dim] -> [B]
    seen_shapes: Set[Tuple[str, int]] = field(default_factory=set)


def _next_pow2(n: int) -> int:
    return 1 << (max(n, 1) - 1).bit_length()


class JaxBackend(ExecutionBackend):
    """jit-compiled device path with shape bucketing + one-time staging.

    ``interpret`` defaults to True off-TPU (kernels run in Pallas
    interpret mode under jit) and False on TPU. Whole chunks run as one
    device call — the bucketing supersedes host-side window batching, so
    ``batch_size`` annotations are telemetry-only on this backend.
    """

    name = "jax"

    def __init__(self, *, interpret: Optional[bool] = None,
                 min_bucket: int = 32, block_rows: int = 256):
        import jax  # deferred so numpy-only paths never pay the import

        super().__init__()
        self._jax = jax
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else bool(interpret))
        self.min_bucket = min_bucket
        self.block_rows = block_rows
        self._staged: Dict[str, StagedModel] = {}
        self._lock = threading.Lock()
        self.stage_count = 0             # actual device stagings performed
        self.on_compile: Optional[Callable[[str, Tuple[str, int]], None]] \
            = None

    # -- staging ----------------------------------------------------------
    def stage(self, version: str, zoo_model) -> StagedModel:
        with self._lock:
            if version in self._staged:
                return self._staged[version]
        jax, jnp = self._jax, self._jax.numpy
        from repro.kernels.fused_embed import fused_embed

        mode = zoo_model.mode
        W = jax.device_put(jnp.asarray(zoo_model.W, jnp.float32))
        in_dim = int(zoo_model.W.shape[0])
        if mode == "radial":
            centers = jax.device_put(
                jnp.asarray(zoo_model.centers, jnp.float32))
            inv_two_sig2 = 1.0 / (2.0 * float(zoo_model.sigma) ** 2)
            out_dim = int(zoo_model.centers.shape[0])

            def raw(X):
                d2 = ((X[:, None, :] - centers[None]) ** 2).sum(-1)
                return jnp.exp(-d2 * inv_two_sig2)
        elif mode == "relu":
            out_dim = int(zoo_model.W.shape[1])

            def raw(X):
                return jnp.maximum(X @ W, 0.0)
        elif mode == "proj1d":
            out_dim = 2 * int(zoo_model.W.shape[1])

            def raw(X):
                Z = X @ W
                return jnp.tanh(jnp.concatenate([Z, Z ** 2 - 1.0], axis=1))
        else:  # linear -> fused normalize+project+tanh Pallas kernel
            out_dim = int(zoo_model.W.shape[1])
            interpret = self.interpret
            block_rows = self.block_rows

            def raw(X):
                return fused_embed(X, W, block_rows=block_rows,
                                   interpret=interpret)
        staged = StagedModel(
            version=version, mode=mode, in_dim=in_dim, out_dim=out_dim,
            features_fn=jax.jit(raw),
            predict_fn=jax.jit(
                lambda X: raw(X).astype(jnp.float32).mean(axis=1)))
        with self._lock:
            if version not in self._staged:   # lost race: first stage wins
                self._staged[version] = staged
                self.stage_count += 1
        return self._staged[version]

    @property
    def compile_count(self) -> int:
        """Distinct compiled (fn, bucket) shapes across staged models —
        jit compiles exactly once per new input shape."""
        with self._lock:
            return sum(len(s.seen_shapes) for s in self._staged.values())

    # -- bucketed execution ------------------------------------------------
    def _staged_for(self, spec: InferSpec) -> StagedModel:
        staged = self._staged.get(spec.version)
        if staged is None:                    # not staged at resolve: late
            staged = self.stage(spec.version, spec.model.zoo_model)
        return staged

    def _bucketed(self, staged: StagedModel, fn_key: str, fn: Callable,
                  X: np.ndarray, out_shape: Tuple[int, ...]) -> np.ndarray:
        n = len(X)
        if n == 0:
            return np.zeros(out_shape, np.float32)
        Xp = adapt_input_width(np.asarray(X, np.float32), staged.in_dim)
        d = staged.in_dim
        bucket = max(_next_pow2(n), self.min_bucket)
        if bucket == n:                       # aligned chunk: no pad copy
            Xb = np.ascontiguousarray(Xp)
        else:
            Xb = np.zeros((bucket, d), np.float32)
            Xb[:n] = Xp
        key = (fn_key, bucket)
        with self._lock:
            new_shape = key not in staged.seen_shapes
            if new_shape:
                staged.seen_shapes.add(key)
        if new_shape and self.on_compile is not None:
            self.on_compile(staged.version, key)
        out = np.asarray(fn(Xb))
        return out[:n]

    def _features(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        staged = self._staged_for(spec)
        t0 = time.perf_counter()
        out = self._bucketed(staged, "features", staged.features_fn, X,
                             (0, staged.out_dim))
        dt = time.perf_counter() - t0
        st = spec.stats
        with self._stats_lock:
            st.batches += 1 if len(X) else 0
            st.rows += len(X)
            st.infer_seconds += dt
        return out

    def _predict(self, spec: InferSpec, X: np.ndarray) -> np.ndarray:
        staged = self._staged_for(spec)
        t0 = time.perf_counter()
        # the staged predict_fn fuses the *mean* score head (what
        # ResolvedModel serves); a model carrying a custom head keeps
        # numpy-backend parity by running features on device + head on host
        if getattr(spec.model, "head_kind", "mean") == "mean":
            out = self._bucketed(staged, "predict", staged.predict_fn, X,
                                 (0,))
        else:
            F = self._bucketed(staged, "features", staged.features_fn, X,
                               (0, staged.out_dim))
            out = np.asarray(spec.model.head(F))
        dt = time.perf_counter() - t0
        st = spec.stats
        with self._stats_lock:
            st.batches += 1 if len(X) else 0
            st.rows += len(X)
            st.infer_seconds += dt
        return out

    # -- calibration hooks -------------------------------------------------
    def measure_link_bandwidth(self, nbytes: int = 8 << 20,
                               repeats: int = 3) -> float:
        """bytes/s of the host->device staging path (device_put)."""
        jax, jnp = self._jax, self._jax.numpy
        buf = np.ones(nbytes // 4, np.float32)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.device_put(buf).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return buf.nbytes / max(best, 1e-9)


_HOST_BACKEND: Optional[NumpyBackend] = None


def default_host_backend() -> NumpyBackend:
    """Singleton numpy backend used by lowered ``node.fn`` closures so
    executors constructed without a registry keep working."""
    global _HOST_BACKEND
    if _HOST_BACKEND is None:
        _HOST_BACKEND = NumpyBackend()
    return _HOST_BACKEND


def make_backends(kind: str = "auto",
                  devices: Tuple[str, ...] = ("host", "tpu")
                  ) -> Dict[str, ExecutionBackend]:
    """Build the device-annotation -> backend registry.

    'auto'  -> host: numpy, tpu: jax (numpy fallback if jax is missing)
    'numpy' -> every device runs the host numpy path
    'jax'   -> every device runs the jitted path (CPU = interpret kernels)
    """
    np_b = NumpyBackend()
    if kind == "numpy":
        return {d: np_b for d in devices}
    if kind == "jax":
        jb = JaxBackend()
        return {d: jb for d in devices}
    if kind != "auto":
        raise ValueError(f"unknown backend kind {kind!r}")
    reg: Dict[str, ExecutionBackend] = {}
    for d in devices:
        if d == "tpu":
            try:
                reg[d] = JaxBackend()
            except Exception:                 # jax unavailable: degrade
                reg[d] = np_b
        else:
            reg[d] = np_b
    return reg
