"""Admission control for the serving lanes (ROADMAP: "SLO-aware
admission and scheduling under production load").

The cost model sizes batches to minimize inference time (Eq. 10/11);
this module supplies the layer that keeps those batches healthy when
the offered load exceeds what the hardware can absorb:

- :class:`AdmissionPolicy` — one declarative knob bundle per server:
  per-lane queue-depth caps with a backpressure mode (``reject`` returns
  a typed :class:`Rejected` immediately, ``block`` waits up to a timeout
  for the queue to drain), per-request **priority classes** with
  weighted lane draining, retry/backoff limits for transient backend
  failures, and the circuit-breaker thresholds;
- typed admission outcomes — :class:`Rejected` (backpressure),
  :class:`CircuitOpen` (the lane's breaker tripped after repeated batch
  failures), :class:`RequestError` (this request's batch failed after
  retries; the *lane* is fine and keeps serving);
- :class:`LaneBreaker` — consecutive-failure circuit breaker: a lane
  whose batches fail ``breaker_threshold`` times in a row stops
  admitting (queued requests drain with :class:`CircuitOpen`) until a
  supervisor resets it after ``breaker_cooldown_s``.

The deadline-aware dynamic row budget that pairs with this policy lives
in :class:`repro.pipeline.cost.DynamicBudget` (it is Eq. 11 made
adaptive, so it belongs with the rest of the batch-size math).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Sequence, Tuple

# priority classes, in draining-preference order. The weights say how
# many requests of a class the weighted-round-robin drain pops per
# credit cycle while lower classes still have queued work: interactive
# traffic is preferred 8:3:1 but best-effort is never fully starved.
INTERACTIVE = "interactive"
BATCH = "batch"
BEST_EFFORT = "best_effort"
PRIORITIES: Tuple[str, ...] = (INTERACTIVE, BATCH, BEST_EFFORT)
DEFAULT_WEIGHTS: Dict[str, int] = {INTERACTIVE: 8, BATCH: 3, BEST_EFFORT: 1}


def _rebuild_error(cls, args, state):
    """Reconstruct a typed admission error from (class, args, attrs).

    The default exception pickling replays ``cls(*args)``, which loses
    every keyword-only field (lane, priority, reason, ...). These errors
    cross the dispatch tier's process boundary, so they rebuild from the
    message args plus the full attribute dict instead."""
    err = cls.__new__(cls)
    RuntimeError.__init__(err, *args)
    err.__dict__.update(state)
    return err


class Rejected(RuntimeError):
    """Typed admission failure: the lane's queue-depth cap (or its
    block-timeout) pushed back. Carries enough context for the caller
    to decide whether to retry, downgrade priority, or shed."""

    def __init__(self, message: str, *, lane: str = "",
                 priority: str = BATCH, queued_units: int = 0,
                 cap: int = 0, reason: str = "queue_full"):
        super().__init__(message)
        self.lane = lane
        self.priority = priority
        self.queued_units = queued_units
        self.cap = cap
        self.reason = reason

    def __reduce__(self):
        return _rebuild_error, (type(self), self.args, dict(self.__dict__))


class CircuitOpen(Rejected):
    """The lane's circuit breaker is open: repeated batch failures
    tripped it and the lane sheds all traffic until a supervisor resets
    it (``MorphingServer`` does so on the next submit after the
    cooldown)."""

    def __init__(self, message: str, *, lane: str = "",
                 priority: str = BATCH, failures: int = 0):
        super().__init__(message, lane=lane, priority=priority,
                         reason="breaker_open")
        self.failures = failures


class RequestError(RuntimeError):
    """A served request's batch failed after the retry budget. The
    failure is scoped to the requests that shared the batch — the lane
    worker survived and keeps serving; ``__cause__`` holds the backend
    exception."""

    def __init__(self, message: str, *, lane: str = "",
                 attempts: int = 1,
                 req_ids: Sequence[int] = ()):
        super().__init__(message)
        self.lane = lane
        self.attempts = attempts
        self.req_ids = tuple(req_ids)

    def __reduce__(self):
        return _rebuild_error, (type(self), self.args, dict(self.__dict__))


@dataclass(frozen=True)
class AdmissionPolicy:
    """Declarative per-server admission/robustness policy, applied to
    every lane (`docs/serving.md` "Admission & SLOs").

    Queue caps are measured in the lane's ``size_of`` units — rows for
    serving lanes — and bound *queued* work only; in-flight batches are
    bounded by the (dynamic) Eq. 11 row budget.
    """
    max_queue_rows: int = 65536          # per-lane cap over all classes
    # optional tighter per-class caps, e.g. {"best_effort": 2048}: a
    # class at its cap rejects while the others keep admitting
    per_priority_rows: Mapping[str, int] = field(default_factory=dict)
    mode: str = "reject"                 # 'reject' | 'block'
    block_timeout_s: float = 1.0
    weights: Mapping[str, int] = field(
        default_factory=lambda: dict(DEFAULT_WEIGHTS))
    # transient-failure handling: a failed batch retries with capped
    # exponential backoff before surfacing RequestError
    retry_limit: int = 2
    retry_backoff_s: float = 0.01
    retry_backoff_cap_s: float = 0.25
    # circuit breaker: this many *consecutive* permanently-failed
    # batches trip the lane (0 disables)
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 0.5
    # deadline-aware dynamic Eq. 11 budget (cost.DynamicBudget)
    min_batch_rows: int = 8
    shrink_at: float = 0.8               # p95/deadline ratio that shrinks
    grow_at: float = 0.4                 # ratio below which budgets regrow

    def __post_init__(self):
        if self.mode not in ("reject", "block"):
            raise ValueError(f"unknown backpressure mode {self.mode!r}")
        bad = set(self.per_priority_rows) - set(PRIORITIES)
        bad |= set(self.weights) - set(PRIORITIES)
        if bad:
            raise ValueError(f"unknown priority classes {sorted(bad)}")

    def weight_of(self, priority: str) -> int:
        return max(int(self.weights.get(priority,
                                        DEFAULT_WEIGHTS.get(priority, 1))),
                   1)

    def cap_of(self, priority: str) -> int:
        """Effective queue cap for one class (min of the class cap and
        the lane-wide cap)."""
        cap = self.per_priority_rows.get(priority, self.max_queue_rows)
        return min(int(cap), int(self.max_queue_rows))

    def backoff_s(self, attempt: int) -> float:
        """Capped exponential backoff before retry ``attempt`` (1-based)."""
        return min(self.retry_backoff_s * (2.0 ** max(attempt - 1, 0)),
                   self.retry_backoff_cap_s)


def validate_priority(priority: str) -> str:
    if priority not in PRIORITIES:
        raise ValueError(
            f"unknown priority {priority!r}; expected one of {PRIORITIES}")
    return priority


@dataclass
class LaneBreaker:
    """Consecutive-failure circuit breaker for one lane.

    Not thread-safe by itself — the owning batcher mutates it under its
    condition variable. ``threshold <= 0`` disables tripping."""
    threshold: int = 3
    cooldown_s: float = 0.5
    failures: int = 0                    # consecutive failed batches
    trips: int = 0
    open: bool = False
    opened_at: float = 0.0

    def record_success(self) -> None:
        self.failures = 0

    def record_failure(self, now: float) -> bool:
        """Count one permanently-failed batch; returns True when this
        failure trips the breaker open."""
        self.failures += 1
        if self.threshold > 0 and self.failures >= self.threshold \
                and not self.open:
            self.open = True
            self.opened_at = now
            self.trips += 1
            return True
        return False

    def cooled_down(self, now: float) -> bool:
        return self.open and (now - self.opened_at) >= self.cooldown_s

    def reset(self) -> None:
        self.open = False
        self.failures = 0
