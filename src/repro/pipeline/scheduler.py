"""Pipelined DAG execution (paper §5.2 'Pipeline Processing').

The executor walks the DAG in Algorithm-1 order; independent operators of
a wave run concurrently on a thread pool (host relational work overlaps
device inference), and ``predict`` nodes are dispatched to the device the
cost model selected. Chunked mode streams table chunks through the whole
DAG so stage i of chunk c overlaps stage i+1 of chunk c-1 — the paper's
'minimize idle time between stages'.
"""
from __future__ import annotations

import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.pipeline.cost import OpProfile, choose_device, op_cost
from repro.pipeline.dag import Dag, Node
from repro.pipeline.operators import Batch, batch_len, concat_batches, iter_chunks


@dataclass
class ExecStats:
    wall_seconds: float = 0.0
    op_seconds: Dict[str, float] = field(default_factory=dict)
    device_of: Dict[str, str] = field(default_factory=dict)
    rows_out: int = 0


class PipelineExecutor:
    def __init__(self, dag: Dag, *, workers: int = 4,
                 profiles: Optional[Dict[str, OpProfile]] = None,
                 devices=("host", "tpu")):
        self.dag = dag
        self.workers = workers
        self.profiles = profiles or {}
        self.devices = devices
        self.stats = ExecStats()

    # -- device placement (cost model, Eq. 10) -----------------------------
    def place(self, nrows_hint: int = 1024) -> Dict[str, str]:
        placement = {}
        for op_id, node in self.dag.nodes.items():
            prof = self.profiles.get(op_id)
            if node.kind in ("predict", "embed") and prof is not None:
                placement[op_id] = choose_device(prof, nrows_hint,
                                                 self.devices)
            else:
                placement[op_id] = "host"
            node.device = placement[op_id]
        self.stats.device_of = placement
        return placement

    # -- execution ---------------------------------------------------------
    def _run_node(self, node: Node, inputs: List[Any]) -> Any:
        t0 = time.time()
        out = node.fn(*inputs) if node.fn else (inputs[0] if inputs else None)
        self.stats.op_seconds[node.op_id] = (
            self.stats.op_seconds.get(node.op_id, 0.0) + time.time() - t0)
        return out

    def execute(self, sources: Dict[str, Any]) -> Dict[str, Any]:
        """Single-shot wave execution with intra-wave parallelism."""
        dep = self.dag.dependency_map()
        results: Dict[str, Any] = dict(sources)
        t0 = time.time()
        with ThreadPoolExecutor(self.workers) as pool:
            for wave in self.dag.stages():
                futs: Dict[str, Future] = {}
                for op_id in wave:
                    if op_id in results:  # source node
                        continue
                    node = self.dag.nodes[op_id]
                    ins = [results[d] for d in sorted(
                        dep[op_id],
                        key=lambda u: node.meta.get("arg_order", {}).get(u, 0))]
                    futs[op_id] = pool.submit(self._run_node, node, ins)
                for op_id, f in futs.items():
                    results[op_id] = f.result()
        self.stats.wall_seconds = time.time() - t0
        return results

    def execute_chunked(self, source_id: str, table: Batch,
                        chunk_rows: int = 256,
                        sink_id: Optional[str] = None,
                        static: Optional[Dict[str, Any]] = None) -> Batch:
        """Stream chunks through the DAG with cross-chunk stage overlap:
        chunk c's wave w runs while chunk c+1's wave w-1 runs. ``static``
        supplies non-streamed sources (e.g. dimension tables)."""
        static = static or {}
        order = [v for v in self.dag.execution_order()
                 if v != source_id and v not in static]
        dep = self.dag.dependency_map()
        t0 = time.time()
        outs: List[Batch] = []
        with ThreadPoolExecutor(self.workers) as pool:
            inflight: List[Dict[str, Future]] = []

            def launch(chunk: Batch) -> Dict[str, Future]:
                futs: Dict[str, Future] = {}
                base: Dict[str, Any] = {source_id: chunk, **static}

                def make_runner(op_id):
                    node = self.dag.nodes[op_id]

                    def run():
                        ins = []
                        for d in sorted(dep[op_id], key=lambda u: node.meta
                                        .get("arg_order", {}).get(u, 0)):
                            ins.append(base[d] if d in base
                                       else futs[d].result())
                        return self._run_node(node, ins)
                    return run

                for op_id in order:
                    futs[op_id] = pool.submit(make_runner(op_id))
                return futs

            for chunk in iter_chunks(table, chunk_rows):
                inflight.append(launch(chunk))
                if len(inflight) > 2:  # bounded pipeline depth
                    done = inflight.pop(0)
                    outs.append(done[sink_id or order[-1]].result())
            for futs in inflight:
                outs.append(futs[sink_id or order[-1]].result())
        self.stats.wall_seconds = time.time() - t0
        result = concat_batches(outs) if outs else {}
        self.stats.rows_out = batch_len(result)
        return result
