"""Pipelined DAG execution (paper §5.2 'Pipeline Processing').

The executor is a *pure runtime*: it walks an already-annotated DAG in
Algorithm-1 order; independent operators of a wave run concurrently on a
thread pool (host relational work overlaps device inference), and each
node runs on the device its ``Node.device`` annotation names. Placement
itself is a planning decision — `repro.pipeline.cost.place_dag` (Eq. 10)
or the `repro.engine` optimizer annotates the DAG before execution.
Chunked mode streams table chunks through the whole DAG so stage i of
chunk c overlaps stage i+1 of chunk c-1 — the paper's 'minimize idle
time between stages' — with a configurable in-flight depth.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.pipeline.backend import ExecutionBackend
from repro.pipeline.dag import Dag, Node
from repro.pipeline.operators import (Batch, batch_len, concat_batches,
                                      iter_chunks, slice_batch)


@dataclass
class ExecStats:
    wall_seconds: float = 0.0
    op_seconds: Dict[str, float] = field(default_factory=dict)
    device_of: Dict[str, str] = field(default_factory=dict)
    backend_of: Dict[str, str] = field(default_factory=dict)
    calls_of: Dict[str, int] = field(default_factory=dict)
    rows_out: int = 0


class PipelineExecutor:
    def __init__(self, dag: Dag, *, workers: int = 4,
                 backends: Optional[Dict[str, ExecutionBackend]] = None):
        self.dag = dag
        self.workers = workers
        self.backends = backends or {}
        self.stats = ExecStats()
        self._stats_lock = threading.Lock()

    # -- execution ---------------------------------------------------------
    def _run_node(self, node: Node, inputs: List[Any]) -> Any:
        backend = self.backends.get(node.device)
        t0 = time.perf_counter()
        if backend is not None:
            out = backend.run_node(node, inputs)
        else:
            out = (node.fn(*inputs) if node.fn
                   else (inputs[0] if inputs else None))
        dt = time.perf_counter() - t0
        # chunked mode runs nodes from pool threads: accumulate under the
        # lock (dict read-modify-write is not atomic across threads)
        with self._stats_lock:
            s = self.stats
            s.op_seconds[node.op_id] = s.op_seconds.get(node.op_id, 0.0) + dt
            s.calls_of[node.op_id] = s.calls_of.get(node.op_id, 0) + 1
            s.device_of[node.op_id] = node.device
            s.backend_of[node.op_id] = (backend.name if backend is not None
                                        else "fn")
        return out

    def execute(self, sources: Dict[str, Any]) -> Dict[str, Any]:
        """Single-shot wave execution with intra-wave parallelism."""
        dep = self.dag.dependency_map()
        results: Dict[str, Any] = dict(sources)
        t0 = time.time()
        with ThreadPoolExecutor(self.workers) as pool:
            for wave in self.dag.stages():
                futs: Dict[str, Future] = {}
                for op_id in wave:
                    if op_id in results:  # source node
                        continue
                    node = self.dag.nodes[op_id]
                    ins = [results[d] for d in sorted(
                        dep[op_id],
                        key=lambda u: node.meta.get("arg_order", {}).get(u, 0))]
                    futs[op_id] = pool.submit(self._run_node, node, ins)
                for op_id, f in futs.items():
                    results[op_id] = f.result()
        self.stats.wall_seconds = time.time() - t0
        return results

    def execute_chunked(self, source_id: str, table: Batch,
                        chunk_rows: int = 256,
                        sink_id: Optional[str] = None,
                        static: Optional[Dict[str, Any]] = None,
                        max_inflight: int = 3) -> Batch:
        """Stream chunks through the DAG with cross-chunk stage overlap:
        chunk c's wave w runs while chunk c+1's wave w-1 runs. ``static``
        supplies non-streamed sources (e.g. dimension tables);
        ``max_inflight`` bounds how many chunks may be in the pipeline at
        once (memory vs overlap trade-off)."""
        static = static or {}
        max_inflight = max(1, max_inflight)
        order = [v for v in self.dag.execution_order()
                 if v != source_id and v not in static]
        dep = self.dag.dependency_map()
        t0 = time.time()
        outs: List[Batch] = []
        with ThreadPoolExecutor(self.workers) as pool:
            inflight: List[Dict[str, Future]] = []

            def launch(chunk: Batch) -> Dict[str, Future]:
                futs: Dict[str, Future] = {}
                base: Dict[str, Any] = {source_id: chunk, **static}

                def make_runner(op_id):
                    node = self.dag.nodes[op_id]

                    def run():
                        ins = []
                        for d in sorted(dep[op_id], key=lambda u: node.meta
                                        .get("arg_order", {}).get(u, 0)):
                            ins.append(base[d] if d in base
                                       else futs[d].result())
                        return self._run_node(node, ins)
                    return run

                for op_id in order:
                    futs[op_id] = pool.submit(make_runner(op_id))
                return futs

            chunks = iter_chunks(table, chunk_rows)
            if batch_len(table) == 0:
                # stream one empty chunk so the output keeps the schema
                # the pipeline produces (columns, dtypes) at zero rows
                chunks = iter([slice_batch(table, 0, 0)])
            for chunk in chunks:
                inflight.append(launch(chunk))
                if len(inflight) > max_inflight - 1:  # bounded depth
                    done = inflight.pop(0)
                    outs.append(done[sink_id or order[-1]].result())
            for futs in inflight:
                outs.append(futs[sink_id or order[-1]].result())
        self.stats.wall_seconds = time.time() - t0
        result = concat_batches(outs) if outs else {}
        self.stats.rows_out = batch_len(result)
        return result
