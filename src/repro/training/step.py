"""jit-able train / prefill / serve step builders.

These are the units the launcher jits onto the production mesh and the
dry-run lowers+compiles per (arch x shape x mesh) cell.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.training.optimizer import (AdamWState, OptimizerConfig,
                                      apply_updates)


def make_train_step(model, opt_cfg: OptimizerConfig,
                    accum_steps: int = 1) -> Callable:
    """fwd+bwd+AdamW. With ``accum_steps > 1`` the global batch is split
    into microbatches scanned sequentially (gradient accumulation) —
    activation memory scales with the microbatch, enabling 100B+ archs on
    16 GB/chip meshes."""

    grad_fn = jax.value_and_grad(model.loss, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch):
        if accum_steps <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def mb(carry, mbatch):
                gsum, lsum = carry
                (l, m), g = grad_fn(params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), ms = jax.lax.scan(
                mb, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
            metrics = jax.tree.map(lambda m: m[-1], ms)
        new_params, new_state, om = apply_updates(opt_cfg, params, grads,
                                                  opt_state)
        out = {"loss": loss, **metrics, **om}
        return new_params, new_state, out

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}

    return eval_step


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch):
        if model.cfg.is_encoder_decoder:
            return model.prefill(params, batch)
        return model.prefill(params, batch["tokens"])

    return prefill_step


def make_serve_step(model, greedy: bool = True) -> Callable:
    """One decode step: (params, cache, tokens[B,1]) -> (next[B,1], cache)."""

    def serve_step(params, state, tokens):
        logits, state = model.decode_step(params, state, tokens)
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return nxt, state

    return serve_step
