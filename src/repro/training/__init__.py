from repro.training.fault import (ElasticScaler, FaultInjector,
                                  InjectedFault, StragglerMonitor,
                                  TrainController)
from repro.training.optimizer import (AdamWState, OptimizerConfig,
                                      abstract_state, apply_updates,
                                      init_state, state_axes)
from repro.training.step import (make_eval_step, make_prefill_step,
                                 make_serve_step, make_train_step)

__all__ = [
    "ElasticScaler", "FaultInjector", "InjectedFault", "StragglerMonitor",
    "TrainController",
    "AdamWState", "OptimizerConfig", "abstract_state", "apply_updates",
    "init_state", "state_axes", "make_eval_step", "make_prefill_step",
    "make_serve_step", "make_train_step",
]
