"""AdamW + schedules, from scratch (no optax dependency).

Optimizer state is a pytree parallel to params (m, v in f32), sharded
identically to the parameters (axes tree reuse), which makes FSDP'd
optimizer state free.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # bf16 moments halve optimizer HBM for trillion-param archs
    # (beyond-paper distributed-memory optimization; see EXPERIMENTS.md).
    opt_dtype: str = "float32"


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_state(params, opt_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(opt_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def abstract_state(abstract_params, opt_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(opt_dtype)
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, dt), abstract_params)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), z, z)


def state_axes(param_axes) -> AdamWState:
    return AdamWState((), param_axes, param_axes)


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), grads), g


def apply_updates(cfg: OptimizerConfig, params, grads,
                  state: AdamWState) -> Tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    odt = jnp.dtype(cfg.opt_dtype)

    def upd(p, g, m, v):
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/scales exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(odt), v32.astype(odt)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
