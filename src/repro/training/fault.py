"""Fault tolerance for pod-scale training.

Components (all exercised by tests with simulated failures):
  - ``TrainController``: checkpoint-every-N + automatic restart-from-latest
    on step failure; bounded retries; async save so the loop doesn't stall.
  - ``StragglerMonitor``: per-host step-time tracking; flags hosts slower
    than ``median * threshold`` over a sliding window — the mitigation hook
    triggers (a) redistribution (shrink data-parallel degree) or (b) host
    replacement, per policy.
  - ``ElasticScaler``: recompute data-parallel layout when the healthy host
    set changes, and reshard the latest checkpoint onto it (Mvec range
    reads; no full-checkpoint rewrite needed).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.storage.checkpoint import CheckpointManager


@dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x median step time
    window: int = 8
    min_samples: int = 4
    _hist: Dict[int, deque] = field(default_factory=dict)

    def record(self, host: int, step_time: float) -> None:
        self._hist.setdefault(host, deque(maxlen=self.window)).append(step_time)

    def stragglers(self) -> List[int]:
        means = {h: float(np.mean(v)) for h, v in self._hist.items()
                 if len(v) >= self.min_samples}
        if len(means) < 2:
            return []
        med = float(np.median(list(means.values())))
        return [h for h, m in means.items() if m > self.threshold * med]


@dataclass
class ElasticScaler:
    """Tracks the healthy host set; yields dp layout + restore shards."""
    num_hosts: int
    failed: set = field(default_factory=set)

    @property
    def healthy(self) -> List[int]:
        return [h for h in range(self.num_hosts) if h not in self.failed]

    def fail(self, host: int) -> None:
        self.failed.add(host)

    def recover(self, host: int) -> None:
        self.failed.discard(host)

    def layout(self) -> Dict[str, Any]:
        n = len(self.healthy)
        return {"dp_degree": n, "hosts": self.healthy}

    def reshard_plan(self, ckpt: CheckpointManager, template) -> Dict[int, Any]:
        """Per-healthy-host restore slices from the latest checkpoint."""
        n = len(self.healthy)
        plan = {}
        for rank, host in enumerate(self.healthy):
            state, step = ckpt.restore(template, shard=rank, num_hosts=n)
            plan[host] = (state, step)
        return plan


class TrainController:
    """Checkpointed, restartable training loop driver."""

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 *, ckpt_every: int = 10, max_restarts: int = 5,
                 monitor: Optional[StragglerMonitor] = None,
                 on_event: Optional[Callable[[str, dict], None]] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.monitor = monitor or StragglerMonitor()
        self.events: List[Tuple[str, dict]] = []
        self._on_event = on_event

    def _event(self, kind: str, **info) -> None:
        self.events.append((kind, info))
        if self._on_event:
            self._on_event(kind, info)

    def run(self, state, num_steps: int, *, start_step: int = 0,
            num_shards: int = 1):
        """Run ``num_steps``; on exception restore latest checkpoint and
        continue. ``state`` is the full pytree the step_fn maps over."""
        step = start_step
        restarts = 0
        if self.ckpt.latest_step() is not None:
            state, step = self.ckpt.restore(state)
            self._event("resume", step=step)
        while step < num_steps:
            t0 = time.time()
            try:
                state = self.step_fn(state, step)
            except Exception as e:  # noqa: BLE001 - any step failure
                restarts += 1
                self._event("failure", step=step, error=repr(e),
                            restarts=restarts)
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                self.ckpt.wait()
                if self.ckpt.latest_step() is not None:
                    state, step = self.ckpt.restore(state)
                    self._event("restart", from_step=step)
                continue
            dt = time.time() - t0
            self.monitor.record(0, dt)
            step += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save_async(step, state, num_shards=num_shards)
                self._event("checkpoint", step=step)
        self.ckpt.wait()
        return state, step
