"""Fault tolerance for pod-scale training — and fault *injection* for
the serving path.

Components (all exercised by tests with simulated failures):
  - ``TrainController``: checkpoint-every-N + automatic restart-from-latest
    on step failure; bounded retries; async save so the loop doesn't stall.
  - ``StragglerMonitor``: per-host step-time tracking; flags hosts slower
    than ``median * threshold`` over a sliding window — the mitigation hook
    triggers (a) redistribution (shrink data-parallel degree) or (b) host
    replacement, per policy.
  - ``ElasticScaler``: recompute data-parallel layout when the healthy host
    set changes, and reshard the latest checkpoint onto it (Mvec range
    reads; no full-checkpoint rewrite needed).
  - ``FaultInjector``: the serving-side chaos hook. Threaded through
    ``BackendPool.set_fault_injector`` it fires on every backend
    ``run_infer`` call — probabilistic or scripted ``InjectedFault``
    errors, stalls, and slow batches — so the admission layer's retry /
    breaker / fault-attribution machinery can be exercised by tests and
    ``benchmarks/bench_overload.py`` without a real flaky device.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.storage.checkpoint import CheckpointManager


class InjectedFault(RuntimeError):
    """A simulated backend failure (distinguishable from real errors so
    chaos tests can assert nothing *else* broke)."""


@dataclass
class FaultInjector:
    """Deterministic chaos for backend inference calls.

    Faults are decided per ``run_infer`` call (one trunk batch), indexed
    from 0 in call order, so a *retry* of a failed batch is a fresh call
    with a fresh roll — exactly the transient-failure model the
    batcher's retry/backoff path targets. ``scripted_errors`` pins
    specific call indices to fail regardless of ``error_rate`` (e.g.
    ``{0, 1, 2}`` trips a threshold-3 breaker deterministically).

    Thread-safe: lanes on different backends share one injector.
    """
    error_rate: float = 0.0          # P(call raises InjectedFault)
    scripted_errors: Sequence[int] = ()
    slow_rate: float = 0.0           # P(call sleeps slow_s first)
    slow_s: float = 0.0
    stall_rate: float = 0.0          # P(call wedges stall_s — long sleeps
    stall_s: float = 0.0             # exercise the stop-timeout path)
    kinds: Sequence[str] = ("embed", "predict")
    seed: int = 0
    armed: bool = True

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._lock = threading.Lock()
        self._scripted = set(int(i) for i in self.scripted_errors)
        self.calls = 0
        self.injected_errors = 0
        self.injected_slow = 0
        self.injected_stalls = 0
        self.error_calls: List[int] = []   # which call indices failed

    def arm(self) -> None:
        self.armed = True

    def disarm(self) -> None:
        """Stop injecting (counters keep their totals) — benches disarm
        for the fault-free parity leg without rebuilding the server."""
        self.armed = False

    def on_infer(self, spec, n_rows: int) -> None:
        """Called by the backend at the top of every ``run_infer``.
        May sleep (slow/stall) and may raise :class:`InjectedFault`."""
        if not self.armed or getattr(spec, "kind", None) not in self.kinds:
            return
        with self._lock:
            idx = self.calls
            self.calls += 1
            fail = idx in self._scripted \
                or (self.error_rate > 0
                    and self._rng.random() < self.error_rate)
            slow = (self.slow_rate > 0
                    and self._rng.random() < self.slow_rate)
            stall = (self.stall_rate > 0
                     and self._rng.random() < self.stall_rate)
            if slow:
                self.injected_slow += 1
            if stall:
                self.injected_stalls += 1
            if fail:
                self.injected_errors += 1
                self.error_calls.append(idx)
        if slow and self.slow_s > 0:
            time.sleep(self.slow_s)
        if stall and self.stall_s > 0:
            time.sleep(self.stall_s)
        if fail:
            raise InjectedFault(
                f"injected backend fault on infer call {idx} "
                f"({getattr(spec, 'kind', '?')}/"
                f"{getattr(spec, 'task', '?')}, {n_rows} rows)")


@dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x median step time
    window: int = 8
    min_samples: int = 4
    _hist: Dict[int, deque] = field(default_factory=dict)

    def record(self, host: int, step_time: float) -> None:
        self._hist.setdefault(host, deque(maxlen=self.window)).append(step_time)

    def stragglers(self) -> List[int]:
        means = {h: float(np.mean(v)) for h, v in self._hist.items()
                 if len(v) >= self.min_samples}
        if len(means) < 2:
            return []
        med = float(np.median(list(means.values())))
        return [h for h, m in means.items() if m > self.threshold * med]


@dataclass
class ElasticScaler:
    """Tracks the healthy host set; yields dp layout + restore shards."""
    num_hosts: int
    failed: set = field(default_factory=set)

    @property
    def healthy(self) -> List[int]:
        return [h for h in range(self.num_hosts) if h not in self.failed]

    def fail(self, host: int) -> None:
        self.failed.add(host)

    def recover(self, host: int) -> None:
        self.failed.discard(host)

    def layout(self) -> Dict[str, Any]:
        n = len(self.healthy)
        return {"dp_degree": n, "hosts": self.healthy}

    def reshard_plan(self, ckpt: CheckpointManager, template) -> Dict[int, Any]:
        """Per-healthy-host restore slices from the latest checkpoint."""
        n = len(self.healthy)
        plan = {}
        for rank, host in enumerate(self.healthy):
            state, step = ckpt.restore(template, shard=rank, num_hosts=n)
            plan[host] = (state, step)
        return plan


class TrainController:
    """Checkpointed, restartable training loop driver."""

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 *, ckpt_every: int = 10, max_restarts: int = 5,
                 monitor: Optional[StragglerMonitor] = None,
                 on_event: Optional[Callable[[str, dict], None]] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.monitor = monitor or StragglerMonitor()
        self.events: List[Tuple[str, dict]] = []
        self._on_event = on_event

    def _event(self, kind: str, **info) -> None:
        self.events.append((kind, info))
        if self._on_event:
            self._on_event(kind, info)

    def run(self, state, num_steps: int, *, start_step: int = 0,
            num_shards: int = 1):
        """Run ``num_steps``; on exception restore latest checkpoint and
        continue. ``state`` is the full pytree the step_fn maps over."""
        step = start_step
        restarts = 0
        if self.ckpt.latest_step() is not None:
            state, step = self.ckpt.restore(state)
            self._event("resume", step=step)
        while step < num_steps:
            t0 = time.time()
            try:
                state = self.step_fn(state, step)
            except Exception as e:  # noqa: BLE001 - any step failure
                restarts += 1
                self._event("failure", step=step, error=repr(e),
                            restarts=restarts)
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                self.ckpt.wait()
                if self.ckpt.latest_step() is not None:
                    state, step = self.ckpt.restore(state)
                    self._event("restart", from_step=step)
                continue
            dt = time.time() - t0
            self.monitor.record(0, dt)
            step += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save_async(step, state, num_shards=num_shards)
                self._event("checkpoint", step=step)
        self.ckpt.wait()
        return state, step
